"""L2 JAX model: the wafer-shard step function.

One *shard* is the slice of the neural network hosted behind one
communication FPGA. The rust coordinator drives an AOT-compiled step per
shard per timestep:

    state' = step(state, spikes_in, w)

with

    state:     f32[3, n_local]   packed (v, refrac, last spikes)
    spikes_in: f32[n_global]     global spike vector delivered over the
                                 simulated Extoll fabric (0/1 or counts)
    w:         f32[n_local, n_global] synaptic weights (uploaded once,
                                 kept device-side by the rust runtime)

Model parameters (decay, threshold, reset, refractory period, external
drive) are baked into the lowered HLO as constants and recorded in the
artifact manifest so the rust side knows what it is running.

The function composes the two L1 Pallas kernels so everything lowers into
a single HLO module.
"""

import dataclasses
import functools

from .kernels.lif_step import lif_step
from .kernels.synapse import synapse_input


@dataclasses.dataclass(frozen=True)
class LifParams:
    """LIF parameters, fixed at AOT time."""

    # membrane decay per timestep: exp(-dt/tau_m); dt=0.1ms, tau_m=10ms
    decay: float = 0.99004983
    v_th: float = 1.0
    v_reset: float = 0.0
    refrac_steps: float = 20.0  # 2 ms at dt=0.1ms
    # constant external drive (models the Poisson background of the
    # cortical microcircuit's stationary state); slightly suprathreshold so
    # isolated neurons fire tonically at ~20 Hz biological (charge time
    # ~390 steps at dt=0.1 ms) and the recurrent E/I interaction shapes
    # the rates around that operating point
    i_ext: float = 1.02

    def to_dict(self):
        return dataclasses.asdict(self)


def make_shard_step(params: LifParams, *, block_n=512, block_m=256, block_k=512,
                    interpret=True):
    """Build the shard step function for given parameters and tilings."""

    def step(state, spikes_in, w):
        i_syn = synapse_input(w, spikes_in, block_m=block_m, block_k=block_k,
                              interpret=interpret)
        i_total = i_syn + params.i_ext
        return lif_step(
            state,
            i_total,
            decay=params.decay,
            v_th=params.v_th,
            v_reset=params.v_reset,
            refrac_steps=params.refrac_steps,
            block_n=block_n,
            interpret=interpret,
        )

    return step


@functools.lru_cache(maxsize=None)
def default_params() -> LifParams:
    return LifParams()
