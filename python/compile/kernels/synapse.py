"""L1 Pallas kernel: synaptic input accumulation.

Computes the per-neuron input current of a shard from the global spike
vector: ``i = W @ s`` with ``W: f32[n_local, n_global]`` and
``s: f32[n_global]`` (0/1 spike indicators, or spike counts when several
source steps are batched by the coordinator).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles ``W`` into
``(block_m, block_k)`` VMEM blocks; the k-axis accumulation is the
HBM→VMEM streaming schedule a GPU implementation would express with
threadblocks, and the inner product is MXU-shaped when the coordinator
batches spike vectors (matvec degenerates to VPU work, which is fine for
the CPU-interpret path used here).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _synapse_kernel(w_ref, s_ref, o_ref):
    """Accumulate one (block_m × block_k) tile of the matvec."""
    k = pl.program_id(1)
    partial = w_ref[...] @ s_ref[...]

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial


def synapse_input(w, s, *, block_m=256, block_k=512, interpret=True):
    """Synaptic current ``w @ s`` with explicit tiling.

    Args:
      w: f32[n_local, n_global] synaptic weights (signed; inhibitory < 0).
      s: f32[n_global] spike vector/counts.
      block_m: output-axis tile (rows of W per grid step).
      block_k: reduction-axis tile (columns of W per grid step).
      interpret: Pallas interpret mode (required for CPU PJRT).

    Returns:
      f32[n_local] input currents.
    """
    n_local, n_global = w.shape
    assert s.shape == (n_global,)
    assert n_local % block_m == 0, f"n_local={n_local} % block_m={block_m} != 0"
    assert n_global % block_k == 0, f"n_global={n_global} % block_k={block_k} != 0"
    grid = (n_local // block_m, n_global // block_k)
    return pl.pallas_call(
        _synapse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_k,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_local,), jnp.float32),
        interpret=interpret,
    )(w, s)
