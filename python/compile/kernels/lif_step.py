"""L1 Pallas kernel: leaky integrate-and-fire neuron update.

The role HICANN plays in the BrainScaleS system — emulating neuron
dynamics that produce the spike traffic — is filled here by a LIF model
compiled ahead-of-time. The kernel updates a *shard* of neurons (the
slice hosted behind one FPGA) in VMEM-sized tiles over the neuron axis.

State layout (one packed f32 array, so the AOT executable has a single
non-tuple output that the rust runtime can keep device-side):

    state[0, :] = membrane potential v
    state[1, :] = refractory countdown (timesteps, 0 = active)
    state[2, :] = spike output of the *previous* step (0.0 / 1.0)

TPU notes (DESIGN.md §Hardware-Adaptation): the neuron axis is blocked by
``block_n`` via ``BlockSpec`` — on a real TPU each tile lives in VMEM and
the elementwise update vectorizes on the VPU; ``interpret=True`` keeps
the same schedule executable on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STATE_ROWS = 3


def _lif_kernel(state_ref, i_in_ref, out_ref, *, decay, v_th, v_reset, refrac_steps):
    """One LIF update on a block of neurons."""
    v = state_ref[0, :]
    r = state_ref[1, :]
    i_in = i_in_ref[...]
    active = r <= 0.0
    # exponential membrane integration towards the input current
    v_new = jnp.where(active, v * decay + i_in * (1.0 - decay), v)
    spike = jnp.logical_and(v_new >= v_th, active)
    v_out = jnp.where(spike, v_reset, v_new)
    r_out = jnp.where(spike, jnp.float32(refrac_steps), jnp.maximum(r - 1.0, 0.0))
    out_ref[0, :] = v_out
    out_ref[1, :] = r_out
    out_ref[2, :] = spike.astype(jnp.float32)


def lif_step(state, i_in, *, decay, v_th, v_reset, refrac_steps, block_n=512,
             interpret=True):
    """Apply one LIF timestep to a neuron shard.

    Args:
      state: f32[3, n] packed state (see module docstring).
      i_in:  f32[n] total input current for this step.
      decay: membrane decay factor exp(-dt/tau_m).
      v_th / v_reset: threshold and reset potentials.
      refrac_steps: refractory period in timesteps.
      block_n: neuron-axis tile size (VMEM sizing on TPU).
      interpret: Pallas interpret mode (required for CPU PJRT).

    Returns:
      f32[3, n] updated state; row 2 holds this step's spikes.
    """
    n = state.shape[1]
    assert state.shape == (STATE_ROWS, n)
    assert i_in.shape == (n,)
    assert n % block_n == 0, f"n={n} must be a multiple of block_n={block_n}"
    grid = (n // block_n,)
    kernel = functools.partial(
        _lif_kernel,
        decay=decay,
        v_th=v_th,
        v_reset=v_reset,
        refrac_steps=refrac_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((STATE_ROWS, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((STATE_ROWS, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((STATE_ROWS, n), jnp.float32),
        interpret=interpret,
    )(state, i_in)
