"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between the two across shape/parameter sweeps (see
``python/tests/test_kernel.py``). The references are deliberately written
in the most obvious jnp style — no tiling, no tricks.
"""

import jax.numpy as jnp

STATE_ROWS = 3


def lif_step_ref(state, i_in, *, decay, v_th, v_reset, refrac_steps):
    """Reference LIF update (see kernels/lif_step.py for semantics)."""
    v = state[0]
    r = state[1]
    active = r <= 0.0
    v_new = jnp.where(active, v * decay + i_in * (1.0 - decay), v)
    spike = jnp.logical_and(v_new >= v_th, active)
    v_out = jnp.where(spike, v_reset, v_new)
    r_out = jnp.where(spike, jnp.float32(refrac_steps), jnp.maximum(r - 1.0, 0.0))
    return jnp.stack([v_out, r_out, spike.astype(jnp.float32)])


def synapse_input_ref(w, s):
    """Reference synaptic accumulation: plain matvec."""
    return w @ s


def shard_step_ref(state, spikes_in, w, *, i_ext, decay, v_th, v_reset, refrac_steps):
    """Reference full shard step: synapse + external drive + LIF."""
    i_total = synapse_input_ref(w, spikes_in) + i_ext
    return lif_step_ref(
        state,
        i_total,
        decay=decay,
        v_th=v_th,
        v_reset=v_reset,
        refrac_steps=refrac_steps,
    )
