"""AOT lowering: JAX shard step → HLO text artifacts for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
xla crate's bundled XLA (xla_extension 0.5.1) rejects jax ≥ 0.5 protos
with 64-bit instruction ids, while the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a pair:

    <name>.hlo.txt   — the lowered module
    <name>.json      — manifest: shapes, tilings, LIF parameters

Usage:
    python -m compile.aot --local 256 --global 1024 --out ../artifacts
    python -m compile.aot --suite --out ../artifacts      # default set
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import LifParams, make_shard_step

# Default artifact suite: (name, n_local, n_global, block_n, block_m, block_k)
#
# Perf note (EXPERIMENTS.md §Perf): on the CPU-PJRT path the Pallas
# interpret-mode grid loop dominates step time (~21x at 256x1024), so the
# CPU artifacts use whole-shard tiles (grid 1x1). On a real TPU the tiles
# must fit VMEM: the DESIGN.md §Hardware-Adaptation schedule is
# block_m=256 x block_k=512 (0.5 MiB weight tiles, double-buffered), which
# is what the hypothesis sweeps in python/tests keep verified.
SUITE = [
    ("shard_256x1024", 256, 1024, 256, 256, 1024),
    ("shard_1024x4096", 1024, 4096, 1024, 1024, 4096),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_shard(n_local: int, n_global: int, params: LifParams, *,
                block_n: int, block_m: int, block_k: int) -> str:
    """Lower one shard-step function to HLO text."""
    step = make_shard_step(params, block_n=block_n, block_m=block_m,
                           block_k=block_k, interpret=True)
    state = jax.ShapeDtypeStruct((3, n_local), jnp.float32)
    spikes = jax.ShapeDtypeStruct((n_global,), jnp.float32)
    w = jax.ShapeDtypeStruct((n_local, n_global), jnp.float32)
    lowered = jax.jit(step).lower(state, spikes, w)
    return to_hlo_text(lowered)


def build_artifact(outdir: str, name: str, n_local: int, n_global: int,
                   params: LifParams, *, block_n: int, block_m: int,
                   block_k: int) -> dict:
    """Lower, write the .hlo.txt + manifest, return the manifest dict."""
    hlo = lower_shard(n_local, n_global, params, block_n=block_n,
                      block_m=block_m, block_k=block_k)
    os.makedirs(outdir, exist_ok=True)
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    manifest = {
        "name": name,
        "n_local": n_local,
        "n_global": n_global,
        "inputs": ["state[3,n_local]", "spikes_in[n_global]", "w[n_local,n_global]"],
        "output": "state[3,n_local]",
        "dtype": "f32",
        "block_n": block_n,
        "block_m": block_m,
        "block_k": block_k,
        "params": params.to_dict(),
        "hlo_sha256": hashlib.sha256(hlo.encode()).hexdigest(),
        "hlo_bytes": len(hlo),
        "jax_version": jax.__version__,
    }
    with open(os.path.join(outdir, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--suite", action="store_true", help="build the default artifact suite")
    ap.add_argument("--local", type=int, default=256, dest="n_local")
    ap.add_argument("--global", type=int, default=1024, dest="n_global")
    ap.add_argument("--block-n", type=int, default=256)
    ap.add_argument("--block-m", type=int, default=256)
    ap.add_argument("--block-k", type=int, default=512)
    ap.add_argument("--name", default=None)
    args = ap.parse_args()

    params = LifParams()
    if args.suite:
        for (name, n_local, n_global, bn, bm, bk) in SUITE:
            m = build_artifact(args.out, name, n_local, n_global, params,
                               block_n=bn, block_m=bm, block_k=bk)
            print(f"wrote {name}: {m['hlo_bytes']} chars, sha={m['hlo_sha256'][:12]}")
        # stamp file lets `make` skip rebuilds when inputs are unchanged
        with open(os.path.join(args.out, ".stamp"), "w") as f:
            f.write("ok\n")
    else:
        name = args.name or f"shard_{args.n_local}x{args.n_global}"
        m = build_artifact(args.out, name, args.n_local, args.n_global, params,
                           block_n=args.block_n, block_m=args.block_m,
                           block_k=args.block_k)
        print(f"wrote {name}: {m['hlo_bytes']} chars, sha={m['hlo_sha256'][:12]}")


if __name__ == "__main__":
    main()
