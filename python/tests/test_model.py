"""L2 model correctness: shard step composition vs oracle + dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels.ref import shard_step_ref
from compile.model import LifParams, make_shard_step


def make_inputs(seed, n_local, n_global):
    rng = np.random.default_rng(seed)
    state = jnp.stack([
        jnp.asarray(rng.uniform(-0.5, 0.9, n_local).astype(np.float32)),
        jnp.zeros(n_local, dtype=jnp.float32),
        jnp.zeros(n_local, dtype=jnp.float32),
    ])
    spikes = jnp.asarray((rng.random(n_global) < 0.05).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.2, (n_local, n_global)).astype(np.float32))
    return state, spikes, w


@pytest.mark.parametrize("n_local,n_global", [(256, 1024), (512, 512)])
def test_step_matches_ref(n_local, n_global):
    params = LifParams()
    step = make_shard_step(params, block_n=256, block_m=256, block_k=512)
    state, spikes, w = make_inputs(5, n_local, n_global)
    got = step(state, spikes, w)
    want = shard_step_ref(state, spikes, w, **params.to_dict())
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_step_under_jit_matches_eager():
    params = LifParams()
    step = make_shard_step(params, block_n=256, block_m=256, block_k=512)
    state, spikes, w = make_inputs(9, 256, 1024)
    eager = step(state, spikes, w)
    jitted = jax.jit(step)(state, spikes, w)
    np.testing.assert_allclose(eager, jitted, rtol=1e-6, atol=1e-6)


def test_multi_step_trajectory_spikes():
    # with constant suprathreshold drive, neurons fire periodically with
    # period ≈ time-to-threshold + refractory
    params = LifParams(decay=0.9, v_th=1.0, v_reset=0.0, refrac_steps=5.0, i_ext=2.0)
    n = 256
    step = make_shard_step(params, block_n=256, block_m=256, block_k=512)
    state = jnp.zeros((3, n), dtype=jnp.float32)
    spikes_in = jnp.zeros(512, dtype=jnp.float32)
    w = jnp.zeros((n, 512), dtype=jnp.float32)
    total_spikes = 0.0
    for _ in range(50):
        state = step(state, spikes_in, w)
        total_spikes += float(state[2].sum())
    assert total_spikes > 0, "constant drive must make neurons fire"
    # every neuron fires the same (uniform network)
    assert total_spikes % n == 0


def test_recurrent_inhibition_suppresses():
    # strong self-inhibition: after the first volley, firing should drop
    params = LifParams(decay=0.9, refrac_steps=0.0, i_ext=1.5)
    n = 256
    step = make_shard_step(params, block_n=256, block_m=256, block_k=256)
    w_inhib = -50.0 * jnp.ones((n, n), dtype=jnp.float32) / n
    state = jnp.zeros((3, n), dtype=jnp.float32)
    rates_inhib = []
    s_in = jnp.zeros(n, dtype=jnp.float32)
    for _ in range(40):
        state = step(state, s_in, w_inhib)
        s_in = state[2]  # feed spikes back (single closed shard)
        rates_inhib.append(float(state[2].mean()))
    # compare against the unconnected control
    w_zero = jnp.zeros((n, n), dtype=jnp.float32)
    state = jnp.zeros((3, n), dtype=jnp.float32)
    s_in = jnp.zeros(n, dtype=jnp.float32)
    rates_free = []
    for _ in range(40):
        state = step(state, s_in, w_zero)
        s_in = state[2]
        rates_free.append(float(state[2].mean()))
    assert sum(rates_inhib) < sum(rates_free), "inhibition must reduce firing"


def test_params_recorded_roundtrip():
    p = LifParams(decay=0.5, v_th=1.25, v_reset=-0.25, refrac_steps=7.0, i_ext=0.1)
    d = p.to_dict()
    assert d["decay"] == 0.5
    assert d["refrac_steps"] == 7.0
    p2 = LifParams(**d)
    assert p2 == p
