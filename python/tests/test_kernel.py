"""L1 kernel correctness: Pallas vs pure-jnp oracle (the core signal)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lif_step import lif_step
from compile.kernels.ref import lif_step_ref, synapse_input_ref
from compile.kernels.synapse import synapse_input

DEFAULTS = dict(decay=0.99, v_th=1.0, v_reset=0.0, refrac_steps=20.0)


def rand_state(rng, n):
    v = rng.uniform(-1.0, 1.5, size=n).astype(np.float32)
    r = rng.integers(0, 4, size=n).astype(np.float32)
    s = rng.integers(0, 2, size=n).astype(np.float32)
    return jnp.stack([jnp.asarray(v), jnp.asarray(r), jnp.asarray(s)])


# ---------------------------------------------------------------- LIF kernel

@pytest.mark.parametrize("n,block_n", [(64, 64), (256, 64), (512, 512), (1024, 256)])
def test_lif_matches_ref_shapes(n, block_n):
    rng = np.random.default_rng(42 + n)
    state = rand_state(rng, n)
    i_in = jnp.asarray(rng.normal(0.5, 0.5, size=n).astype(np.float32))
    got = lif_step(state, i_in, block_n=block_n, **DEFAULTS)
    want = lif_step_ref(state, i_in, **DEFAULTS)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_lif_spikes_and_resets():
    # v crosses threshold -> spike, reset, refractory set
    state = jnp.asarray([[0.99, 0.2, -0.5, 1.4], [0.0, 0.0, 2.0, 0.0],
                         [0.0, 0.0, 0.0, 0.0]], dtype=jnp.float32)
    i_in = jnp.asarray([5.0, 0.0, 5.0, 0.0], dtype=jnp.float32)
    out = lif_step(state, i_in, block_n=4, **DEFAULTS)
    # neuron 0: 0.99*0.99 + 5*0.01 = 1.0301 >= 1.0 -> spike
    assert out[2, 0] == 1.0
    assert out[0, 0] == 0.0  # reset
    assert out[1, 0] == 20.0  # refractory
    # neuron 1: no spike
    assert out[2, 1] == 0.0
    # neuron 2: refractory -> frozen, no spike despite drive
    assert out[2, 2] == 0.0
    assert out[0, 2] == pytest.approx(-0.5)
    assert out[1, 2] == 1.0  # counts down
    # neuron 3: already above threshold with no drive: 1.4*0.99 = 1.386 >= 1
    assert out[2, 3] == 1.0


def test_lif_refractory_counts_down_to_active():
    state = jnp.asarray([[0.0], [1.0], [0.0]], dtype=jnp.float32)
    # decay=0.99 weights the input by 0.01: 200*0.01 = 2.0 ≥ v_th in one step
    i_in = jnp.asarray([200.0], dtype=jnp.float32)
    out1 = lif_step(state, i_in, block_n=1, **DEFAULTS)
    assert out1[1, 0] == 0.0 and out1[2, 0] == 0.0
    out2 = lif_step(out1, i_in, block_n=1, **DEFAULTS)
    assert out2[2, 0] == 1.0  # active again and driven hard -> spikes


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
    decay=st.floats(0.5, 0.999),
    v_th=st.floats(0.5, 2.0),
    refrac=st.integers(0, 30),
)
def test_lif_hypothesis_sweep(n_blocks, block, seed, decay, v_th, refrac):
    n = n_blocks * block
    rng = np.random.default_rng(seed)
    state = rand_state(rng, n)
    i_in = jnp.asarray(rng.normal(0.0, 1.0, size=n).astype(np.float32))
    kw = dict(decay=decay, v_th=v_th, v_reset=0.0, refrac_steps=float(refrac))
    got = lif_step(state, i_in, block_n=block, **kw)
    want = lif_step_ref(state, i_in, **kw)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_lif_rejects_bad_block():
    rng = np.random.default_rng(0)
    state = rand_state(rng, 100)
    i_in = jnp.zeros(100, dtype=jnp.float32)
    with pytest.raises(AssertionError):
        lif_step(state, i_in, block_n=64, **DEFAULTS)


# ------------------------------------------------------------ synapse kernel

@pytest.mark.parametrize(
    "n_local,n_global,bm,bk",
    [(64, 128, 64, 128), (256, 512, 64, 128), (128, 1024, 128, 512), (512, 512, 256, 512)],
)
def test_synapse_matches_ref_shapes(n_local, n_global, bm, bk):
    rng = np.random.default_rng(7 + n_local)
    w = jnp.asarray(rng.normal(0, 0.1, size=(n_local, n_global)).astype(np.float32))
    s = jnp.asarray((rng.random(n_global) < 0.1).astype(np.float32))
    got = synapse_input(w, s, block_m=bm, block_k=bk)
    want = synapse_input_ref(w, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    bm=st.sampled_from([16, 64]),
    bk=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
    density=st.floats(0.0, 1.0),
)
def test_synapse_hypothesis_sweep(mi, ki, bm, bk, seed, density):
    n_local, n_global = mi * bm, ki * bk
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1.0, size=(n_local, n_global)).astype(np.float32))
    s = jnp.asarray((rng.random(n_global) < density).astype(np.float32))
    got = synapse_input(w, s, block_m=bm, block_k=bk)
    want = synapse_input_ref(w, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_synapse_zero_spikes_zero_current():
    w = jnp.ones((64, 128), dtype=jnp.float32)
    s = jnp.zeros(128, dtype=jnp.float32)
    out = synapse_input(w, s, block_m=64, block_k=128)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(64, dtype=np.float32))


def test_synapse_counts_supported():
    # spike *counts* > 1 (multiple source steps batched) scale linearly
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(0, 1, size=(64, 128)).astype(np.float32))
    s1 = jnp.asarray((rng.random(128) < 0.2).astype(np.float32))
    got1 = synapse_input(w, s1, block_m=64, block_k=128)
    got3 = synapse_input(w, 3.0 * s1, block_m=64, block_k=128)
    np.testing.assert_allclose(3.0 * np.asarray(got1), got3, rtol=1e-5, atol=1e-5)


def test_kernels_jit_compatible():
    # kernels must lower inside jit (the AOT path requires it)
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.1, size=(64, 128)).astype(np.float32))
    s = jnp.asarray((rng.random(128) < 0.1).astype(np.float32))
    f = jax.jit(lambda w, s: synapse_input(w, s, block_m=64, block_k=128))
    np.testing.assert_allclose(f(w, s), synapse_input_ref(w, s), rtol=1e-4, atol=1e-5)
