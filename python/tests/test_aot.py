"""AOT path: HLO text artifacts are produced, parseable and deterministic."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build_artifact, lower_shard
from compile.model import LifParams


def test_lower_produces_hlo_text():
    hlo = lower_shard(64, 128, LifParams(), block_n=64, block_m=64, block_k=128)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # inputs appear as parameters
    assert "parameter(0)" in hlo
    assert "parameter(1)" in hlo
    assert "parameter(2)" in hlo


def test_lowering_is_deterministic():
    kw = dict(block_n=64, block_m=64, block_k=128)
    a = lower_shard(64, 128, LifParams(), **kw)
    b = lower_shard(64, 128, LifParams(), **kw)
    assert a == b


def test_artifact_manifest(tmp_path):
    m = build_artifact(str(tmp_path), "t", 64, 128, LifParams(),
                       block_n=64, block_m=64, block_k=128)
    hlo_path = tmp_path / "t.hlo.txt"
    man_path = tmp_path / "t.json"
    assert hlo_path.exists() and man_path.exists()
    with open(man_path) as f:
        j = json.load(f)
    assert j == m
    assert j["n_local"] == 64
    assert j["n_global"] == 128
    assert j["dtype"] == "f32"
    assert j["params"]["v_th"] == 1.0
    assert j["hlo_bytes"] == os.path.getsize(hlo_path)


def test_hlo_reloads_and_executes_like_python():
    """Round-trip: lowered HLO, recompiled via xla_client, must match the
    eager python step — the same check the rust runtime test performs."""
    from jax._src.lib import xla_client as xc
    from compile.model import make_shard_step

    params = LifParams()
    n_local, n_global = 64, 128
    hlo = lower_shard(n_local, n_global, params, block_n=64, block_m=64, block_k=128)

    # parse text back and run through the local CPU client
    client = xc._xla.get_tfrt_cpu_client(asynchronous=False)
    comp = xc._xla.parse_hlo_module_as_computation(hlo) if hasattr(
        xc._xla, "parse_hlo_module_as_computation") else None
    if comp is None:
        pytest.skip("no HLO text parser exposed in this jaxlib")
    exe = client.compile(comp.as_serialized_hlo_module_proto())

    rng = np.random.default_rng(0)
    state = np.stack([
        rng.uniform(-0.5, 0.9, n_local).astype(np.float32),
        np.zeros(n_local, dtype=np.float32),
        np.zeros(n_local, dtype=np.float32),
    ])
    spikes = (rng.random(n_global) < 0.1).astype(np.float32)
    w = rng.normal(0, 0.2, (n_local, n_global)).astype(np.float32)

    out = exe.execute([client.buffer_from_pyval(x) for x in (state, spikes, w)])
    got = np.asarray(out[0])
    step = make_shard_step(params, block_n=64, block_m=64, block_k=128)
    want = np.asarray(step(jnp.asarray(state), jnp.asarray(spikes), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
