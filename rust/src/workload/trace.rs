//! Spike trace record / replay.
//!
//! A [`Trace`] is a time-sorted list of HICANN events. Traces can be saved
//! to and loaded from JSON (regression fixtures, cross-run comparisons)
//! and replayed into an FPGA actor with exact timing via [`TraceReplay`].

use crate::fpga::event::SpikeEvent;
use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};
use crate::util::json::Json;

/// A recorded spike trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// (emission time, event), sorted by time.
    pub events: Vec<(Time, SpikeEvent)>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { events: Vec::new() }
    }

    /// Append an event (must be ≥ the last timestamp).
    pub fn push(&mut self, at: Time, ev: SpikeEvent) {
        if let Some((last, _)) = self.events.last() {
            assert!(at >= *last, "trace must be appended in time order");
        }
        self.events.push((at, ev));
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn duration(&self) -> Time {
        self.events.last().map(|(t, _)| *t).unwrap_or(Time::ZERO)
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        let mut rows = Json::arr();
        for (t, ev) in &self.events {
            rows.push(
                Json::obj()
                    .set("t_ps", t.ps())
                    .set("hicann", ev.hicann as u64)
                    .set("pulse", ev.pulse_addr as u64)
                    .set("ts", ev.timestamp as u64),
            );
        }
        Json::obj().set("version", 1u64).set("events", rows)
    }

    /// Parse from JSON (inverse of [`Trace::to_json`]).
    pub fn from_json(j: &Json) -> Result<Trace, String> {
        let rows = j
            .get("events")
            .and_then(Json::as_arr)
            .ok_or("missing 'events' array")?;
        let mut trace = Trace::new();
        for r in rows {
            let t = Time::from_ps(r.get("t_ps").and_then(Json::as_u64).ok_or("bad t_ps")?);
            let ev = SpikeEvent::new(
                r.get("hicann").and_then(Json::as_u64).ok_or("bad hicann")? as u8,
                r.get("pulse").and_then(Json::as_u64).ok_or("bad pulse")? as u16,
                r.get("ts").and_then(Json::as_u64).ok_or("bad ts")? as u16,
            );
            trace.push(t, ev);
        }
        Ok(trace)
    }

    /// Write to a file (pretty JSON).
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> std::io::Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
        Trace::from_json(&j)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// Actor that replays a trace into an FPGA with exact timing. Events are
/// scheduled lazily (one timer at a time) so huge traces do not flood the
/// event queue.
pub struct TraceReplay {
    trace: Trace,
    fpga: ActorId,
    cursor: usize,
    pub replayed: u64,
}

impl TraceReplay {
    pub fn new(trace: Trace, fpga: ActorId) -> Self {
        TraceReplay {
            trace,
            fpga,
            cursor: 0,
            replayed: 0,
        }
    }

    fn emit_due(&mut self, ctx: &mut Ctx<'_, Msg>) {
        // emit every event due now, then schedule the next wake-up
        while self.cursor < self.trace.events.len() {
            let (at, ev) = self.trace.events[self.cursor];
            if at > ctx.now() {
                ctx.send_at(ctx.self_id(), at, Msg::Timer(0));
                return;
            }
            ctx.send(self.fpga, Time::ZERO, Msg::HicannEvent(ev));
            self.replayed += 1;
            self.cursor += 1;
        }
    }
}

impl Actor<Msg> for TraceReplay {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(_) => self.emit_due(ctx),
            other => panic!("trace replay: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        "trace-replay".to_string()
    }

    /// Rides with the FPGA it replays into (zero-latency events).
    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::With(self.fpga)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sim;

    fn sample_trace() -> Trace {
        let mut t = Trace::new();
        t.push(Time::from_ns(10), SpikeEvent::new(0, 1, 100));
        t.push(Time::from_ns(10), SpikeEvent::new(1, 2, 101));
        t.push(Time::from_ns(50), SpikeEvent::new(7, 4095, 0x7FFF));
        t
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_trace();
        let j = t.to_json();
        let t2 = Trace::from_json(&j).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample_trace();
        let path = std::env::temp_dir().join("bss_extoll_trace_test.json");
        t.save(&path).unwrap();
        let t2 = Trace::load(&path).unwrap();
        assert_eq!(t, t2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[should_panic(expected = "time order")]
    fn out_of_order_rejected() {
        let mut t = Trace::new();
        t.push(Time::from_ns(50), SpikeEvent::new(0, 1, 2));
        t.push(Time::from_ns(10), SpikeEvent::new(0, 1, 2));
    }

    struct FpgaStub {
        events: Vec<(Time, SpikeEvent)>,
    }

    impl Actor<Msg> for FpgaStub {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::HicannEvent(ev) = msg {
                self.events.push((ctx.now(), ev));
            }
        }
    }

    #[test]
    fn replay_preserves_timing() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let rep = sim.add(TraceReplay::new(sample_trace(), stub));
        sim.schedule(Time::ZERO, rep, Msg::Timer(0));
        sim.run_to_completion();
        let got = &sim.get::<FpgaStub>(stub).events;
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].0, Time::from_ns(10));
        assert_eq!(got[1].0, Time::from_ns(10));
        assert_eq!(got[2].0, Time::from_ns(50));
        assert_eq!(got[2].1.pulse_addr, 4095);
        assert_eq!(sim.get::<TraceReplay>(rep).replayed, 3);
    }

    #[test]
    fn empty_trace_replay_is_noop() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let rep = sim.add(TraceReplay::new(Trace::new(), stub));
        sim.schedule(Time::ZERO, rep, Msg::Timer(0));
        sim.run_to_completion();
        assert!(sim.get::<FpgaStub>(stub).events.is_empty());
    }
}
