//! Spike-traffic generator actors.
//!
//! Generators stand in for the HICANN chips: they emit [`SpikeEvent`]s to
//! an FPGA actor, respecting the per-link pacing of the 8 × 1 Gbit/s
//! HICANN links (paper §1) — i.e. at most one event per
//! [`HicannLinkConfig::event_spacing`] per link, ≈210 Mevent/s aggregate.
//!
//! [`PoissonGen`] draws exponential inter-event times (biologically
//! realistic spike trains); [`RegularGen`] emits at a fixed interval
//! (ceiling/saturation measurements); [`BurstGen`] emits Poisson-arriving
//! bursts of link-rate-paced events (synchronous-population regime that
//! stresses bucket renaming). Scenarios select between them via
//! [`GeneratorKind`] and [`spawn_generator`].

use crate::fpga::event::{systime_of, SpikeEvent, TS_MASK};
use crate::fpga::hicann::{HicannLinkConfig, HICANNS_PER_FPGA};
use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Sim, Time};
use crate::util::rng::Rng;

/// Timer tag base: per-HICANN-link generator wake-up (tag = base + link).
pub const TIMER_GEN_BASE: u32 = 100;

/// Shared generator configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Pulse addresses to draw from, per HICANN link (sources must match
    /// the routes programmed into the FPGA's TX lookup table).
    pub sources: Vec<(u8, u16)>,
    /// Aggregate event rate across all 8 links, events/s.
    pub rate_hz: f64,
    /// Deadline offset added to the emission time, in systime units.
    pub deadline_offset: u16,
    /// Stop generating at this simulation time (None = run forever).
    pub until: Option<Time>,
    /// HICANN link pacing parameters.
    pub link: HicannLinkConfig,
    /// Events per burst ([`BurstGen`] only; others ignore it).
    pub burst_len: u32,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            sources: vec![(0, 0)],
            rate_hz: 1e6,
            deadline_offset: 2000,
            until: None,
            link: HicannLinkConfig::default(),
            burst_len: 64,
        }
    }
}

/// Which traffic generator a scenario spawns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    /// Exponential inter-event times (default).
    Poisson,
    /// Fixed inter-event interval.
    Regular,
    /// Poisson-arriving bursts of back-to-back events.
    Burst,
}

impl GeneratorKind {
    pub fn parse(s: &str) -> Option<GeneratorKind> {
        match s {
            "poisson" => Some(GeneratorKind::Poisson),
            "regular" => Some(GeneratorKind::Regular),
            "burst" => Some(GeneratorKind::Burst),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            GeneratorKind::Poisson => "poisson",
            GeneratorKind::Regular => "regular",
            GeneratorKind::Burst => "burst",
        }
    }
}

/// Spawn a generator of `kind` feeding `fpga` and return its actor id.
/// The caller still schedules the kick-off `Msg::Timer(0)`.
pub fn spawn_generator(
    sim: &mut Sim<Msg>,
    kind: GeneratorKind,
    cfg: GenConfig,
    fpga: ActorId,
    seed: u64,
) -> ActorId {
    match kind {
        GeneratorKind::Poisson => sim.add(PoissonGen::new(cfg, fpga, seed)),
        GeneratorKind::Regular => sim.add(RegularGen::new(cfg, fpga)),
        GeneratorKind::Burst => sim.add(BurstGen::new(cfg, fpga, seed)),
    }
}

/// Sum of `stats.generated` over every generator actor in the simulation,
/// regardless of kind (post-run metric collection).
pub fn total_generated(sim: &Sim<Msg>) -> u64 {
    let mut total = 0;
    for id in 0..sim.n_actors() {
        if let Some(g) = sim.try_get::<PoissonGen>(id) {
            total += g.stats.generated;
        } else if let Some(g) = sim.try_get::<RegularGen>(id) {
            total += g.stats.generated;
        } else if let Some(g) = sim.try_get::<BurstGen>(id) {
            total += g.stats.generated;
        }
    }
    total
}

/// Generator statistics.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub generated: u64,
    /// Events delayed by link pacing (wanted to fire earlier).
    pub paced: u64,
}

/// Poisson spike generator: exponential inter-arrival per HICANN link.
pub struct PoissonGen {
    pub cfg: GenConfig,
    fpga: ActorId,
    rng: Rng,
    /// Sources grouped by link for fast draw.
    by_link: [Vec<u16>; HICANNS_PER_FPGA],
    /// Earliest next allowed emission per link (pacing).
    link_free: [Time; HICANNS_PER_FPGA],
    pub stats: GenStats,
}

impl PoissonGen {
    pub fn new(cfg: GenConfig, fpga: ActorId, seed: u64) -> Self {
        let mut by_link: [Vec<u16>; HICANNS_PER_FPGA] = Default::default();
        for &(h, p) in &cfg.sources {
            by_link[h as usize].push(p);
        }
        PoissonGen {
            cfg,
            fpga,
            rng: Rng::new(seed),
            by_link,
            link_free: [Time::ZERO; HICANNS_PER_FPGA],
            stats: GenStats::default(),
        }
    }

    fn active_links(&self) -> Vec<u8> {
        (0..HICANNS_PER_FPGA as u8)
            .filter(|&h| !self.by_link[h as usize].is_empty())
            .collect()
    }

    /// Per-link rate (aggregate split over active links).
    fn link_rate(&self) -> f64 {
        let n = self.active_links().len().max(1);
        self.cfg.rate_hz / n as f64
    }

    fn schedule_next(&mut self, link: u8, ctx: &mut Ctx<'_, Msg>) {
        let gap = self.rng.exponential(self.link_rate());
        let mut at = ctx.now() + Time::from_secs_f64(gap);
        let free = self.link_free[link as usize];
        if at < free {
            at = free;
            self.stats.paced += 1;
        }
        if let Some(until) = self.cfg.until {
            if at > until {
                return;
            }
        }
        ctx.send_at(
            ctx.self_id(),
            at,
            Msg::Timer(TIMER_GEN_BASE + link as u32),
        );
    }

    fn emit(&mut self, link: u8, ctx: &mut Ctx<'_, Msg>) {
        let pulses = &self.by_link[link as usize];
        let pulse = pulses[self.rng.index(pulses.len())];
        let ts = (systime_of(ctx.now()) as u32 + self.cfg.deadline_offset as u32) as u16 & TS_MASK;
        let ev = SpikeEvent::new(link, pulse, ts);
        self.link_free[link as usize] = ctx.now() + self.cfg.link.event_spacing();
        self.stats.generated += 1;
        ctx.send(self.fpga, Time::ZERO, Msg::HicannEvent(ev));
    }
}

impl Actor<Msg> for PoissonGen {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(t) if t >= TIMER_GEN_BASE => {
                let link = (t - TIMER_GEN_BASE) as u8;
                self.emit(link, ctx);
                self.schedule_next(link, ctx);
            }
            Msg::Timer(0) => {
                // kick-off: schedule all active links
                for link in self.active_links() {
                    self.schedule_next(link, ctx);
                }
            }
            other => panic!("poisson gen: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        "poisson-gen".to_string()
    }

    /// Rides with the FPGA it feeds (zero-latency `HicannEvent`s).
    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::With(self.fpga)
    }
}

/// Deterministic fixed-interval generator (saturation/ceiling workloads).
pub struct RegularGen {
    pub cfg: GenConfig,
    fpga: ActorId,
    by_link: [Vec<u16>; HICANNS_PER_FPGA],
    /// Round-robin cursor per link.
    cursor: [usize; HICANNS_PER_FPGA],
    pub stats: GenStats,
}

impl RegularGen {
    pub fn new(cfg: GenConfig, fpga: ActorId) -> Self {
        let mut by_link: [Vec<u16>; HICANNS_PER_FPGA] = Default::default();
        for &(h, p) in &cfg.sources {
            by_link[h as usize].push(p);
        }
        RegularGen {
            cfg,
            fpga,
            by_link,
            cursor: [0; HICANNS_PER_FPGA],
            stats: GenStats::default(),
        }
    }

    fn active_links(&self) -> Vec<u8> {
        (0..HICANNS_PER_FPGA as u8)
            .filter(|&h| !self.by_link[h as usize].is_empty())
            .collect()
    }

    fn interval(&self) -> Time {
        let n = self.active_links().len().max(1);
        let per_link = self.cfg.rate_hz / n as f64;
        let raw = Time::from_secs_f64(1.0 / per_link);
        raw.max(self.cfg.link.event_spacing())
    }
}

impl Actor<Msg> for RegularGen {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(0) => {
                for link in self.active_links() {
                    ctx.send_self(Time::ZERO, Msg::Timer(TIMER_GEN_BASE + link as u32));
                }
            }
            Msg::Timer(t) if t >= TIMER_GEN_BASE => {
                let link = (t - TIMER_GEN_BASE) as usize;
                let pulses = &self.by_link[link];
                let pulse = pulses[self.cursor[link] % pulses.len()];
                self.cursor[link] += 1;
                let ts = (systime_of(ctx.now()) as u32 + self.cfg.deadline_offset as u32) as u16
                    & TS_MASK;
                self.stats.generated += 1;
                ctx.send(
                    self.fpga,
                    Time::ZERO,
                    Msg::HicannEvent(SpikeEvent::new(link as u8, pulse, ts)),
                );
                let next = ctx.now() + self.interval();
                if self.cfg.until.map(|u| next <= u).unwrap_or(true) {
                    ctx.send_at(ctx.self_id(), next, Msg::Timer(TIMER_GEN_BASE + link as u32));
                }
            }
            other => panic!("regular gen: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        "regular-gen".to_string()
    }

    /// Rides with the FPGA it feeds (zero-latency `HicannEvent`s).
    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::With(self.fpga)
    }
}

/// Bursty generator: bursts arrive per link as a Poisson process; inside a
/// burst, `burst_len` events fire back-to-back at the HICANN link rate
/// (one per [`HicannLinkConfig::event_spacing`]). Models synchronized
/// population activity — the regime in which aggregation buckets fill
/// fastest and renaming/eviction is stressed.
pub struct BurstGen {
    pub cfg: GenConfig,
    fpga: ActorId,
    rng: Rng,
    /// Sources grouped by link for fast draw.
    by_link: [Vec<u16>; HICANNS_PER_FPGA],
    /// Events left in the current burst, per link (0 = between bursts).
    remaining: [u32; HICANNS_PER_FPGA],
    pub stats: GenStats,
    /// Bursts started so far.
    pub bursts: u64,
}

impl BurstGen {
    pub fn new(cfg: GenConfig, fpga: ActorId, seed: u64) -> Self {
        let mut by_link: [Vec<u16>; HICANNS_PER_FPGA] = Default::default();
        for &(h, p) in &cfg.sources {
            by_link[h as usize].push(p);
        }
        BurstGen {
            cfg,
            fpga,
            rng: Rng::new(seed),
            by_link,
            remaining: [0; HICANNS_PER_FPGA],
            stats: GenStats::default(),
            bursts: 0,
        }
    }

    fn active_links(&self) -> Vec<u8> {
        (0..HICANNS_PER_FPGA as u8)
            .filter(|&h| !self.by_link[h as usize].is_empty())
            .collect()
    }

    /// Per-link burst arrival rate so the mean event rate over all active
    /// links approximates `cfg.rate_hz`.
    fn burst_rate(&self) -> f64 {
        let n = self.active_links().len().max(1);
        self.cfg.rate_hz / (n as f64 * self.cfg.burst_len.max(1) as f64)
    }

    fn schedule(&mut self, link: u8, at: Time, ctx: &mut Ctx<'_, Msg>) {
        if let Some(until) = self.cfg.until {
            if at > until {
                self.remaining[link as usize] = 0;
                return;
            }
        }
        ctx.send_at(ctx.self_id(), at, Msg::Timer(TIMER_GEN_BASE + link as u32));
    }

    fn schedule_next_burst(&mut self, link: u8, ctx: &mut Ctx<'_, Msg>) {
        let gap = self.rng.exponential(self.burst_rate());
        let at = ctx.now() + Time::from_secs_f64(gap);
        self.remaining[link as usize] = self.cfg.burst_len.max(1);
        self.schedule(link, at, ctx);
    }

    fn emit(&mut self, link: u8, ctx: &mut Ctx<'_, Msg>) {
        let pulses = &self.by_link[link as usize];
        let pulse = pulses[self.rng.index(pulses.len())];
        let ts =
            (systime_of(ctx.now()) as u32 + self.cfg.deadline_offset as u32) as u16 & TS_MASK;
        self.stats.generated += 1;
        ctx.send(
            self.fpga,
            Time::ZERO,
            Msg::HicannEvent(SpikeEvent::new(link, pulse, ts)),
        );
    }
}

impl Actor<Msg> for BurstGen {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(0) => {
                // kick-off: schedule the first burst on every active link
                for link in self.active_links() {
                    self.schedule_next_burst(link, ctx);
                }
            }
            Msg::Timer(t) if t >= TIMER_GEN_BASE => {
                let link = (t - TIMER_GEN_BASE) as u8;
                if self.remaining[link as usize] == self.cfg.burst_len.max(1) {
                    self.bursts += 1;
                }
                self.emit(link, ctx);
                self.remaining[link as usize] -= 1;
                if self.remaining[link as usize] > 0 {
                    let at = ctx.now() + self.cfg.link.event_spacing();
                    self.schedule(link, at, ctx);
                } else {
                    self.schedule_next_burst(link, ctx);
                }
            }
            other => panic!("burst gen: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        "burst-gen".to_string()
    }

    /// Rides with the FPGA it feeds (zero-latency `HicannEvent`s).
    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::With(self.fpga)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sim;

    /// Counts HICANN events per link with timestamps.
    struct FpgaStub {
        events: Vec<(Time, SpikeEvent)>,
    }

    impl Actor<Msg> for FpgaStub {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::HicannEvent(ev) = msg {
                self.events.push((ctx.now(), ev));
            }
        }
    }

    fn sources_all_links(per_link: usize) -> Vec<(u8, u16)> {
        let mut v = Vec::new();
        for h in 0..8u8 {
            for p in 0..per_link as u16 {
                v.push((h, p));
            }
        }
        v
    }

    #[test]
    fn poisson_rate_is_close() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let cfg = GenConfig {
            sources: sources_all_links(4),
            rate_hz: 10e6,
            until: Some(Time::from_ms(10)),
            ..GenConfig::default()
        };
        let gen = sim.add(PoissonGen::new(cfg, stub, 42));
        sim.schedule(Time::ZERO, gen, Msg::Timer(0));
        sim.run_to_completion();
        let n = sim.get::<FpgaStub>(stub).events.len() as f64;
        let expect = 10e6 * 10e-3;
        assert!(
            (n - expect).abs() < expect * 0.05,
            "generated {n}, expected ≈{expect}"
        );
    }

    #[test]
    fn pacing_limits_link_rate() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        // one active link, demand 100 Mev/s ≫ 26.3 Mev/s link limit
        let cfg = GenConfig {
            sources: vec![(3, 1), (3, 2)],
            rate_hz: 100e6,
            until: Some(Time::from_ms(1)),
            ..GenConfig::default()
        };
        let gen = sim.add(PoissonGen::new(cfg.clone(), stub, 7));
        sim.schedule(Time::ZERO, gen, Msg::Timer(0));
        sim.run_to_completion();
        let events = &sim.get::<FpgaStub>(stub).events;
        // achieved rate must be capped by the link spacing
        let cap = (Time::from_ms(1).secs_f64() * cfg.link.max_rate()).ceil() as usize + 1;
        assert!(events.len() <= cap, "{} events exceeds link cap {cap}", events.len());
        // spacing between consecutive events on the link ≥ event_spacing
        for w in events.windows(2) {
            assert!(w[1].0 - w[0].0 >= cfg.link.event_spacing());
        }
        assert!(sim.get::<PoissonGen>(gen).stats.paced > 0);
    }

    #[test]
    fn regular_generator_exact_count() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let cfg = GenConfig {
            sources: sources_all_links(1),
            rate_hz: 8e6, // 1 Mev/s per link → 1 µs interval
            until: Some(Time::from_us(100)),
            ..GenConfig::default()
        };
        let gen = sim.add(RegularGen::new(cfg, stub));
        sim.schedule(Time::ZERO, gen, Msg::Timer(0));
        sim.run_to_completion();
        let events = &sim.get::<FpgaStub>(stub).events;
        // 8 links × (100 µs / 1 µs + 1 initial) = 808
        assert_eq!(events.len(), 808);
    }

    #[test]
    fn deadline_offsets_applied() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let cfg = GenConfig {
            sources: vec![(0, 9)],
            rate_hz: 1e6,
            deadline_offset: 555,
            until: Some(Time::from_us(50)),
            ..GenConfig::default()
        };
        let gen = sim.add(PoissonGen::new(cfg, stub, 3));
        sim.schedule(Time::ZERO, gen, Msg::Timer(0));
        sim.run_to_completion();
        for (at, ev) in &sim.get::<FpgaStub>(stub).events {
            let emitted_sys = systime_of(*at);
            let delta = crate::fpga::event::ts_delta(emitted_sys, ev.timestamp);
            assert!(delta == 555 || delta == 554 || delta == 556, "delta {delta}");
        }
    }

    #[test]
    fn burst_generator_is_bursty_and_rate_close() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let cfg = GenConfig {
            sources: sources_all_links(4),
            rate_hz: 10e6,
            burst_len: 32,
            until: Some(Time::from_ms(10)),
            ..GenConfig::default()
        };
        let spacing = cfg.link.event_spacing();
        let gen = sim.add(BurstGen::new(cfg, stub, 99));
        sim.schedule(Time::ZERO, gen, Msg::Timer(0));
        sim.run_to_completion();
        let g: &BurstGen = sim.get(gen);
        assert!(g.bursts > 10, "only {} bursts", g.bursts);
        let events = &sim.get::<FpgaStub>(stub).events;
        // mean rate within 25% of nominal (burst duration biases it low)
        let n = events.len() as f64;
        let expect = 10e6 * 10e-3;
        assert!(
            n > expect * 0.75 && n < expect * 1.25,
            "generated {n}, expected ≈{expect}"
        );
        // burstiness: a large fraction of same-link gaps equal the pacing
        let mut per_link: Vec<Vec<Time>> = vec![Vec::new(); 8];
        for (at, ev) in events {
            per_link[ev.hicann as usize].push(*at);
        }
        let mut paced = 0u64;
        let mut gaps = 0u64;
        for times in &per_link {
            for w in times.windows(2) {
                gaps += 1;
                if w[1] - w[0] == spacing {
                    paced += 1;
                }
            }
        }
        assert!(
            paced as f64 > gaps as f64 * 0.8,
            "{paced}/{gaps} gaps at link pacing — not bursty"
        );
    }

    #[test]
    fn burst_generator_deterministic() {
        let run = || {
            let mut sim = Sim::new();
            let stub = sim.add(FpgaStub { events: vec![] });
            let cfg = GenConfig {
                sources: sources_all_links(2),
                rate_hz: 5e6,
                burst_len: 16,
                until: Some(Time::from_ms(2)),
                ..GenConfig::default()
            };
            let gen = sim.add(BurstGen::new(cfg, stub, 7));
            sim.schedule(Time::ZERO, gen, Msg::Timer(0));
            sim.run_to_completion();
            sim.get::<FpgaStub>(stub).events.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn spawn_generator_dispatches_kinds() {
        assert_eq!(GeneratorKind::parse("poisson"), Some(GeneratorKind::Poisson));
        assert_eq!(GeneratorKind::parse("regular"), Some(GeneratorKind::Regular));
        assert_eq!(GeneratorKind::parse("burst"), Some(GeneratorKind::Burst));
        assert_eq!(GeneratorKind::parse("nope"), None);
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let cfg = GenConfig {
            sources: sources_all_links(1),
            rate_hz: 4e6,
            until: Some(Time::from_us(200)),
            ..GenConfig::default()
        };
        for kind in [
            GeneratorKind::Poisson,
            GeneratorKind::Regular,
            GeneratorKind::Burst,
        ] {
            let g = spawn_generator(&mut sim, kind, cfg.clone(), stub, 5);
            sim.schedule(Time::ZERO, g, Msg::Timer(0));
        }
        sim.run_to_completion();
        assert!(!sim.get::<FpgaStub>(stub).events.is_empty());
        assert!(total_generated(&sim) > 0);
        assert_eq!(
            total_generated(&sim),
            sim.get::<FpgaStub>(stub).events.len() as u64
        );
    }

    #[test]
    fn generator_distributes_over_sources() {
        let mut sim = Sim::new();
        let stub = sim.add(FpgaStub { events: vec![] });
        let cfg = GenConfig {
            sources: vec![(0, 1), (0, 2), (0, 3), (0, 4)],
            rate_hz: 5e6,
            until: Some(Time::from_ms(1)),
            ..GenConfig::default()
        };
        let gen = sim.add(PoissonGen::new(cfg, stub, 11));
        sim.schedule(Time::ZERO, gen, Msg::Timer(0));
        sim.run_to_completion();
        let mut counts = [0u32; 5];
        for (_, ev) in &sim.get::<FpgaStub>(stub).events {
            counts[ev.pulse_addr as usize] += 1;
        }
        for p in 1..=4 {
            assert!(counts[p] > 100, "pulse {p} undersampled: {}", counts[p]);
        }
    }
}
