//! Workload generation: Poisson/regular/burst spike traffic with HICANN
//! link pacing, trace record/replay, and the Potjans-Diesmann cortical
//! microcircuit (the paper's target multi-wafer network). Scenarios pick
//! their generator via [`generators::GeneratorKind`].

pub mod generators;
pub mod microcircuit;
pub mod trace;

pub use generators::{
    spawn_generator, total_generated, BurstGen, GenConfig, GenStats, GeneratorKind,
    PoissonGen, RegularGen, TIMER_GEN_BASE,
};
pub use microcircuit::{
    Microcircuit, Placement, CONN_PROB, FIRING_RATES_HZ, FULL_SCALE_NEURONS, POPULATIONS,
};
pub use trace::{Trace, TraceReplay};
