//! The cell-type-specific cortical microcircuit model of Potjans &
//! Diesmann (paper refs [8, 9]) — the first multi-wafer network the paper
//! targets ("One of the first multi-wafer networks will be a full scale
//! cortical microcircuit model").
//!
//! Provides: the 8-population architecture (sizes, connection
//! probabilities, stationary firing rates), arbitrary down-scaling, a
//! placement of neurons onto wafers/FPGAs/HICANNs, and the derived
//! FPGA-to-FPGA traffic matrix used by the network benchmarks. The LIF
//! dynamics themselves run in the AOT-compiled JAX/Pallas artifact (see
//! `python/compile/model.py` and [`crate::neuro`]).

use crate::extoll::analysis::Flow;
use crate::fpga::lookup::EndpointAddr;
use crate::wafer::system::System;

/// The eight populations of the microcircuit (layer 2/3 … 6, E/I).
pub const POPULATIONS: [(&str, u32); 8] = [
    ("L2/3E", 20_683),
    ("L2/3I", 5_834),
    ("L4E", 21_915),
    ("L4I", 5_479),
    ("L5E", 4_850),
    ("L5I", 1_065),
    ("L6E", 14_395),
    ("L6I", 2_948),
];

/// Total neurons at full scale.
pub const FULL_SCALE_NEURONS: u32 = 77_169;

/// Connection probabilities `CONN_PROB[target][source]` (Potjans &
/// Diesmann 2014, Table 5).
pub const CONN_PROB: [[f64; 8]; 8] = [
    [0.1009, 0.1689, 0.0437, 0.0818, 0.0323, 0.0000, 0.0076, 0.0000],
    [0.1346, 0.1371, 0.0316, 0.0515, 0.0755, 0.0000, 0.0042, 0.0000],
    [0.0077, 0.0059, 0.0497, 0.1350, 0.0067, 0.0003, 0.0453, 0.0000],
    [0.0691, 0.0029, 0.0794, 0.1597, 0.0033, 0.0000, 0.1057, 0.0000],
    [0.1004, 0.0622, 0.0505, 0.0057, 0.0831, 0.3726, 0.0204, 0.0000],
    [0.0548, 0.0269, 0.0257, 0.0022, 0.0600, 0.3158, 0.0086, 0.0000],
    [0.0156, 0.0066, 0.0211, 0.0166, 0.0572, 0.0197, 0.0396, 0.2252],
    [0.0364, 0.0010, 0.0034, 0.0005, 0.0277, 0.0080, 0.0658, 0.1443],
];

/// Stationary single-neuron firing rates (Hz) of the spontaneous state
/// (Potjans & Diesmann 2014, Fig. 6; NEST reference simulation).
pub const FIRING_RATES_HZ: [f64; 8] = [0.86, 2.80, 4.45, 5.93, 7.59, 8.64, 1.09, 7.88];

/// A (possibly down-scaled) instance of the microcircuit.
#[derive(Clone, Debug)]
pub struct Microcircuit {
    /// Scale factor applied to population sizes (1.0 = full 77k).
    pub scale: f64,
    /// Scaled population sizes.
    pub sizes: [u32; 8],
}

impl Microcircuit {
    pub fn new(scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0);
        let sizes = std::array::from_fn(|i| {
            ((POPULATIONS[i].1 as f64 * scale).round() as u32).max(1)
        });
        Microcircuit { scale, sizes }
    }

    pub fn total_neurons(&self) -> u32 {
        self.sizes.iter().sum()
    }

    /// Expected spikes/s emitted by population `p` in total.
    pub fn population_rate_hz(&self, p: usize) -> f64 {
        self.sizes[p] as f64 * FIRING_RATES_HZ[p]
    }

    /// Total expected spike rate of the whole circuit (events/s at the
    /// neuron level, before network multicast).
    pub fn total_rate_hz(&self) -> f64 {
        (0..8).map(|p| self.population_rate_hz(p)).sum()
    }

    /// Expected number of synapses (pairwise Bernoulli connectivity).
    pub fn expected_synapses(&self) -> f64 {
        let mut total = 0.0;
        for (t, row) in CONN_PROB.iter().enumerate() {
            for (s, &p) in row.iter().enumerate() {
                total += p * self.sizes[s] as f64 * self.sizes[t] as f64;
            }
        }
        total
    }
}

/// Assignment of the circuit onto the simulated machine: populations are
/// split evenly over all FPGAs (each FPGA hosts a slice of every
/// population — the layout that maximizes inter-FPGA traffic and thus
/// stresses the communication fabric, matching the paper's motivation).
#[derive(Clone, Debug)]
pub struct Placement {
    /// Per-FPGA slice sizes: `slice[f][p]` = neurons of population `p` on
    /// FPGA `f` (flat FPGA index over all wafers).
    pub slices: Vec<[u32; 8]>,
    /// Endpoints parallel to `slices`.
    pub endpoints: Vec<EndpointAddr>,
}

impl Placement {
    /// Distribute `mc` round-robin over the FPGAs of `sys`.
    pub fn spread(mc: &Microcircuit, sys: &System) -> Placement {
        let endpoints: Vec<EndpointAddr> = sys.fpgas().map(|(_, _, _, ep)| ep).collect();
        let n = endpoints.len();
        assert!(n > 0);
        let mut slices = vec![[0u32; 8]; n];
        for p in 0..8 {
            let base = mc.sizes[p] / n as u32;
            let rem = (mc.sizes[p] % n as u32) as usize;
            for (f, slice) in slices.iter_mut().enumerate() {
                slice[p] = base + u32::from(f < rem);
            }
        }
        Placement { slices, endpoints }
    }

    pub fn n_fpgas(&self) -> usize {
        self.slices.len()
    }

    /// Neurons hosted on FPGA `f`.
    pub fn neurons_on(&self, f: usize) -> u32 {
        self.slices[f].iter().sum()
    }

    /// Probability that a spike from population `s` has ≥1 target among
    /// the population slices on FPGA `f` — i.e. that the spike must be
    /// delivered to that FPGA at all (the GUID multicast granularity).
    pub fn delivery_prob(&self, s: usize, f: usize) -> f64 {
        let mut p_none = 1.0;
        for t in 0..8 {
            let n_targets = self.slices[f][t] as f64;
            let p_conn = CONN_PROB[t][s];
            if p_conn > 0.0 && n_targets > 0.0 {
                p_none *= (1.0 - p_conn).powf(n_targets);
            }
        }
        1.0 - p_none
    }

    /// Expected FPGA→FPGA event rates (events/s on the wire): every spike
    /// of a source slice is shipped once to each FPGA with ≥1 target.
    pub fn traffic_matrix(&self, mc: &Microcircuit) -> Vec<Vec<f64>> {
        let n = self.n_fpgas();
        // per-destination delivery probability per source population
        let deliver: Vec<[f64; 8]> = (0..n)
            .map(|f| std::array::from_fn(|s| self.delivery_prob(s, f)))
            .collect();
        let mut m = vec![vec![0.0; n]; n];
        for (src, row) in m.iter_mut().enumerate() {
            for s in 0..8 {
                // per-neuron firing rates are scale-invariant; slice sizes
                // already carry the down-scaling
                let src_rate = self.slices[src][s] as f64 * FIRING_RATES_HZ[s];
                for (dst, out) in row.iter_mut().enumerate() {
                    if dst == src {
                        continue; // intra-FPGA spikes do not cross the fabric
                    }
                    *out += src_rate * deliver[dst][s];
                }
            }
        }
        let _ = mc;
        m
    }

    /// Convert the traffic matrix into fabric-level flows (Gbit/s) between
    /// torus nodes, using `bits_per_event` for the wire footprint.
    ///
    /// `speedup`: BrainScaleS emulates neurons 10^3–10^4× faster than
    /// biology (the wafer's analog time constant), so wall-clock spike
    /// rates are the biological rates times this factor — this is what
    /// makes the interconnect bandwidth question non-trivial.
    pub fn flows_accelerated(
        &self,
        mc: &Microcircuit,
        bits_per_event: f64,
        speedup: f64,
    ) -> Vec<Flow> {
        let mut flows = self.flows(mc, bits_per_event);
        for f in &mut flows {
            f.gbps *= speedup;
        }
        flows
    }

    /// Biological-real-time flows (speedup 1).
    pub fn flows(&self, mc: &Microcircuit, bits_per_event: f64) -> Vec<Flow> {
        let m = self.traffic_matrix(mc);
        let mut flows = Vec::new();
        for (src, row) in m.iter().enumerate() {
            for (dst, &events_per_s) in row.iter().enumerate() {
                if events_per_s <= 0.0 {
                    continue;
                }
                let src_node = self.endpoints[src].node;
                let dst_node = self.endpoints[dst].node;
                if src_node == dst_node {
                    continue; // same torus node: concentrator-local
                }
                flows.push(Flow {
                    src: src_node,
                    dst: dst_node,
                    gbps: events_per_s * bits_per_event / 1e9,
                });
            }
        }
        flows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::sim::Sim;
    use crate::wafer::system::{System, SystemConfig};

    #[test]
    fn full_scale_sizes_match_paper() {
        let mc = Microcircuit::new(1.0);
        assert_eq!(mc.total_neurons(), FULL_SCALE_NEURONS);
        assert_eq!(mc.sizes[0], 20_683);
        assert_eq!(mc.sizes[7], 2_948);
    }

    #[test]
    fn scaling_preserves_proportions() {
        let mc = Microcircuit::new(0.1);
        assert!((mc.total_neurons() as f64 - 7717.0).abs() < 8.0);
        let ratio = mc.sizes[0] as f64 / mc.sizes[1] as f64;
        let full = 20_683.0 / 5_834.0;
        assert!((ratio - full).abs() < 0.05);
    }

    #[test]
    fn expected_synapses_order_of_magnitude() {
        // the paper's model has ≈0.3 billion synapses at full scale
        let mc = Microcircuit::new(1.0);
        let syn = mc.expected_synapses();
        assert!(
            (2.5e8..3.5e8).contains(&syn),
            "expected ≈3e8 synapses, got {syn:.3e}"
        );
    }

    #[test]
    fn total_rate_plausible() {
        // ≈77k neurons × ~3 Hz ≈ 2-3×10^5 events/s
        let mc = Microcircuit::new(1.0);
        let r = mc.total_rate_hz();
        assert!((1e5..1e6).contains(&r), "rate {r}");
    }

    fn sys_2x12() -> (Sim<crate::msg::Msg>, System) {
        let mut sim = Sim::new();
        let sys = System::build(
            &mut sim,
            SystemConfig {
                n_wafers: 2,
                torus: TorusSpec::new(4, 2, 2),
                fpgas_per_wafer: 12,
                concentrators_per_wafer: 4,
                ..SystemConfig::default()
            },
        );
        (sim, sys)
    }

    #[test]
    fn placement_conserves_neurons() {
        let (_sim, sys) = sys_2x12();
        let mc = Microcircuit::new(0.25);
        let pl = Placement::spread(&mc, &sys);
        assert_eq!(pl.n_fpgas(), 24);
        for p in 0..8 {
            let sum: u32 = pl.slices.iter().map(|s| s[p]).sum();
            assert_eq!(sum, mc.sizes[p], "population {p} lost neurons");
        }
    }

    #[test]
    fn delivery_prob_saturates_at_scale() {
        // with thousands of potential targets per FPGA, nearly every spike
        // must be delivered to nearly every FPGA — the regime that makes
        // aggregation worthwhile
        let (_sim, sys) = sys_2x12();
        let mc = Microcircuit::new(1.0);
        let pl = Placement::spread(&mc, &sys);
        let p = pl.delivery_prob(0, 5); // L2/3E spikes to some FPGA
        assert!(p > 0.99, "delivery prob {p}");
    }

    #[test]
    fn traffic_matrix_symmetric_under_symmetric_placement() {
        let (_sim, sys) = sys_2x12();
        let mc = Microcircuit::new(0.5);
        let pl = Placement::spread(&mc, &sys);
        let m = pl.traffic_matrix(&mc);
        // diag zero, off-diag positive and near-uniform
        for (i, row) in m.iter().enumerate() {
            assert_eq!(row[i], 0.0);
            for (j, &v) in row.iter().enumerate() {
                if i != j {
                    assert!(v > 0.0, "zero flow {i}->{j}");
                }
            }
        }
        let a = m[0][1];
        let b = m[5][9];
        assert!((a - b).abs() / a < 0.05, "flows {a} vs {b} differ");
    }

    #[test]
    fn flows_skip_same_node_pairs() {
        let (_sim, sys) = sys_2x12();
        let mc = Microcircuit::new(0.25);
        let pl = Placement::spread(&mc, &sys);
        let flows = pl.flows(&mc, 32.0);
        for f in &flows {
            assert_ne!(f.src, f.dst);
            assert!(f.gbps > 0.0);
        }
        // 24 FPGAs on 8 nodes: 3 per node; flows between distinct nodes only
        let n_pairs_distinct_nodes = flows.len();
        assert_eq!(n_pairs_distinct_nodes, 24 * 24 - 24 - 24 * 2 /* same-node pairs (3 per node → 2 others) */);
    }
}
