//! Partitioned conservative parallel DES (PDES) across torus domains.
//!
//! A [`Partition`] splits one built [`Sim`] into per-domain instances —
//! one domain per group of torus nodes, each owning its local actors and
//! event queue — and advances them on parallel worker threads under a
//! conservative synchronization protocol in the Chandy–Misra–Bryant
//! family. The safety bound is the windowed (global-minimum) special
//! case of CMB's per-neighbor rule: with every cross-domain link
//! guaranteeing at least `lookahead` of latency, a domain whose earliest
//! pending event is at `t_min_global` or later may execute everything
//! strictly below
//!
//! ```text
//! bound = min(domain clocks) + lookahead  =  t_min_global + lookahead
//! ```
//!
//! because any message another domain emits in the same window is sent at
//! `≥ t_min_global` and therefore arrives at `≥ bound`. Instead of
//! streaming null messages, domains run in lock-step windows on a spin
//! barrier: publish next-event times → leader computes the bound → all
//! domains execute their window in parallel → cross-domain messages are
//! exchanged through per-domain mailboxes → repeat. The lookahead comes
//! from the Extoll link model (cable + router pipeline latency; see
//! [`crate::extoll::network::pdes_lookahead`]).
//!
//! ## Determinism
//!
//! Domain count is a performance knob, not physics: reports are
//! byte-identical at any partitioning (gated by
//! `rust/tests/determinism_queue.rs`). Two properties make that true:
//!
//! 1. every event carries the partition-independent merge key of
//!    `sim/engine.rs` (source actor ‖ per-source send counter), so each
//!    domain's queue pops its local + injected events in exactly the
//!    relative order the single-`Sim` run would have, and
//! 2. the conservative bound guarantees a cross-domain message is always
//!    injected before the receiving domain reaches its timestamp, so no
//!    event is ever delivered "into the key-past".
//!
//! See `docs/ARCHITECTURE.md` for the full argument and the invariants.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::engine::{
    merge_key, ActorId, DomainCtx, EventQueue, Outgoing, Sim, SimParts, EXTERNAL_SRC,
};
use super::time::Time;

/// Sentinel bound value signalling "no work at or below `until` remains".
const STOP: u64 = u64::MAX;

/// A reusable sense-counting spin barrier for the window lock-step.
///
/// Windows are short (one lookahead of simulated time, typically tens of
/// events per domain), so parking on a futex every window would dominate;
/// workers spin briefly and fall back to `yield_now` so oversubscribed
/// hosts (more domains than cores) still make progress. A panicking
/// worker poisons the barrier, releasing every other worker with `false`
/// so the panic propagates instead of deadlocking the fleet.
struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    poisoned: AtomicBool,
}

impl SpinBarrier {
    fn new(n: usize) -> SpinBarrier {
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Wait for all `n` workers; returns false if the barrier was
    /// poisoned (some worker panicked) and the caller should bail out.
    fn wait(&self) -> bool {
        if self.poisoned.load(Ordering::Acquire) {
            return false;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Release);
            self.generation.fetch_add(1, Ordering::Release);
            !self.poisoned.load(Ordering::Acquire)
        } else {
            let mut spins = 0u32;
            loop {
                if self.generation.load(Ordering::Acquire) != gen {
                    return !self.poisoned.load(Ordering::Acquire);
                }
                // re-check inside the loop: a worker can capture the
                // post-poison generation (poison bumps it) and would
                // otherwise spin on a generation that never changes again
                if self.poisoned.load(Ordering::Acquire) {
                    return false;
                }
                spins += 1;
                if spins < 1 << 10 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }

    fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
        // release any worker currently spinning on the generation
        self.generation.fetch_add(1, Ordering::Release);
    }
}

/// Poisons the barrier if its worker unwinds, so sibling workers exit
/// their window loop instead of spinning forever.
struct PoisonOnPanic<'a>(&'a SpinBarrier);

impl Drop for PoisonOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// A simulation partitioned into conservatively synchronized domains.
///
/// Construct with [`Partition::split`] after the system is fully built,
/// drive with [`Partition::run_until`] / [`Partition::schedule`], then
/// [`Partition::into_sim`] reassembles a single [`Sim`] (all actors,
/// global ids intact) for unchanged post-run metric collection.
///
/// ```
/// use bss_extoll::sim::{Actor, Ctx, Partition, Sim, Time};
///
/// // Two actors ping-ponging a countdown over a 100 ns "link".
/// struct Counter { n: u64, peer: usize, link: Time }
/// impl Actor<u32> for Counter {
///     fn handle(&mut self, msg: u32, ctx: &mut Ctx<'_, u32>) {
///         self.n += 1;
///         if msg > 0 {
///             ctx.send(self.peer, self.link, msg - 1);
///         }
///     }
/// }
///
/// let link = Time::from_ns(100);
/// let mut sim = Sim::new();
/// let a = sim.add(Counter { n: 0, peer: 1, link });
/// let b = sim.add(Counter { n: 0, peer: 0, link });
/// sim.schedule(Time::ZERO, a, 64);
///
/// // One domain per actor; the link latency is the lookahead.
/// let mut part = Partition::split(sim, vec![0, 1], 2, link);
/// part.run_until(Time::from_us(100));
/// let merged = part.into_sim();
/// assert_eq!(merged.processed(), 65);
/// let handled = merged.get::<Counter>(a).n + merged.get::<Counter>(b).n;
/// assert_eq!(handled, 65);
/// ```
pub struct Partition<M> {
    domains: Vec<Sim<M>>,
    owner: Arc<Vec<u32>>,
    lookahead: Time,
    /// Continuation of the master sim's external-schedule counter, so
    /// `Partition::schedule` mints the same merge keys the serial run's
    /// `Sim::schedule` would.
    ext_seq: u64,
}

impl<M: Send + 'static> Partition<M> {
    /// Split a built simulation into `n_domains` domains. `owner` maps
    /// every actor id to its domain (resolved from
    /// [`crate::sim::Placement`] by the partitioning driver), and
    /// `lookahead` is the minimum latency of any cross-domain message
    /// (must be positive — conservative synchronization cannot make
    /// progress otherwise).
    pub fn split(sim: Sim<M>, owner: Vec<u32>, n_domains: usize, lookahead: Time) -> Partition<M> {
        assert!(n_domains >= 1, "partition needs at least one domain");
        assert!(lookahead > Time::ZERO, "conservative PDES requires positive lookahead");
        let parts = sim.into_parts();
        assert_eq!(owner.len(), parts.actors.len(), "owner map does not cover every actor");
        assert!(
            owner.iter().all(|&d| (d as usize) < n_domains),
            "owner map references a domain >= {n_domains}"
        );
        let owner = Arc::new(owner);
        let n = parts.actors.len();
        let kind = parts.queue.kind();
        let cap = parts.queue.capacity() / n_domains + 1;

        // distribute actors to their owning domain (global ids preserved)
        let mut actor_tables: Vec<Vec<_>> = (0..n_domains)
            .map(|_| (0..n).map(|_| None).collect())
            .collect();
        for (id, slot) in parts.actors.into_iter().enumerate() {
            if let Some(actor) = slot {
                actor_tables[owner[id] as usize][id] = Some(actor);
            }
        }

        // distribute already-scheduled events by destination owner
        let mut queues: Vec<EventQueue<M>> = (0..n_domains)
            .map(|_| EventQueue::with_capacity(kind, cap))
            .collect();
        let mut master_queue = parts.queue;
        while let Some(ev) = master_queue.pop() {
            queues[owner[ev.dst] as usize].push_keyed(ev.at, ev.seq, ev.dst, ev.msg);
        }

        let domains: Vec<Sim<M>> = actor_tables
            .into_iter()
            .zip(queues)
            .enumerate()
            .map(|(d, (actors, queue))| {
                Sim::from_parts(
                    SimParts {
                        now: parts.now,
                        actors,
                        queue,
                        // the master's pre-split count rides on domain 0 so
                        // the merged total matches a serial run
                        processed: if d == 0 { parts.processed } else { 0 },
                        send_seq: parts.send_seq.clone(),
                        ext_seq: 0, // external keys are minted by Partition
                    },
                    Some(DomainCtx {
                        owner: Arc::clone(&owner),
                        me: d as u32,
                        outbox: Vec::new(),
                    }),
                )
            })
            .collect();

        Partition {
            domains,
            owner,
            lookahead,
            ext_seq: parts.ext_seq,
        }
    }

    /// Number of domains.
    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// The conservative lookahead this partition synchronizes on.
    pub fn lookahead(&self) -> Time {
        self.lookahead
    }

    /// Total events processed across all domains.
    pub fn processed(&self) -> u64 {
        self.domains.iter().map(|d| d.processed()).sum()
    }

    /// Total events still pending across all domains.
    pub fn pending(&self) -> usize {
        self.domains.iter().map(|d| d.pending()).sum()
    }

    /// Schedule an external event, minting the same merge key the serial
    /// run's [`Sim::schedule`] would (callers must issue their external
    /// schedules in the same order in both modes — the fabric driver
    /// does).
    pub fn schedule(&mut self, at: Time, dst: ActorId, msg: M) {
        debug_assert!(
            self.domains.iter().all(|d| at >= d.now),
            "scheduling into the past of a domain"
        );
        let key = merge_key(EXTERNAL_SRC, self.ext_seq);
        self.ext_seq += 1;
        let d = self.owner[dst] as usize;
        self.domains[d].inject_keyed(at, key, dst, msg);
    }

    /// Process all events with timestamp ≤ `until` across all domains in
    /// parallel conservative windows, then advance every domain clock to
    /// `until`. Returns the number of events processed by this call.
    pub fn run_until(&mut self, until: Time) -> u64 {
        let start = self.processed();
        if self.domains.len() == 1 {
            self.domains[0].run_until(until);
            return self.processed() - start;
        }
        let n = self.domains.len();
        let lookahead = self.lookahead.ps();
        assert!(until.ps() < u64::MAX - lookahead - 1, "run_until horizon too large");
        let next_times: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let bound = AtomicU64::new(0);
        let barrier = SpinBarrier::new(n);
        let mailboxes: Vec<Mutex<Vec<Outgoing<M>>>> =
            (0..n).map(|_| Mutex::new(Vec::new())).collect();
        let owner: &[u32] = &self.owner;
        {
            let (next_times, bound, barrier, mailboxes) =
                (&next_times, &bound, &barrier, &mailboxes);
            std::thread::scope(|scope| {
                for (i, dom) in self.domains.iter_mut().enumerate() {
                    scope.spawn(move || {
                        let _poison = PoisonOnPanic(barrier);
                        loop {
                            // 1. publish my earliest pending event time
                            let t = dom.next_time().map_or(u64::MAX, |t| t.ps());
                            next_times[i].store(t, Ordering::Release);
                            if !barrier.wait() {
                                break;
                            }
                            // 2. leader derives the conservative bound
                            if i == 0 {
                                let t_min = next_times
                                    .iter()
                                    .map(|a| a.load(Ordering::Acquire))
                                    .min()
                                    .expect("at least one domain");
                                let b = if t_min > until.ps() {
                                    STOP
                                } else {
                                    // exclusive bound: a neighbor at t_min
                                    // can emit a message arriving exactly
                                    // at t_min + lookahead
                                    (t_min + lookahead).min(until.ps() + 1)
                                };
                                bound.store(b, Ordering::Release);
                            }
                            if !barrier.wait() {
                                break;
                            }
                            let b = bound.load(Ordering::Acquire);
                            if b == STOP {
                                break;
                            }
                            // 3. execute my window, route cross-domain sends
                            dom.run_before(Time::from_ps(b));
                            for m in dom.take_outbox() {
                                let dest = owner[m.dst] as usize;
                                mailboxes[dest].lock().expect("mailbox").push(m);
                            }
                            if !barrier.wait() {
                                break;
                            }
                            // 4. absorb my inbox (sorted for tidiness; the
                            // merge keys alone already fix the pop order)
                            let mut inbox =
                                std::mem::take(&mut *mailboxes[i].lock().expect("mailbox"));
                            inbox.sort_unstable_by_key(|m| (m.at, m.key));
                            for m in inbox {
                                // the lookahead invariant: no cross-domain
                                // message may arrive inside the window that
                                // produced it — a violation here means some
                                // sub-lookahead cross-domain edge exists
                                // (placement bug) and would silently corrupt
                                // the trajectory in release builds
                                debug_assert!(
                                    m.at >= Time::from_ps(b),
                                    "cross-domain arrival {} below window bound {b}",
                                    m.at
                                );
                                dom.inject_keyed(m.at, m.key, m.dst, m.msg);
                            }
                        }
                    });
                }
            });
        }
        for dom in &mut self.domains {
            dom.advance_clock(until);
        }
        self.processed() - start
    }

    /// Merge the domains back into one simulation (all actors under their
    /// global ids, leftover events requeued, clocks and counters folded),
    /// so post-run metric collection is identical to the serial path.
    pub fn into_sim(self) -> Sim<M> {
        let owner = self.owner;
        let mut parts: Vec<SimParts<M>> =
            self.domains.into_iter().map(|d| d.into_parts()).collect();
        let n = owner.len();
        let now = parts.iter().map(|p| p.now).max().unwrap_or(Time::ZERO);
        let processed = parts.iter().map(|p| p.processed).sum();
        let kind = parts.first().map(|p| p.queue.kind()).unwrap_or_default();
        let mut actors: Vec<_> = (0..n).map(|_| None).collect();
        let mut send_seq = vec![0u64; n];
        for (d, p) in parts.iter_mut().enumerate() {
            for id in 0..n {
                if owner[id] as usize == d {
                    actors[id] = p.actors[id].take();
                    send_seq[id] = p.send_seq[id];
                }
            }
        }
        let mut queue = EventQueue::with_kind(kind);
        for p in parts.iter_mut() {
            while let Some(ev) = p.queue.pop() {
                queue.push_keyed(ev.at, ev.seq, ev.dst, ev.msg);
            }
        }
        Sim::from_parts(
            SimParts {
                now,
                actors,
                queue,
                processed,
                send_seq,
                ext_seq: self.ext_seq,
            },
            None,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Actor, Ctx, QueueKind};

    /// Two "nodes" exchanging ping-pong with a fixed link latency, plus a
    /// local zero-delay echo on each side — the smallest system with both
    /// cross-domain and intra-domain traffic.
    #[derive(Debug, Clone, PartialEq)]
    enum M {
        Ping(u32),
        Echo(u32),
    }

    struct Node {
        peer: ActorId,
        echo: ActorId,
        link: Time,
        seen: Vec<(Time, u32)>,
        limit: u32,
    }

    impl Actor<M> for Node {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Ping(n) = msg {
                self.seen.push((ctx.now(), n));
                ctx.send(self.echo, Time::ZERO, M::Echo(n));
                if n < self.limit {
                    ctx.send(self.peer, self.link, M::Ping(n + 1));
                }
            }
        }

        fn placement(&self) -> crate::sim::Placement {
            crate::sim::Placement::Site(if self.echo % 4 < 2 { 0 } else { 1 })
        }
    }

    struct EchoSink {
        seen: Vec<(Time, u32)>,
    }

    impl Actor<M> for EchoSink {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Echo(n) = msg {
                self.seen.push((ctx.now(), n));
            }
        }
    }

    /// Build the 2-node system; returns (sim, node ids, echo ids).
    fn build(link: Time, limit: u32) -> (Sim<M>, [ActorId; 2], [ActorId; 2]) {
        let mut sim = Sim::with_kind(QueueKind::Wheel);
        // ids: node0=0, echo0=1, node1=2, echo1=3
        let n0 = sim.add(Node { peer: 2, echo: 1, link, seen: vec![], limit });
        let e0 = sim.add(EchoSink { seen: vec![] });
        let n1 = sim.add(Node { peer: 0, echo: 3, link, seen: vec![], limit });
        let e1 = sim.add(EchoSink { seen: vec![] });
        sim.schedule(Time::ZERO, n0, M::Ping(0));
        (sim, [n0, n1], [e0, e1])
    }

    fn trajectories(
        sim: &Sim<M>,
        nodes: [ActorId; 2],
        echoes: [ActorId; 2],
    ) -> Vec<Vec<(Time, u32)>> {
        vec![
            sim.get::<Node>(nodes[0]).seen.clone(),
            sim.get::<Node>(nodes[1]).seen.clone(),
            sim.get::<EchoSink>(echoes[0]).seen.clone(),
            sim.get::<EchoSink>(echoes[1]).seen.clone(),
        ]
    }

    #[test]
    fn partitioned_matches_serial() {
        let link = Time::from_ns(50);
        let until = Time::from_us(100);
        // serial reference
        let (mut serial, nodes, echoes) = build(link, 500);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);
        assert!(!want[0].is_empty());

        // partitioned: node0+echo0 in domain 0, node1+echo1 in domain 1
        let (sim, nodes, echoes) = build(link, 500);
        let owner = vec![0u32, 0, 1, 1];
        let mut part = Partition::split(sim, owner, 2, link);
        part.run_until(until);
        let total = part.processed();
        let merged = part.into_sim();
        assert_eq!(merged.processed(), total);
        assert_eq!(merged.now, until);
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn single_domain_partition_matches_serial() {
        let link = Time::from_ns(10);
        let until = Time::from_us(10);
        let (mut serial, nodes, echoes) = build(link, 100);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        let (sim, nodes, echoes) = build(link, 100);
        let mut part = Partition::split(sim, vec![0, 0, 0, 0], 1, link);
        part.run_until(until);
        let merged = part.into_sim();
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn external_schedules_keep_serial_keys() {
        // scheduling through the partition mid-run must mint the same
        // keys (and thus the same trajectory) as the serial Sim
        let link = Time::from_ns(20);
        let t_mid = Time::from_ns(500);
        let until = Time::from_us(5);

        let (mut serial, nodes, echoes) = build(link, 30);
        serial.run_until(t_mid);
        serial.schedule(t_mid, nodes[1], M::Ping(1000));
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        let (sim, nodes, echoes) = build(link, 30);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link);
        part.run_until(t_mid);
        part.schedule(t_mid, nodes[1], M::Ping(1000));
        part.run_until(until);
        let merged = part.into_sim();
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn run_until_is_resumable() {
        let link = Time::from_ns(40);
        let (sim, nodes, echoes) = build(link, 200);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link);
        let mut total = 0;
        for k in 1..=5u64 {
            total += part.run_until(Time::from_us(4 * k));
        }
        assert_eq!(total, part.processed());

        let (mut serial, n2, e2) = build(link, 200);
        serial.run_until(Time::from_us(20));
        assert_eq!(
            trajectories(&part.into_sim(), nodes, echoes),
            trajectories(&serial, n2, e2)
        );
    }

    #[test]
    #[should_panic(expected = "positive lookahead")]
    fn zero_lookahead_rejected() {
        let (sim, _, _) = build(Time::from_ns(1), 1);
        let _ = Partition::split(sim, vec![0, 0, 1, 1], 2, Time::ZERO);
    }

    #[test]
    #[should_panic(expected = "owner map")]
    fn incomplete_owner_map_rejected() {
        let (sim, _, _) = build(Time::from_ns(1), 1);
        let _ = Partition::split(sim, vec![0, 0], 2, Time::from_ns(1));
    }
}
