//! Partitioned conservative parallel DES (PDES) across torus domains.
//!
//! A [`Partition`] splits one built [`Sim`] into per-domain instances —
//! one domain per group of torus nodes, each owning its local actors and
//! event queue — and advances them on parallel worker threads under a
//! conservative synchronization protocol in the Chandy–Misra–Bryant
//! family. Three variants are implemented, selected by [`SyncMode`]:
//!
//! **Windowed** (`sync=window`, the reference implementation) is the
//! global-minimum special case of CMB's per-neighbor rule: with every
//! cross-domain link guaranteeing at least `lookahead` of latency, a
//! domain whose earliest pending event is at `t_min_global` or later may
//! execute everything strictly below
//!
//! ```text
//! bound = min(domain clocks) + lookahead  =  t_min_global + lookahead
//! ```
//!
//! because any message another domain emits in the same window is sent at
//! `≥ t_min_global` and therefore arrives at `≥ bound`.
//!
//! **Channel clocks** (`sync=channel`, the default; enabled by
//! [`Partition::with_channels`]) is the full per-neighbor CMB rule over a
//! [`ChannelGraph`] — the domain adjacency graph closed under path
//! composition (min-plus shortest paths, minimum cycles on the
//! diagonal). Each domain publishes its *earliest output time* (EOT —
//! the timestamp of its earliest pending event, a lower bound on any
//! future send; [`Sim`] computes it next to the outbox it feeds) and
//! advances to
//!
//! ```text
//! bound(i) = min over channels k⇝i of (EOT(k) + path-lookahead(k⇝i))
//! ```
//!
//! so a domain is constrained by exactly the domains that can reach it,
//! each discounted by the full accumulated lookahead of the cheapest
//! route — a slow domain on the far side of the torus no longer clamps
//! everyone to `global-min + one-hop lookahead` the way the windowed
//! bound does.
//!
//! In both round-based modes, instead of streaming null messages,
//! domains run in lock-step rounds on a spin barrier: publish EOTs →
//! derive bounds (leader-computed global bound, or per-domain channel
//! bounds) → all domains execute their windows in parallel →
//! cross-domain messages are exchanged through per-domain mailboxes →
//! repeat. The lookaheads come from the Extoll link model (cable +
//! router pipeline latency; see
//! [`crate::extoll::network::pdes_lookahead`] and
//! [`crate::extoll::network::pdes_channel_graph`]).
//!
//! **Barrier-free** (`sync=free`; [`Partition::barrier_free`] on top of
//! a channel graph) removes the round structure entirely: every ordered
//! domain pair gets a lock-free SPSC event queue, every domain publishes
//! its EOT in an `AtomicU64` (release/acquire), and each worker advances
//! whenever its own closure bounds allow — sparse traffic stops paying
//! barrier synchronization for empty mailboxes. See
//! [`Partition::run_until`]'s dispatch and the safety argument on the
//! free-mode loop (`docs/ARCHITECTURE.md` §2.3).
//!
//! **Fault-aware lookahead.** Under an injected fault model
//! ([`crate::fault::FaultModel`]) the enumerators above exclude links
//! that are dead from `t = 0` — they can never carry a message, so they
//! must not contribute a channel (or tighten a bound) the physical
//! fabric will never use. Links that fail *mid-run* still count: a
//! packet enqueued just before the cutover may cross after it.
//! Degradation, loss and jitter only add latency or remove packets, so
//! the healthy minimum link latency remains a sound lower bound. A
//! domain pair left with no connecting live link simply has no channel:
//! [`ChannelGraph::from_edges`] tolerates missing pairs, and a domain
//! with no in-channels runs unbounded (nothing can reach it).
//!
//! ## Determinism
//!
//! Domain count is a performance knob, not physics: reports are
//! byte-identical at any partitioning (gated by
//! `rust/tests/determinism_queue.rs`). Two properties make that true:
//!
//! 1. every event carries the partition-independent merge key of
//!    `sim/engine.rs` (source actor ‖ per-source send counter), so each
//!    domain's queue pops its local + injected events in exactly the
//!    relative order the single-`Sim` run would have, and
//! 2. the conservative bound guarantees a cross-domain message is always
//!    injected before the receiving domain reaches its timestamp, so no
//!    event is ever delivered "into the key-past".
//!
//! See `docs/ARCHITECTURE.md` for the full argument and the invariants.

use std::cell::UnsafeCell;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::engine::{
    merge_key, ActorId, DomainCtx, EventQueue, Outgoing, Sim, SimParts, EXTERNAL_SRC,
};
use super::time::Time;

/// Sentinel bound value signalling "no work at or below `until` remains".
const STOP: u64 = u64::MAX;

/// Which conservative synchronization protocol a partitioned run uses.
/// All are determinism-gated byte-identical to the serial event loop
/// (`rust/tests/differential_sync.rs`); they differ only in how tightly
/// non-neighboring domains are coupled, i.e. in wall-clock speed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SyncMode {
    /// Lock-step windows on the global-minimum clock plus one global
    /// lookahead. The reference implementation: simplest possible bound,
    /// every domain constrains every other.
    Window,
    /// Per-neighbor CMB channel clocks over a [`ChannelGraph`]: each
    /// domain is bounded by the domains that can reach it, at the
    /// accumulated path lookahead of the cheapest route. The default —
    /// distant domains stop clamping each other to one hop of slack, so
    /// large torii decouple.
    #[default]
    Channel,
    /// Barrier-free channel clocks: the same [`ChannelGraph`] bounds as
    /// `channel`, but no round structure at all — each domain loops
    /// independently, exchanging cross-domain events over per-channel
    /// lock-free SPSC queues and reading neighbor progress from
    /// published per-domain EOT atomics. Sparse traffic stops paying
    /// barrier synchronization for empty mailboxes; dense traffic
    /// behaves like `channel` without the rendezvous.
    Free,
}

impl SyncMode {
    pub fn parse(s: &str) -> Option<SyncMode> {
        match s {
            "window" => Some(SyncMode::Window),
            "channel" => Some(SyncMode::Channel),
            "free" => Some(SyncMode::Free),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SyncMode::Window => "window",
            SyncMode::Channel => "channel",
            SyncMode::Free => "free",
        }
    }

    /// All implemented modes, in protocol-generation order — the
    /// differential harness iterates this so a new mode is picked up by
    /// every cross-mode gate automatically.
    pub const ALL: [SyncMode; 3] = [SyncMode::Window, SyncMode::Channel, SyncMode::Free];

    /// Whether this mode derives bounds from a [`ChannelGraph`] (and so
    /// needs one attached via [`Partition::with_channels`]).
    pub fn needs_channel_graph(self) -> bool {
        !matches!(self, SyncMode::Window)
    }
}

/// The per-neighbor channel topology of a partition, **closed under path
/// composition**: for every ordered pair of domains `(k, i)` with a
/// directed path of physical channels from `k` to `i`, one transitive
/// channel whose lookahead is the min-plus shortest-path distance
/// `D(k→i)` (the diagonal `D(i→i)` is the minimum directed *cycle*
/// through `i` — a domain's own sends can come back). The closure is
/// what makes `EOT + lookahead` a sound bound: a message can reach `i`
/// through intermediate domains whose published EOTs are far in the
/// future, so `i` must be bounded by every domain that can *reach* it,
/// at the accumulated lookahead of the cheapest route — not only by its
/// direct neighbors. Built by the partitioning driver from the physical
/// link graph ([`crate::extoll::network::pdes_channel_graph`] enumerates
/// the inter-domain torus edges), or directly via
/// [`ChannelGraph::from_edges`].
#[derive(Clone, Debug)]
pub struct ChannelGraph {
    /// `in_channels[d]` = sorted `(source domain, path lookahead ps)`
    /// rows: exactly the (transitive) channels whose clocks bound
    /// domain `d`.
    in_channels: Vec<Vec<(u32, u64)>>,
}

impl ChannelGraph {
    /// Build from the **direct** `(source domain, destination domain,
    /// lookahead)` edges; parallel edges collapse to their minimum
    /// lookahead (a channel is only as fast as its fastest link), and
    /// the constructor takes the min-plus closure over paths (see the
    /// type docs). Every lookahead must be positive — conservative
    /// synchronization cannot make progress otherwise.
    pub fn from_edges(
        n_domains: usize,
        edges: impl IntoIterator<Item = (u32, u32, Time)>,
    ) -> ChannelGraph {
        // direct edges, min over parallels; dist[s * n + t] = D(s→t)
        let n = n_domains;
        let mut dist = vec![u64::MAX; n * n];
        for (src, dst, la) in edges {
            assert!(
                (src as usize) < n && (dst as usize) < n,
                "channel {src}->{dst} references a domain >= {n}"
            );
            assert!(src != dst, "channel from domain {src} to itself");
            assert!(la > Time::ZERO, "conservative PDES requires positive channel lookahead");
            let d = &mut dist[src as usize * n + dst as usize];
            *d = (*d).min(la.ps());
        }
        // Floyd–Warshall in min-plus; the diagonal starts at infinity
        // (not 0), so it converges to the minimum directed cycle weight
        // instead of erasing path sums.
        for via in 0..n {
            for s in 0..n {
                let d_sv = dist[s * n + via];
                if d_sv == u64::MAX {
                    continue;
                }
                for t in 0..n {
                    let d_vt = dist[via * n + t];
                    if d_vt == u64::MAX {
                        continue;
                    }
                    let through = d_sv.saturating_add(d_vt);
                    if through < dist[s * n + t] {
                        dist[s * n + t] = through;
                    }
                }
            }
        }
        let in_channels = (0..n)
            .map(|t| {
                (0..n)
                    .filter(|&s| dist[s * n + t] != u64::MAX)
                    .map(|s| (s as u32, dist[s * n + t]))
                    .collect()
            })
            .collect();
        ChannelGraph { in_channels }
    }

    /// Number of domains the graph covers.
    pub fn n_domains(&self) -> usize {
        self.in_channels.len()
    }

    /// Total number of directed channels in the closure (reachable
    /// ordered pairs, including `i→i` cycles).
    pub fn n_channels(&self) -> usize {
        self.in_channels.iter().map(Vec::len).sum()
    }

    /// The (transitive) in-channels of `dst` as `(source domain, path
    /// lookahead in ps)`, sorted by source domain.
    fn in_channels(&self, dst: usize) -> &[(u32, u64)] {
        &self.in_channels[dst]
    }

    /// Minimum lookahead over all channels (closure sums are never
    /// smaller than their constituent edges, so this equals the minimum
    /// direct-edge lookahead — the windowed protocol's global
    /// lookahead). `None` when the graph has no channels.
    pub fn min_lookahead(&self) -> Option<Time> {
        self.in_channels
            .iter()
            .flatten()
            .map(|&(_, la)| Time::from_ps(la))
            .min()
    }
}

/// A reusable sense-counting spin barrier for the window lock-step.
///
/// Windows are short (one lookahead of simulated time, typically tens of
/// events per domain), so parking on a futex every window would dominate;
/// workers spin briefly and fall back to `yield_now` so oversubscribed
/// hosts (more domains than cores) still make progress. A panicking
/// worker poisons the barrier, releasing every other worker with `false`
/// so the panic propagates instead of deadlocking the fleet.
struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    poisoned: AtomicBool,
}

impl SpinBarrier {
    fn new(n: usize) -> SpinBarrier {
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Wait for all `n` workers; returns false if the barrier was
    /// poisoned (some worker panicked) and the caller should bail out.
    fn wait(&self) -> bool {
        if self.poisoned.load(Ordering::Acquire) {
            return false;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Release);
            self.generation.fetch_add(1, Ordering::Release);
            !self.poisoned.load(Ordering::Acquire)
        } else {
            let mut spins = 0u32;
            loop {
                if self.generation.load(Ordering::Acquire) != gen {
                    return !self.poisoned.load(Ordering::Acquire);
                }
                // re-check inside the loop: a worker can capture the
                // post-poison generation (poison bumps it) and would
                // otherwise spin on a generation that never changes again
                if self.poisoned.load(Ordering::Acquire) {
                    return false;
                }
                spins += 1;
                if spins < 1 << 10 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }

    fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
        // release any worker currently spinning on the generation
        self.generation.fetch_add(1, Ordering::Release);
    }
}

/// Poisons the barrier if its worker unwinds, so sibling workers exit
/// their window loop instead of spinning forever.
struct PoisonOnPanic<'a>(&'a SpinBarrier);

impl Drop for PoisonOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// Sets the free-mode poison flag if its worker unwinds, so sibling
/// workers (which check the flag at the top of every advance iteration)
/// exit instead of looping forever on an EOT that will never advance.
struct FreePoisonOnPanic<'a>(&'a AtomicBool);

impl Drop for FreePoisonOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Release);
        }
    }
}

/// One node of an [`SpscQueue`] chain. The dummy head carries no value.
struct SpscNode<T> {
    next: AtomicPtr<SpscNode<T>>,
    val: Option<T>,
}

/// An unbounded lock-free single-producer / single-consumer queue — one
/// per ordered domain pair in [`SyncMode::Free`], replacing the mutexed
/// mailboxes of the barrier modes. A singly linked chain with a dummy
/// head: the producer appends by publishing the predecessor's `next`
/// pointer with `Release`; the consumer follows `next` with `Acquire`
/// and frees consumed nodes. Producer and consumer never touch the same
/// field: `tail` is producer-owned, `head` is consumer-owned, and the
/// only shared state is the per-node `next` pointer.
///
/// # Safety contract
///
/// `push` may be called by at most one thread at a time, and `pop` by at
/// most one thread at a time (they may be different threads — that is
/// the point). `run_free` satisfies this by construction: queue
/// `src→dst` is pushed only by domain `src`'s worker and popped only by
/// domain `dst`'s worker. The queue itself must outlive both workers
/// (it is owned by the coordinating thread across the worker scope), so
/// no endpoint ever dangles; `Drop` frees whatever the consumer left.
struct SpscQueue<T> {
    /// Consumer-owned cursor: the last consumed (or dummy) node.
    head: UnsafeCell<*mut SpscNode<T>>,
    /// Producer-owned cursor: the most recently appended node.
    tail: UnsafeCell<*mut SpscNode<T>>,
}

// The raw pointers are to heap nodes handed off between exactly one
// producer and one consumer under the contract above; `T: Send` is all
// the hand-off needs.
unsafe impl<T: Send> Send for SpscQueue<T> {}
unsafe impl<T: Send> Sync for SpscQueue<T> {}

impl<T> SpscQueue<T> {
    fn new() -> SpscQueue<T> {
        let dummy = Box::into_raw(Box::new(SpscNode {
            next: AtomicPtr::new(ptr::null_mut()),
            val: None,
        }));
        SpscQueue { head: UnsafeCell::new(dummy), tail: UnsafeCell::new(dummy) }
    }

    /// Append `val`. Safety: single producer (see type docs).
    unsafe fn push(&self, val: T) {
        let node = Box::into_raw(Box::new(SpscNode {
            next: AtomicPtr::new(ptr::null_mut()),
            val: Some(val),
        }));
        let tail = self.tail.get();
        // Publish the node: the Release store pairs with the consumer's
        // Acquire load of `next`, making the node's contents visible.
        (**tail).next.store(node, Ordering::Release);
        *tail = node;
    }

    /// Take the oldest value, or `None` if the queue is (momentarily)
    /// empty. Safety: single consumer (see type docs).
    unsafe fn pop(&self) -> Option<T> {
        let head = self.head.get();
        let next = (**head).next.load(Ordering::Acquire);
        if next.is_null() {
            return None;
        }
        let val = (*next).val.take().expect("SPSC node consumed twice");
        // the old head (dummy or already-consumed) retires; `next`
        // becomes the new dummy
        drop(Box::from_raw(*head));
        *head = next;
        Some(val)
    }
}

impl<T> Drop for SpscQueue<T> {
    fn drop(&mut self) {
        // Runs on the owning thread after every worker has been joined,
        // so no endpoint is live: walk the remaining chain and free it.
        unsafe {
            let mut p = *self.head.get();
            while !p.is_null() {
                let next = (*p).next.load(Ordering::Acquire);
                drop(Box::from_raw(p));
                p = next;
            }
        }
    }
}

/// A simulation partitioned into conservatively synchronized domains.
///
/// Construct with [`Partition::split`] after the system is fully built,
/// drive with [`Partition::run_until`] / [`Partition::schedule`], then
/// [`Partition::into_sim`] reassembles a single [`Sim`] (all actors,
/// global ids intact) for unchanged post-run metric collection.
///
/// ```
/// use bss_extoll::sim::{Actor, ChannelGraph, Ctx, Partition, Sim, Time};
///
/// // Two actors ping-ponging a countdown over a 100 ns "link".
/// struct Counter { n: u64, peer: usize, link: Time }
/// impl Actor<u32> for Counter {
///     fn handle(&mut self, msg: u32, ctx: &mut Ctx<'_, u32>) {
///         self.n += 1;
///         if msg > 0 {
///             ctx.send(self.peer, self.link, msg - 1);
///         }
///     }
/// }
///
/// let link = Time::from_ns(100);
/// let mut sim = Sim::new();
/// let a = sim.add(Counter { n: 0, peer: 1, link });
/// let b = sim.add(Counter { n: 0, peer: 0, link });
/// sim.schedule(Time::ZERO, a, 64);
///
/// // One domain per actor; the link latency is the lookahead. The
/// // channel graph (both directions of the one link) switches run_until
/// // to per-neighbor channel clocks — same trajectory either way.
/// let graph = ChannelGraph::from_edges(2, [(0, 1, link), (1, 0, link)]);
/// let mut part = Partition::split(sim, vec![0, 1], 2, link).with_channels(graph);
/// part.run_until(Time::from_us(100));
/// let merged = part.into_sim();
/// assert_eq!(merged.processed(), 65);
/// let handled = merged.get::<Counter>(a).n + merged.get::<Counter>(b).n;
/// assert_eq!(handled, 65);
/// ```
pub struct Partition<M> {
    domains: Vec<Sim<M>>,
    owner: Arc<Vec<u32>>,
    lookahead: Time,
    /// Per-neighbor channel topology; `Some` switches the run loop from
    /// the windowed global bound to channel clocks ([`SyncMode`]).
    channels: Option<ChannelGraph>,
    /// Which protocol `run_until` drives. `Window` until a graph is
    /// attached; [`Partition::with_channels`] selects `Channel`;
    /// [`Partition::barrier_free`] upgrades to `Free`.
    mode: SyncMode,
    /// Seeded scheduling perturbation for the free-mode advance loop
    /// (test/chaos knob, see [`Partition::with_free_chaos`]). `None`
    /// disables injection.
    free_chaos: Option<u64>,
    /// Continuation of the master sim's external-schedule counter, so
    /// `Partition::schedule` mints the same merge keys the serial run's
    /// `Sim::schedule` would.
    ext_seq: u64,
}

impl<M: Send + 'static> Partition<M> {
    /// Split a built simulation into `n_domains` domains. `owner` maps
    /// every actor id to its domain (resolved from
    /// [`crate::sim::Placement`] by the partitioning driver), and
    /// `lookahead` is the minimum latency of any cross-domain message
    /// (must be positive — conservative synchronization cannot make
    /// progress otherwise).
    pub fn split(sim: Sim<M>, owner: Vec<u32>, n_domains: usize, lookahead: Time) -> Partition<M> {
        assert!(n_domains >= 1, "partition needs at least one domain");
        assert!(lookahead > Time::ZERO, "conservative PDES requires positive lookahead");
        let parts = sim.into_parts();
        assert_eq!(owner.len(), parts.actors.len(), "owner map does not cover every actor");
        assert!(
            owner.iter().all(|&d| (d as usize) < n_domains),
            "owner map references a domain >= {n_domains}"
        );
        let owner = Arc::new(owner);
        let n = parts.actors.len();
        let kind = parts.queue.kind();
        let cap = parts.queue.capacity() / n_domains + 1;

        // distribute actors to their owning domain (global ids preserved)
        let mut actor_tables: Vec<Vec<_>> = (0..n_domains)
            .map(|_| (0..n).map(|_| None).collect())
            .collect();
        for (id, slot) in parts.actors.into_iter().enumerate() {
            if let Some(actor) = slot {
                actor_tables[owner[id] as usize][id] = Some(actor);
            }
        }

        // distribute already-scheduled events by destination owner
        let mut queues: Vec<EventQueue<M>> = (0..n_domains)
            .map(|_| EventQueue::with_capacity(kind, cap))
            .collect();
        let mut master_queue = parts.queue;
        while let Some(ev) = master_queue.pop() {
            queues[owner[ev.dst] as usize].push_keyed(ev.at, ev.seq, ev.dst, ev.msg);
        }

        let domains: Vec<Sim<M>> = actor_tables
            .into_iter()
            .zip(queues)
            .enumerate()
            .map(|(d, (actors, queue))| {
                Sim::from_parts(
                    SimParts {
                        now: parts.now,
                        actors,
                        queue,
                        // the master's pre-split count rides on domain 0 so
                        // the merged total matches a serial run
                        processed: if d == 0 { parts.processed } else { 0 },
                        send_seq: parts.send_seq.clone(),
                        ext_seq: 0, // external keys are minted by Partition
                    },
                    Some(DomainCtx {
                        owner: Arc::clone(&owner),
                        me: d as u32,
                        outbox: Vec::new(),
                    }),
                )
            })
            .collect();

        Partition {
            domains,
            owner,
            lookahead,
            channels: None,
            mode: SyncMode::Window,
            free_chaos: None,
            ext_seq: parts.ext_seq,
        }
    }

    /// Switch this partition to per-neighbor channel clocks
    /// ([`SyncMode::Channel`]): each domain is then bounded by the
    /// domains that can reach it in `graph` (at the closure's path
    /// lookaheads) instead of by the global minimum. The graph must
    /// cover every domain and its direct edges must include **every**
    /// pair of domains that actually exchanges messages — a missing edge
    /// makes the receiving domain run ahead of the sender's traffic (the
    /// run loop debug-asserts against it).
    pub fn with_channels(mut self, graph: ChannelGraph) -> Partition<M> {
        assert_eq!(
            graph.n_domains(),
            self.domains.len(),
            "channel graph does not cover every domain"
        );
        self.channels = Some(graph);
        self.mode = SyncMode::Channel;
        self
    }

    /// Upgrade a channel-clocked partition ([`Partition::with_channels`]
    /// must have been called) to the barrier-free protocol
    /// ([`SyncMode::Free`]): same [`ChannelGraph`] bounds, but each
    /// domain advances independently over lock-free SPSC queues and
    /// published EOT atomics instead of barrier-separated rounds.
    pub fn barrier_free(mut self) -> Partition<M> {
        assert!(
            self.channels.is_some(),
            "barrier-free sync needs a channel graph (call with_channels first)"
        );
        self.mode = SyncMode::Free;
        self
    }

    /// Inject seeded pseudo-random `yield_now` calls into the free-mode
    /// advance loop, perturbing per-domain thread scheduling without
    /// touching the protocol. A determinism gate run under many chaos
    /// seeds demonstrates the conservative bounds absorb every ordering
    /// the OS could produce — the trajectory must not change. No effect
    /// on the barrier modes (their rounds already serialize scheduling).
    pub fn with_free_chaos(mut self, seed: u64) -> Partition<M> {
        self.free_chaos = Some(seed);
        self
    }

    /// Number of domains.
    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// The conservative lookahead this partition synchronizes on.
    pub fn lookahead(&self) -> Time {
        self.lookahead
    }

    /// Which synchronization protocol [`Partition::run_until`] uses.
    pub fn sync_mode(&self) -> SyncMode {
        self.mode
    }

    /// Total events processed across all domains.
    pub fn processed(&self) -> u64 {
        self.domains.iter().map(|d| d.processed()).sum()
    }

    /// Total events still pending across all domains.
    pub fn pending(&self) -> usize {
        self.domains.iter().map(|d| d.pending()).sum()
    }

    /// Schedule an external event, minting the same merge key the serial
    /// run's [`Sim::schedule`] would (callers must issue their external
    /// schedules in the same order in both modes — the fabric driver
    /// does).
    pub fn schedule(&mut self, at: Time, dst: ActorId, msg: M) {
        let d = self.owner[dst] as usize;
        // Only the destination domain's clock bounds an external
        // schedule: channel clocks legitimately let other domains run
        // ahead of `at`, and their pasts are not this event's past.
        debug_assert!(
            at >= self.domains[d].now,
            "scheduling into the past of domain {d}"
        );
        let key = merge_key(EXTERNAL_SRC, self.ext_seq);
        self.ext_seq += 1;
        self.domains[d].inject_keyed(at, key, dst, msg);
    }

    /// Process all events with timestamp ≤ `until` across all domains in
    /// parallel conservative windows, then advance every domain clock to
    /// `until`. Returns the number of events processed by this call.
    ///
    /// The window bounds come from the [`SyncMode`]: the global-minimum
    /// window (reference), per-neighbor channel clocks when a
    /// [`ChannelGraph`] was attached via [`Partition::with_channels`],
    /// or the barrier-free loop after [`Partition::barrier_free`]. In
    /// every mode the trajectory — and thus every report — is identical.
    pub fn run_until(&mut self, until: Time) -> u64 {
        let start = self.processed();
        if self.domains.len() == 1 {
            self.domains[0].run_until(until);
            return self.processed() - start;
        }
        match self.mode {
            SyncMode::Window => self.run_windows_global(until),
            SyncMode::Channel => self.run_windows_channel(until),
            SyncMode::Free => self.run_free(until),
        }
        for dom in &mut self.domains {
            dom.advance_clock(until);
        }
        self.processed() - start
    }

    /// The windowed (global-minimum) protocol: one leader-computed bound
    /// per round, three barriers. Kept verbatim as the reference
    /// implementation `sync=channel` must match byte-for-byte.
    fn run_windows_global(&mut self, until: Time) {
        let n = self.domains.len();
        let lookahead = self.lookahead.ps();
        assert!(until.ps() < u64::MAX - lookahead - 1, "run_until horizon too large");
        let next_times: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let bound = AtomicU64::new(0);
        let barrier = SpinBarrier::new(n);
        let mailboxes: Vec<Mutex<Vec<Outgoing<M>>>> =
            (0..n).map(|_| Mutex::new(Vec::new())).collect();
        let owner: &[u32] = &self.owner;
        {
            let (next_times, bound, barrier, mailboxes) =
                (&next_times, &bound, &barrier, &mailboxes);
            std::thread::scope(|scope| {
                for (i, dom) in self.domains.iter_mut().enumerate() {
                    scope.spawn(move || {
                        let _poison = PoisonOnPanic(barrier);
                        loop {
                            // 1. publish my earliest output time
                            next_times[i].store(dom.eot_ps(), Ordering::Release);
                            if !barrier.wait() {
                                break;
                            }
                            // 2. leader derives the conservative bound
                            if i == 0 {
                                let t_min = next_times
                                    .iter()
                                    .map(|a| a.load(Ordering::Acquire))
                                    .min()
                                    .expect("at least one domain");
                                let b = if t_min > until.ps() {
                                    STOP
                                } else {
                                    // exclusive bound: a neighbor at t_min
                                    // can emit a message arriving exactly
                                    // at t_min + lookahead
                                    (t_min + lookahead).min(until.ps() + 1)
                                };
                                bound.store(b, Ordering::Release);
                            }
                            if !barrier.wait() {
                                break;
                            }
                            let b = bound.load(Ordering::Acquire);
                            if b == STOP {
                                break;
                            }
                            // 3. execute my window, route cross-domain sends
                            dom.run_before(Time::from_ps(b));
                            for m in dom.take_outbox() {
                                let dest = owner[m.dst] as usize;
                                mailboxes[dest].lock().expect("mailbox").push(m);
                            }
                            if !barrier.wait() {
                                break;
                            }
                            // 4. absorb my inbox (sorted for tidiness; the
                            // merge keys alone already fix the pop order)
                            let mut inbox =
                                std::mem::take(&mut *mailboxes[i].lock().expect("mailbox"));
                            inbox.sort_unstable_by_key(|m| (m.at, m.key));
                            for m in inbox {
                                // the lookahead invariant: no cross-domain
                                // message may arrive inside the window that
                                // produced it — a violation here means some
                                // sub-lookahead cross-domain edge exists
                                // (placement bug) and would silently corrupt
                                // the trajectory in release builds
                                debug_assert!(
                                    m.at >= Time::from_ps(b),
                                    "cross-domain arrival {} below window bound {b}",
                                    m.at
                                );
                                dom.inject_keyed(m.at, m.key, m.dst, m.msg);
                            }
                        }
                    });
                }
            });
        }
    }

    /// The per-neighbor channel-clock protocol ([`SyncMode::Channel`]):
    /// every domain derives its **own** bound from the closure channels
    /// that end at it (published EOT of each domain that can reach it,
    /// plus that route's accumulated lookahead), so distant domains only
    /// constrain it through real path latency, and each round needs only
    /// two barriers (no leader step — every worker reads the same
    /// published snapshot).
    ///
    /// Safety (the per-channel CMB invariant, `docs/ARCHITECTURE.md`
    /// §2.3): any message that ever arrives at domain `i` materializes
    /// through a causal chain of events that starts at some event
    /// pending *now* in some domain `k` (at `t ≥ EOT(k)`) and crosses,
    /// hop by hop, a directed path of physical channels `k ⇝ i` — so it
    /// arrives at `t' ≥ EOT(k) + D(k⇝i) ≥ bound(i)`, where `D` is the
    /// closure distance ([`ChannelGraph`]), never inside the window `i`
    /// executes this round (the diagonal `D(i⇝i)` covers `i`'s own sends
    /// bouncing back). The bound is monotone across rounds: a domain's
    /// post-round EOT is at least `min(EOT, bound)`, and composing a
    /// `k ⇝ j` route with a `j ⇝ i` route never beats `D(k⇝i)`, so
    /// next round's bounds only grow — the argument covers every later
    /// round by induction.
    fn run_windows_channel(&mut self, until: Time) {
        let n = self.domains.len();
        assert!(until.ps() < u64::MAX - 1, "run_until horizon too large");
        let graph = self.channels.as_ref().expect("channel sync without a graph");
        let next_times: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let barrier = SpinBarrier::new(n);
        let mailboxes: Vec<Mutex<Vec<Outgoing<M>>>> =
            (0..n).map(|_| Mutex::new(Vec::new())).collect();
        let owner: &[u32] = &self.owner;
        {
            let (next_times, barrier, mailboxes) = (&next_times, &barrier, &mailboxes);
            std::thread::scope(|scope| {
                for (i, dom) in self.domains.iter_mut().enumerate() {
                    let in_ch = graph.in_channels(i);
                    scope.spawn(move || {
                        let _poison = PoisonOnPanic(barrier);
                        loop {
                            // 1. publish my earliest output time: nothing
                            // I send from here on departs below it
                            next_times[i].store(dom.eot_ps(), Ordering::Release);
                            if !barrier.wait() {
                                break;
                            }
                            // 2. consistent termination check — every
                            // worker reads the same barrier-separated
                            // snapshot, so all break in the same round
                            let t_min = next_times
                                .iter()
                                .map(|a| a.load(Ordering::Acquire))
                                .min()
                                .expect("at least one domain");
                            if t_min > until.ps() {
                                break;
                            }
                            // 3. my own bound: only the closure channels
                            // ending at me constrain me (exclusive, like
                            // the windowed bound; `until + 1` caps the
                            // last window)
                            let mut b = until.ps() + 1;
                            for &(src, la) in in_ch {
                                let eot = next_times[src as usize].load(Ordering::Acquire);
                                b = b.min(eot.saturating_add(la));
                            }
                            // execute my window, route cross-domain sends
                            dom.run_before(Time::from_ps(b));
                            for m in dom.take_outbox() {
                                let dest = owner[m.dst] as usize;
                                mailboxes[dest].lock().expect("mailbox").push(m);
                            }
                            if !barrier.wait() {
                                break;
                            }
                            // 4. absorb my inbox (sorted for tidiness; the
                            // merge keys alone already fix the pop order)
                            let mut inbox =
                                std::mem::take(&mut *mailboxes[i].lock().expect("mailbox"));
                            inbox.sort_unstable_by_key(|m| (m.at, m.key));
                            for m in inbox {
                                // the channel invariant: an arrival below
                                // my bound means some physical j→i link is
                                // faster than the channel graph's
                                // lookahead(j→i), or the j→i channel is
                                // missing — either silently corrupts the
                                // trajectory in release builds
                                debug_assert!(
                                    m.at >= Time::from_ps(b),
                                    "cross-domain arrival {} below channel bound {b}",
                                    m.at
                                );
                                dom.inject_keyed(m.at, m.key, m.dst, m.msg);
                            }
                        }
                    });
                }
            });
        }
    }

    /// The barrier-free channel-clock protocol ([`SyncMode::Free`]): no
    /// rounds, no barriers, no leader. Every ordered domain pair gets a
    /// lock-free [`SpscQueue`] of in-flight events, and every domain
    /// publishes its EOT in a shared `AtomicU64`. Each worker then loops
    /// independently:
    ///
    /// 1. snapshot each in-channel source's published EOT (`Acquire`),
    ///    **then** drain every incoming queue — in that order, per
    ///    source: the Acquire read pairs with the sender's Release
    ///    publication, which is ordered *after* its queue pushes, so
    ///    every message sent before that publication is drained here;
    /// 2. derive the bound `min over in-channels k of (EOT(k) + D(k⇝i))`
    ///    from the snapshot (same closure bound as `sync=channel`);
    /// 3. execute the window strictly below the bound, route
    ///    cross-domain sends into the SPSC queues;
    /// 4. publish the new EOT (`Release`, ordered after the pushes);
    /// 5. stop when both the bound and the local EOT pass `until` — a
    ///    consistent-by-construction termination check: undrained or
    ///    future arrivals are `≥ bound > until` (safety argument below)
    ///    and pending work is `≥ EOT > until`, so no barrier-separated
    ///    global snapshot is needed.
    ///
    /// **Safety** (`docs/ARCHITECTURE.md` §2.3): any message this
    /// worker has *not* drained in step 1 is the endpoint of a finite
    /// causal chain of executions. If every link of that chain ran
    /// before the publication whose value the worker read for its
    /// source domain, the final push happened-before the worker's drain
    /// (push → Release publish → Acquire read → drain) and *was*
    /// drained — contradiction. So some chain event was still pending
    /// at its domain `k` when `k` published the value `e_k` the worker
    /// read, giving it timestamp `≥ e_k`; the remaining hops add link
    /// latencies that sum to at least the closure distance `D(k⇝i)`,
    /// so the message arrives at `≥ e_k + D(k⇝i) ≥ bound`. Applied to
    /// every earlier iteration, each arrival is at or above *every*
    /// bound this domain has executed to — no stragglers — and the
    /// merge keys make injection order irrelevant, so the trajectory is
    /// byte-identical to serial. Note the argument anchors on
    /// happens-before edges, not per-domain EOT monotonicity: a
    /// published EOT may legitimately *drop* when an idle domain
    /// receives early work, and the closure (triangle inequality)
    /// absorbs it.
    ///
    /// A panicking worker sets a shared poison flag (checked at the top
    /// of every iteration) instead of poisoning a barrier, so siblings
    /// exit rather than spinning on an EOT that will never advance.
    fn run_free(&mut self, until: Time) {
        let n = self.domains.len();
        assert!(until.ps() < u64::MAX - 1, "run_until horizon too large");
        let graph = self.channels.as_ref().expect("free sync without a graph");
        let chaos = self.free_chaos;
        // seed each domain's EOT before any worker reads it: sound
        // (it is the true minimum over that domain's pending events)
        // and it spares the first iterations a cold-start crawl
        let eots: Vec<AtomicU64> =
            self.domains.iter().map(|d| AtomicU64::new(d.eot_ps())).collect();
        let poisoned = AtomicBool::new(false);
        // queue[src * n + dst]: pushed only by src's worker, popped only
        // by dst's worker — the SPSC contract, by construction
        let queues: Vec<SpscQueue<Outgoing<M>>> = (0..n * n).map(|_| SpscQueue::new()).collect();
        let owner: &[u32] = &self.owner;
        {
            let (eots, poisoned, queues) = (&eots, &poisoned, &queues);
            std::thread::scope(|scope| {
                for (i, dom) in self.domains.iter_mut().enumerate() {
                    let in_ch = graph.in_channels(i);
                    scope.spawn(move || {
                        let _poison = FreePoisonOnPanic(poisoned);
                        // xorshift64* for chaos yield injection — cheap,
                        // deterministic per (seed, domain)
                        let salt = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1);
                        let mut rng = chaos.map(|seed| seed ^ salt);
                        let mut chaos_tick = move || {
                            if let Some(s) = rng.as_mut() {
                                *s ^= *s << 13;
                                *s ^= *s >> 7;
                                *s ^= *s << 17;
                                if *s % 3 == 0 {
                                    std::thread::yield_now();
                                }
                            }
                        };
                        // highest bound executed so far: arrivals below it
                        // would be stragglers (see debug_assert below)
                        let mut horizon = 0u64;
                        let mut eot_snapshot = vec![0u64; in_ch.len()];
                        let mut idle_spins = 0u32;
                        loop {
                            if poisoned.load(Ordering::Acquire) {
                                break;
                            }
                            chaos_tick();
                            // 1. snapshot in-channel EOTs, then drain every
                            // incoming queue (order is load-bearing: read
                            // the publication before draining the pushes
                            // it covers)
                            for (slot, &(src, _)) in eot_snapshot.iter_mut().zip(in_ch) {
                                *slot = eots[src as usize].load(Ordering::Acquire);
                            }
                            let mut progressed = false;
                            for src in 0..n {
                                if src == i {
                                    continue;
                                }
                                // safety: this worker is queue src→i's only
                                // consumer
                                while let Some(m) = unsafe { queues[src * n + i].pop() } {
                                    debug_assert!(
                                        m.at.ps() >= horizon,
                                        "cross-domain arrival {} below executed horizon {horizon}",
                                        m.at
                                    );
                                    dom.inject_keyed(m.at, m.key, m.dst, m.msg);
                                    progressed = true;
                                }
                            }
                            // 2. my bound from the snapshot (exclusive;
                            // `until + 1` caps the last window)
                            let mut b = until.ps() + 1;
                            for (&e, &(_, la)) in eot_snapshot.iter().zip(in_ch) {
                                b = b.min(e.saturating_add(la));
                            }
                            // 3. execute my window, route cross-domain sends
                            if b > horizon {
                                let before = dom.processed();
                                dom.run_before(Time::from_ps(b));
                                horizon = b;
                                progressed |= dom.processed() != before;
                                for m in dom.take_outbox() {
                                    let dest = owner[m.dst] as usize;
                                    // safety: this worker is queue i→dest's
                                    // only producer
                                    unsafe { queues[i * n + dest].push(m) };
                                }
                            }
                            chaos_tick();
                            // 4. publish my EOT — Release, ordered after the
                            // pushes, so a reader that sees it also sees them
                            let eot = dom.eot_ps();
                            eots[i].store(eot, Ordering::Release);
                            // 5. termination: nothing pending ≤ until, and
                            // the bound proves nothing ≤ until can still
                            // arrive (drained before computing it)
                            if eot > until.ps() && b > until.ps() {
                                break;
                            }
                            // back off while a neighbor's EOT is the only
                            // thing standing between us and progress
                            if progressed {
                                idle_spins = 0;
                            } else {
                                idle_spins += 1;
                                if idle_spins < 1 << 6 {
                                    std::hint::spin_loop();
                                } else {
                                    std::thread::yield_now();
                                }
                            }
                        }
                    });
                }
            });
        }
        // A worker may exit while a late message from a still-running
        // sibling sits undrained in its queues. The safety argument
        // puts every such message strictly past `until`, but it is
        // still real traffic: reclaim it into the destination domain so
        // a later (resumed) `run_until` sees it as pending.
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                // safety: every worker has been joined — this thread is
                // now the queue's only consumer
                while let Some(m) = unsafe { queues[src * n + dst].pop() } {
                    debug_assert!(
                        m.at > until,
                        "stranded cross-domain arrival {} at or below the horizon {until}",
                        m.at
                    );
                    self.domains[dst].inject_keyed(m.at, m.key, m.dst, m.msg);
                }
            }
        }
    }

    /// Merge the domains back into one simulation (all actors under their
    /// global ids, leftover events requeued, clocks and counters folded),
    /// so post-run metric collection is identical to the serial path.
    pub fn into_sim(self) -> Sim<M> {
        let owner = self.owner;
        let mut parts: Vec<SimParts<M>> =
            self.domains.into_iter().map(|d| d.into_parts()).collect();
        let n = owner.len();
        let now = parts.iter().map(|p| p.now).max().unwrap_or(Time::ZERO);
        let processed = parts.iter().map(|p| p.processed).sum();
        let kind = parts.first().map(|p| p.queue.kind()).unwrap_or_default();
        let mut actors: Vec<_> = (0..n).map(|_| None).collect();
        let mut send_seq = vec![0u64; n];
        for (d, p) in parts.iter_mut().enumerate() {
            for id in 0..n {
                if owner[id] as usize == d {
                    actors[id] = p.actors[id].take();
                    send_seq[id] = p.send_seq[id];
                }
            }
        }
        let mut queue = EventQueue::with_kind(kind);
        for p in parts.iter_mut() {
            while let Some(ev) = p.queue.pop() {
                queue.push_keyed(ev.at, ev.seq, ev.dst, ev.msg);
            }
        }
        Sim::from_parts(
            SimParts {
                now,
                actors,
                queue,
                processed,
                send_seq,
                ext_seq: self.ext_seq,
            },
            None,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Actor, Ctx, QueueKind};

    /// Two "nodes" exchanging ping-pong with a fixed link latency, plus a
    /// local zero-delay echo on each side — the smallest system with both
    /// cross-domain and intra-domain traffic.
    #[derive(Debug, Clone, PartialEq)]
    enum M {
        Ping(u32),
        Echo(u32),
    }

    struct Node {
        peer: ActorId,
        echo: ActorId,
        link: Time,
        seen: Vec<(Time, u32)>,
        limit: u32,
    }

    impl Actor<M> for Node {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Ping(n) = msg {
                self.seen.push((ctx.now(), n));
                ctx.send(self.echo, Time::ZERO, M::Echo(n));
                if n < self.limit {
                    ctx.send(self.peer, self.link, M::Ping(n + 1));
                }
            }
        }

        fn placement(&self) -> crate::sim::Placement {
            crate::sim::Placement::Site(if self.echo % 4 < 2 { 0 } else { 1 })
        }
    }

    struct EchoSink {
        seen: Vec<(Time, u32)>,
    }

    impl Actor<M> for EchoSink {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Echo(n) = msg {
                self.seen.push((ctx.now(), n));
            }
        }
    }

    /// Build the 2-node system; returns (sim, node ids, echo ids).
    fn build(link: Time, limit: u32) -> (Sim<M>, [ActorId; 2], [ActorId; 2]) {
        let mut sim = Sim::with_kind(QueueKind::Wheel);
        // ids: node0=0, echo0=1, node1=2, echo1=3
        let n0 = sim.add(Node { peer: 2, echo: 1, link, seen: vec![], limit });
        let e0 = sim.add(EchoSink { seen: vec![] });
        let n1 = sim.add(Node { peer: 0, echo: 3, link, seen: vec![], limit });
        let e1 = sim.add(EchoSink { seen: vec![] });
        sim.schedule(Time::ZERO, n0, M::Ping(0));
        (sim, [n0, n1], [e0, e1])
    }

    fn trajectories(
        sim: &Sim<M>,
        nodes: [ActorId; 2],
        echoes: [ActorId; 2],
    ) -> Vec<Vec<(Time, u32)>> {
        vec![
            sim.get::<Node>(nodes[0]).seen.clone(),
            sim.get::<Node>(nodes[1]).seen.clone(),
            sim.get::<EchoSink>(echoes[0]).seen.clone(),
            sim.get::<EchoSink>(echoes[1]).seen.clone(),
        ]
    }

    #[test]
    fn partitioned_matches_serial() {
        let link = Time::from_ns(50);
        let until = Time::from_us(100);
        // serial reference
        let (mut serial, nodes, echoes) = build(link, 500);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);
        assert!(!want[0].is_empty());

        // partitioned: node0+echo0 in domain 0, node1+echo1 in domain 1
        let (sim, nodes, echoes) = build(link, 500);
        let owner = vec![0u32, 0, 1, 1];
        let mut part = Partition::split(sim, owner, 2, link);
        part.run_until(until);
        let total = part.processed();
        let merged = part.into_sim();
        assert_eq!(merged.processed(), total);
        assert_eq!(merged.now, until);
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn single_domain_partition_matches_serial() {
        let link = Time::from_ns(10);
        let until = Time::from_us(10);
        let (mut serial, nodes, echoes) = build(link, 100);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        let (sim, nodes, echoes) = build(link, 100);
        let mut part = Partition::split(sim, vec![0, 0, 0, 0], 1, link);
        part.run_until(until);
        let merged = part.into_sim();
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn external_schedules_keep_serial_keys() {
        // scheduling through the partition mid-run must mint the same
        // keys (and thus the same trajectory) as the serial Sim
        let link = Time::from_ns(20);
        let t_mid = Time::from_ns(500);
        let until = Time::from_us(5);

        let (mut serial, nodes, echoes) = build(link, 30);
        serial.run_until(t_mid);
        serial.schedule(t_mid, nodes[1], M::Ping(1000));
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        let (sim, nodes, echoes) = build(link, 30);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link);
        part.run_until(t_mid);
        part.schedule(t_mid, nodes[1], M::Ping(1000));
        part.run_until(until);
        let merged = part.into_sim();
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn run_until_is_resumable() {
        let link = Time::from_ns(40);
        let (sim, nodes, echoes) = build(link, 200);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link);
        let mut total = 0;
        for k in 1..=5u64 {
            total += part.run_until(Time::from_us(4 * k));
        }
        assert_eq!(total, part.processed());

        let (mut serial, n2, e2) = build(link, 200);
        serial.run_until(Time::from_us(20));
        assert_eq!(
            trajectories(&part.into_sim(), nodes, echoes),
            trajectories(&serial, n2, e2)
        );
    }

    #[test]
    #[should_panic(expected = "positive lookahead")]
    fn zero_lookahead_rejected() {
        let (sim, _, _) = build(Time::from_ns(1), 1);
        let _ = Partition::split(sim, vec![0, 0, 1, 1], 2, Time::ZERO);
    }

    #[test]
    #[should_panic(expected = "owner map")]
    fn incomplete_owner_map_rejected() {
        let (sim, _, _) = build(Time::from_ns(1), 1);
        let _ = Partition::split(sim, vec![0, 0], 2, Time::from_ns(1));
    }

    // ---- per-neighbor channel clocks (PR 5) ------------------------------

    /// The two-domain channel graph of the `build` fixture: one link,
    /// both directions.
    fn two_domain_graph(link: Time) -> ChannelGraph {
        ChannelGraph::from_edges(2, [(0u32, 1u32, link), (1, 0, link)])
    }

    #[test]
    fn channel_clocks_match_serial() {
        let link = Time::from_ns(50);
        let until = Time::from_us(100);
        let (mut serial, nodes, echoes) = build(link, 500);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);
        assert!(!want[0].is_empty());

        let (sim, nodes, echoes) = build(link, 500);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link)
            .with_channels(two_domain_graph(link));
        assert_eq!(part.sync_mode(), SyncMode::Channel);
        part.run_until(until);
        let total = part.processed();
        let merged = part.into_sim();
        assert_eq!(merged.processed(), total);
        assert_eq!(merged.now, until);
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn channel_clocks_resumable_with_external_schedules() {
        let link = Time::from_ns(20);
        let t_mid = Time::from_ns(500);
        let until = Time::from_us(5);

        let (mut serial, nodes, echoes) = build(link, 30);
        serial.run_until(t_mid);
        serial.schedule(t_mid, nodes[1], M::Ping(1000));
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        let (sim, nodes, echoes) = build(link, 30);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link)
            .with_channels(two_domain_graph(link));
        part.run_until(t_mid);
        part.schedule(t_mid, nodes[1], M::Ping(1000));
        part.run_until(until);
        let merged = part.into_sim();
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    /// A forwarding chain actor: on Ping(n), record and pass n+1 on.
    struct Relay {
        next: Option<ActorId>,
        delay: Time,
        seen: Vec<(Time, u32)>,
    }

    impl Actor<M> for Relay {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Ping(n) = msg {
                self.seen.push((ctx.now(), n));
                if let Some(next) = self.next {
                    ctx.send(next, self.delay, M::Ping(n + 1));
                }
            }
        }
    }

    /// Chain per-hop latencies for the heterogeneous-lookahead test.
    const CHAIN_DELAYS: [Time; 3] = [Time::from_ns(10), Time::from_ns(200), Time::from_ns(35)];

    fn build_chain(mut edges: Option<&mut Vec<(u32, u32, Time)>>) -> Sim<M> {
        let mut sim: Sim<M> = Sim::with_kind(QueueKind::Wheel);
        for (i, &d) in CHAIN_DELAYS.iter().enumerate() {
            sim.add(Relay { next: Some(i + 1), delay: d, seen: vec![] });
            if let Some(edges) = edges.as_deref_mut() {
                edges.push((i as u32, i as u32 + 1, d));
            }
        }
        sim.add(Relay { next: None, delay: Time::ZERO, seen: vec![] });
        for k in 0..40u64 {
            sim.schedule(Time::from_ns(3 * k), 0, M::Ping(0));
        }
        sim
    }

    /// Four relays in a chain, one domain each, heterogeneous link
    /// latencies: only chain-adjacent domains share a channel, so
    /// non-neighbors are fully decoupled — and the trajectory still
    /// matches the serial run exactly.
    #[test]
    fn channel_chain_with_heterogeneous_lookaheads_matches_serial() {
        let until = Time::from_us(50);
        let mut serial = build_chain(None);
        serial.run_until(until);
        let want: Vec<Vec<(Time, u32)>> =
            (0..4).map(|id| serial.get::<Relay>(id).seen.clone()).collect();
        assert!(!want[3].is_empty());

        let mut edges = Vec::new();
        let sim = build_chain(Some(&mut edges));
        let graph = ChannelGraph::from_edges(4, edges);
        // closure of a 4-chain: every upstream domain reaches every
        // downstream one (3 + 2 + 1 ordered pairs), no cycles
        assert_eq!(graph.n_channels(), 6, "chain closure covers upstream pairs");
        assert_eq!(graph.min_lookahead(), Some(Time::from_ns(10)));
        let want_in_3 = [
            (0u32, (CHAIN_DELAYS[0] + CHAIN_DELAYS[1] + CHAIN_DELAYS[2]).ps()),
            (1, (CHAIN_DELAYS[1] + CHAIN_DELAYS[2]).ps()),
            (2, CHAIN_DELAYS[2].ps()),
        ];
        assert_eq!(graph.in_channels(3), &want_in_3, "path distances accumulate");
        let mut part = Partition::split(sim, vec![0, 1, 2, 3], 4, Time::from_ns(10))
            .with_channels(graph);
        part.run_until(until);
        let merged = part.into_sim();
        let got: Vec<Vec<(Time, u32)>> =
            (0..4).map(|id| merged.get::<Relay>(id).seen.clone()).collect();
        assert_eq!(got, want);
    }

    /// Regression: a chain `0 → 1 → 2` whose *middle* domain is idle.
    /// Domain 2 must not run ahead of a message still routing through
    /// domain 1 — the closure channel `0 ⇝ 2` (distance `2·la`) bounds
    /// it even though domain 1's own EOT is far in the future. A bound
    /// built from direct in-neighbors only would execute domain 2's
    /// far-future local event first and corrupt the trajectory.
    #[test]
    fn channel_transitive_chain_bounds_through_idle_middle() {
        let la = Time::from_ns(10);
        let build3 = || {
            let mut sim: Sim<M> = Sim::with_kind(QueueKind::Wheel);
            sim.add(Relay { next: Some(1), delay: la, seen: vec![] });
            sim.add(Relay { next: Some(2), delay: la, seen: vec![] });
            sim.add(Relay { next: None, delay: Time::ZERO, seen: vec![] });
            sim.schedule(Time::ZERO, 0, M::Ping(0));
            // far-future local event on the last domain: an unsound
            // bound would execute it before the chain message arrives
            sim.schedule(Time::from_us(10), 2, M::Ping(100));
            sim
        };
        let until = Time::from_us(20);
        let mut serial = build3();
        serial.run_until(until);
        let want: Vec<Vec<(Time, u32)>> =
            (0..3).map(|id| serial.get::<Relay>(id).seen.clone()).collect();
        assert_eq!(want[2], vec![(la + la, 2), (Time::from_us(10), 100)]);

        let sim = build3();
        let graph = ChannelGraph::from_edges(3, [(0u32, 1u32, la), (1, 2, la)]);
        let mut part = Partition::split(sim, vec![0, 1, 2], 3, la).with_channels(graph);
        part.run_until(until);
        let merged = part.into_sim();
        let got: Vec<Vec<(Time, u32)>> =
            (0..3).map(|id| merged.get::<Relay>(id).seen.clone()).collect();
        assert_eq!(got, want);
    }

    /// The closure's diagonal: a domain's own sends can bounce back, so
    /// each domain carries a self-channel at the minimum cycle weight.
    #[test]
    fn channel_graph_closure_includes_cycles() {
        let link = Time::from_ns(10);
        let g = two_domain_graph(link);
        assert_eq!(g.n_channels(), 4, "two direct edges + two diagonal cycles");
        let want0 = [(0u32, Time::from_ns(20).ps()), (1, Time::from_ns(10).ps())];
        assert_eq!(g.in_channels(0), &want0);
        let want1 = [(0u32, Time::from_ns(10).ps()), (1, Time::from_ns(20).ps())];
        assert_eq!(g.in_channels(1), &want1);
        assert_eq!(g.min_lookahead(), Some(link));
    }

    /// Regression (PR 5): `Partition::schedule` must compare `at` against
    /// the **destination** domain's clock only. Channel clocks let other
    /// domains run ahead; their pasts are not this event's past.
    #[test]
    fn schedule_checks_only_destination_domain_clock() {
        let link = Time::from_ns(20);
        let (sim, nodes, _) = build(link, 10);
        let mut part = Partition::split(sim, vec![0, 0, 1, 1], 2, link);
        let pending_before = part.pending();
        // domain 1 has drifted ahead; scheduling into domain 0's present
        // is still valid even though it is domain 1's past
        part.domains[1].now = Time::from_us(10);
        part.schedule(Time::from_ns(5), nodes[0], M::Ping(7));
        assert_eq!(part.pending(), pending_before + 1);
    }

    #[test]
    #[should_panic(expected = "does not cover every domain")]
    fn channel_graph_must_cover_every_domain() {
        let link = Time::from_ns(10);
        let (sim, _, _) = build(link, 1);
        let _ = Partition::split(sim, vec![0, 0, 1, 1], 2, link)
            .with_channels(ChannelGraph::from_edges(3, [(0u32, 1u32, link)]));
    }

    #[test]
    #[should_panic(expected = "positive channel lookahead")]
    fn channel_graph_rejects_zero_lookahead() {
        let _ = ChannelGraph::from_edges(2, [(0u32, 1u32, Time::ZERO)]);
    }

    #[test]
    fn channel_graph_takes_min_over_parallel_edges() {
        let g = ChannelGraph::from_edges(
            3,
            [
                (0u32, 1u32, Time::from_ns(40)),
                (0, 1, Time::from_ns(15)),
                (2, 1, Time::from_ns(25)),
            ],
        );
        assert_eq!(g.n_domains(), 3);
        assert_eq!(g.n_channels(), 2, "parallel edges collapse into one channel");
        let want = [(0u32, Time::from_ns(15).ps()), (2, Time::from_ns(25).ps())];
        assert_eq!(g.in_channels(1), &want);
        assert_eq!(g.min_lookahead(), Some(Time::from_ns(15)));
        assert_eq!(ChannelGraph::from_edges(2, []).min_lookahead(), None);
    }

    /// A domain pair with no connecting live link (e.g. severed by the
    /// fault model's dead-from-`t=0` exclusion) simply has no channel:
    /// the closure tolerates disconnected pairs, and a domain with no
    /// in-channels runs unbounded — nothing can reach it.
    #[test]
    fn channel_graph_tolerates_disconnected_domains() {
        let g = ChannelGraph::from_edges(3, [(0u32, 1u32, Time::from_ns(10))]);
        assert_eq!(g.n_channels(), 1, "one edge, no cycles, nothing transitive");
        assert!(g.in_channels(0).is_empty(), "no channel ends at domain 0");
        assert!(g.in_channels(2).is_empty(), "unreachable domain is unbounded");
        assert_eq!(g.min_lookahead(), Some(Time::from_ns(10)));
    }

    #[test]
    fn sync_mode_parse_roundtrip() {
        assert_eq!(SyncMode::parse("window"), Some(SyncMode::Window));
        assert_eq!(SyncMode::parse("channel"), Some(SyncMode::Channel));
        assert_eq!(SyncMode::parse("free"), Some(SyncMode::Free));
        assert_eq!(SyncMode::parse("global"), None);
        for m in SyncMode::ALL {
            assert_eq!(SyncMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(SyncMode::default(), SyncMode::Channel);
        assert!(!SyncMode::Window.needs_channel_graph());
        assert!(SyncMode::Channel.needs_channel_graph());
        assert!(SyncMode::Free.needs_channel_graph());
    }

    // ---- barrier-free channel clocks (sync=free) -------------------------

    /// Build a partition of the `build` fixture in the given sync mode.
    fn partition_in(sim: Sim<M>, link: Time, mode: SyncMode) -> Partition<M> {
        let part = Partition::split(sim, vec![0, 0, 1, 1], 2, link);
        match mode {
            SyncMode::Window => part,
            SyncMode::Channel => part.with_channels(two_domain_graph(link)),
            SyncMode::Free => part.with_channels(two_domain_graph(link)).barrier_free(),
        }
    }

    #[test]
    fn free_clocks_match_serial() {
        let link = Time::from_ns(50);
        let until = Time::from_us(100);
        let (mut serial, nodes, echoes) = build(link, 500);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);
        assert!(!want[0].is_empty());

        let (sim, nodes, echoes) = build(link, 500);
        let mut part = partition_in(sim, link, SyncMode::Free);
        assert_eq!(part.sync_mode(), SyncMode::Free);
        part.run_until(until);
        let total = part.processed();
        let merged = part.into_sim();
        assert_eq!(merged.processed(), total);
        assert_eq!(merged.now, until);
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    #[test]
    fn free_clocks_resumable_with_external_schedules() {
        let link = Time::from_ns(20);
        let t_mid = Time::from_ns(500);
        let until = Time::from_us(5);

        let (mut serial, nodes, echoes) = build(link, 30);
        serial.run_until(t_mid);
        serial.schedule(t_mid, nodes[1], M::Ping(1000));
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        let (sim, nodes, echoes) = build(link, 30);
        let mut part = partition_in(sim, link, SyncMode::Free);
        part.run_until(t_mid);
        part.schedule(t_mid, nodes[1], M::Ping(1000));
        part.run_until(until);
        let merged = part.into_sim();
        assert_eq!(trajectories(&merged, nodes, echoes), want);
    }

    /// Free mode over the heterogeneous 4-domain relay chain, including
    /// the idle-middle transitive-bound regression the closure covers.
    #[test]
    fn free_chain_with_heterogeneous_lookaheads_matches_serial() {
        let until = Time::from_us(50);
        let mut serial = build_chain(None);
        serial.run_until(until);
        let want: Vec<Vec<(Time, u32)>> =
            (0..4).map(|id| serial.get::<Relay>(id).seen.clone()).collect();

        let mut edges = Vec::new();
        let sim = build_chain(Some(&mut edges));
        let graph = ChannelGraph::from_edges(4, edges);
        let mut part = Partition::split(sim, vec![0, 1, 2, 3], 4, Time::from_ns(10))
            .with_channels(graph)
            .barrier_free();
        part.run_until(until);
        let merged = part.into_sim();
        let got: Vec<Vec<(Time, u32)>> =
            (0..4).map(|id| merged.get::<Relay>(id).seen.clone()).collect();
        assert_eq!(got, want);
    }

    /// Liveness regression (the empty-mailbox case barrier modes pay
    /// for): two domains in a ring channel graph with **zero**
    /// cross-domain traffic must both drain their local work and
    /// terminate — no domain may block on its neighbor's EOT, because no
    /// worker ever waits inside an iteration; it just republishes and
    /// rechecks. A hang here fails the test harness by timeout.
    #[test]
    fn free_mode_terminates_with_zero_cross_domain_traffic() {
        let link = Time::from_ns(25);
        let mut sim: Sim<M> = Sim::with_kind(QueueKind::Wheel);
        sim.add(Relay { next: None, delay: Time::ZERO, seen: vec![] });
        sim.add(Relay { next: None, delay: Time::ZERO, seen: vec![] });
        for k in 0..200u64 {
            sim.schedule(Time::from_ns(40 * k), 0, M::Ping(0));
            sim.schedule(Time::from_ns(40 * k + 7), 1, M::Ping(1));
        }
        let mut part = Partition::split(sim, vec![0, 1], 2, link)
            .with_channels(two_domain_graph(link))
            .barrier_free();
        part.run_until(Time::from_us(100));
        assert_eq!(part.processed(), 400, "all local events drained");
        let merged = part.into_sim();
        assert_eq!(merged.get::<Relay>(0).seen.len(), 200);
        assert_eq!(merged.get::<Relay>(1).seen.len(), 200);
    }

    /// Seeded scheduling chaos must not change the trajectory: the
    /// conservative bounds absorb every interleaving the OS (or the
    /// injected yields) can produce.
    #[test]
    fn free_chaos_seeds_do_not_change_trajectory() {
        let link = Time::from_ns(50);
        let until = Time::from_us(100);
        let (mut serial, nodes, echoes) = build(link, 500);
        serial.run_until(until);
        let want = trajectories(&serial, nodes, echoes);

        for seed in [1u64, 0xDEAD_BEEF, u64::MAX] {
            let (sim, nodes, echoes) = build(link, 500);
            let mut part = partition_in(sim, link, SyncMode::Free).with_free_chaos(seed);
            part.run_until(until);
            let merged = part.into_sim();
            assert_eq!(trajectories(&merged, nodes, echoes), want, "seed {seed}");
        }
    }

    #[test]
    #[should_panic(expected = "needs a channel graph")]
    fn barrier_free_without_channels_rejected() {
        let link = Time::from_ns(10);
        let (sim, _, _) = build(link, 1);
        let _ = Partition::split(sim, vec![0, 0, 1, 1], 2, link).barrier_free();
    }

    // ---- barrier poisoning -----------------------------------------------

    /// A poisoned barrier releases spinning waiters with `false` instead
    /// of deadlocking them, and stays poisoned for later arrivals.
    #[test]
    fn poisoned_barrier_releases_waiters() {
        let barrier = SpinBarrier::new(2);
        std::thread::scope(|s| {
            let waiter = s.spawn(|| barrier.wait());
            // give the waiter time to park in its spin loop
            std::thread::sleep(std::time::Duration::from_millis(10));
            barrier.poison();
            assert!(!waiter.join().expect("waiter must not panic"));
        });
        assert!(!barrier.wait(), "poison must be sticky");
    }

    /// An actor that unwinds mid-run: the owning worker must poison the
    /// shared teardown signal — the spin barrier in the round-based
    /// modes, the free-mode poison flag otherwise — so its siblings exit
    /// instead of spinning forever (in free mode, on an EOT that will
    /// never advance), and the panic must propagate out of `run_until`
    /// in **every** sync mode.
    struct Bomb;

    impl Actor<M> for Bomb {
        fn handle(&mut self, _msg: M, _ctx: &mut Ctx<'_, M>) {
            panic!("bomb actor detonated");
        }
    }

    #[test]
    fn panicking_worker_releases_siblings() {
        for mode in SyncMode::ALL {
            let link = Time::from_ns(30);
            let mut sim: Sim<M> = Sim::new();
            let feeder = sim.add(Relay { next: Some(1), delay: link, seen: vec![] });
            let _bomb = sim.add(Bomb);
            for k in 0..10u64 {
                sim.schedule(Time::from_ns(10 * k), feeder, M::Ping(0));
            }
            let mut part = Partition::split(sim, vec![0, 1], 2, link);
            if mode.needs_channel_graph() {
                part = part.with_channels(two_domain_graph(link));
            }
            if mode == SyncMode::Free {
                part = part.barrier_free();
            }
            assert_eq!(part.sync_mode(), mode);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                part.run_until(Time::from_us(1));
            }));
            assert!(result.is_err(), "panic must propagate (mode={})", mode.as_str());
        }
    }

    /// The SPSC queue underneath free mode: FIFO per channel, values
    /// survive producer/consumer interleaving, leftovers freed on drop.
    #[test]
    fn spsc_queue_fifo_across_threads() {
        let q: SpscQueue<u64> = SpscQueue::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                for v in 0..10_000u64 {
                    // safety: sole producer in this test
                    unsafe { q.push(v) };
                }
            });
            s.spawn(|| {
                let mut expect = 0u64;
                while expect < 9_000 {
                    // safety: sole consumer in this test
                    if let Some(v) = unsafe { q.pop() } {
                        assert_eq!(v, expect, "SPSC order violated");
                        expect += 1;
                    } else {
                        std::hint::spin_loop();
                    }
                }
            });
        });
        // remaining ~1000 nodes are freed by Drop (miri/asan would catch
        // a leak or double free here)
    }
}
