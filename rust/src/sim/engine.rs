//! The discrete-event engine: actors, event queue, simulation loop.
//!
//! Components implement [`Actor`] and communicate exclusively via
//! timestamped messages delivered through the [`Sim`]'s event queue.
//! Determinism guarantee: events with equal timestamps are delivered in
//! the order they were scheduled (a monotone sequence number breaks ties),
//! so a given configuration always produces the same trajectory.

use std::any::Any;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::Time;

/// Index of an actor within a [`Sim`].
pub type ActorId = usize;

/// A scheduled message delivery.
#[derive(Debug)]
pub struct Event<M> {
    pub at: Time,
    pub seq: u64,
    pub dst: ActorId,
    pub msg: M,
}

impl<M> PartialEq for Event<M> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<M> Eq for Event<M> {}

impl<M> PartialOrd for Event<M> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<M> Ord for Event<M> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Fixed-size heap entry: the message payload lives in a slab so that heap
/// sift operations move 24 bytes instead of the full `M` (40% of a traffic
/// simulation's time went into `BinaryHeap::pop` before this — see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, PartialEq, Eq)]
struct HeapEntry {
    at: Time,
    seq: u64,
    dst: u32,
    slot: u32,
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Priority queue of pending events (earliest timestamp first, FIFO ties).
#[derive(Debug)]
pub struct EventQueue<M> {
    heap: BinaryHeap<HeapEntry>,
    slab: Vec<Option<M>>,
    free: Vec<u32>,
    seq: u64,
}

impl<M> Default for EventQueue<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> EventQueue<M> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, at: Time, dst: ActorId, msg: M) {
        let seq = self.seq;
        self.seq += 1;
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(msg);
                s
            }
            None => {
                self.slab.push(Some(msg));
                (self.slab.len() - 1) as u32
            }
        };
        self.heap.push(HeapEntry {
            at,
            seq,
            dst: dst as u32,
            slot,
        });
    }

    pub fn pop(&mut self) -> Option<Event<M>> {
        let e = self.heap.pop()?;
        let msg = self.slab[e.slot as usize]
            .take()
            .expect("slab slot empty");
        self.free.push(e.slot);
        Some(Event {
            at: e.at,
            seq: e.seq,
            dst: e.dst as usize,
            msg,
        })
    }

    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Scheduling context handed to an actor while it handles a message.
pub struct Ctx<'a, M> {
    now: Time,
    self_id: ActorId,
    queue: &'a mut EventQueue<M>,
}

impl<'a, M> Ctx<'a, M> {
    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// The id of the actor currently handling a message.
    pub fn self_id(&self) -> ActorId {
        self.self_id
    }

    /// Deliver `msg` to `dst` after `delay`.
    pub fn send(&mut self, dst: ActorId, delay: Time, msg: M) {
        self.queue.push(self.now + delay, dst, msg);
    }

    /// Deliver `msg` to `dst` at absolute time `at` (must be ≥ now).
    pub fn send_at(&mut self, dst: ActorId, at: Time, msg: M) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.queue.push(at.max(self.now), dst, msg);
    }

    /// Schedule a message to self (timers, clock ticks).
    pub fn send_self(&mut self, delay: Time, msg: M) {
        let id = self.self_id;
        self.send(id, delay, msg);
    }
}

/// A simulation component. `handle` consumes one message and may schedule
/// any number of future messages via the context.
pub trait Actor<M>: Any {
    fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>);

    /// Human-readable name for traces and error messages.
    fn name(&self) -> String {
        "actor".to_string()
    }
}

/// The simulation: a set of actors plus the event queue and clock.
pub struct Sim<M> {
    pub now: Time,
    actors: Vec<Box<dyn Actor<M>>>,
    queue: EventQueue<M>,
    processed: u64,
    /// Optional diagnostic hook invoked on every dispatched message.
    tracer: Option<Box<dyn FnMut(&M)>>,
}

impl<M: 'static> Default for Sim<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M: 'static> Sim<M> {
    pub fn new() -> Self {
        Sim {
            now: Time::ZERO,
            actors: Vec::new(),
            queue: EventQueue::new(),
            processed: 0,
            tracer: None,
        }
    }

    /// Register an actor; returns its id for message addressing.
    pub fn add(&mut self, actor: impl Actor<M>) -> ActorId {
        self.actors.push(Box::new(actor));
        self.actors.len() - 1
    }

    /// Register a pre-boxed actor.
    pub fn add_boxed(&mut self, actor: Box<dyn Actor<M>>) -> ActorId {
        self.actors.push(actor);
        self.actors.len() - 1
    }

    pub fn n_actors(&self) -> usize {
        self.actors.len()
    }

    /// Schedule an initial message from outside the simulation.
    pub fn schedule(&mut self, at: Time, dst: ActorId, msg: M) {
        debug_assert!(at >= self.now);
        self.queue.push(at, dst, msg);
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Timestamp of the next pending event.
    pub fn next_time(&self) -> Option<Time> {
        self.queue.peek_time()
    }

    /// Install a diagnostic tracer called with every dispatched message.
    pub fn set_tracer(&mut self, f: impl FnMut(&M) + 'static) {
        self.tracer = Some(Box::new(f));
    }

    /// Process exactly one event. Returns false if the queue is empty.
    pub fn step(&mut self) -> bool {
        let Some(ev) = self.queue.pop() else {
            return false;
        };
        if let Some(t) = &mut self.tracer {
            t(&ev.msg);
        }
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        let actor = self
            .actors
            .get_mut(ev.dst)
            .unwrap_or_else(|| panic!("message to unknown actor {}", ev.dst));
        let mut ctx = Ctx {
            now: ev.at,
            self_id: ev.dst,
            queue: &mut self.queue,
        };
        actor.handle(ev.msg, &mut ctx);
        self.processed += 1;
        true
    }

    /// Run until the queue is empty or `limit` events were processed.
    /// Returns the number of events processed in this call.
    pub fn run(&mut self, limit: u64) -> u64 {
        let start = self.processed;
        while self.processed - start < limit {
            if !self.step() {
                break;
            }
        }
        self.processed - start
    }

    /// Process all events with timestamp ≤ `until`, then set the clock to
    /// `until`. Returns the number of events processed.
    pub fn run_until(&mut self, until: Time) -> u64 {
        let start = self.processed;
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            self.step();
        }
        self.now = self.now.max(until);
        self.processed - start
    }

    /// Drain the queue completely (careful: self-perpetuating actors never
    /// terminate; prefer `run_until`). Returns events processed.
    pub fn run_to_completion(&mut self) -> u64 {
        let start = self.processed;
        while self.step() {}
        self.processed - start
    }

    /// Typed access to an actor (post-run metric collection).
    pub fn get<T: Actor<M>>(&self, id: ActorId) -> &T {
        (self.actors[id].as_ref() as &dyn Any)
            .downcast_ref::<T>()
            .unwrap_or_else(|| panic!("actor {id} is not a {}", std::any::type_name::<T>()))
    }

    /// Typed mutable access to an actor.
    pub fn get_mut<T: Actor<M>>(&mut self, id: ActorId) -> &mut T {
        (self.actors[id].as_mut() as &mut dyn Any)
            .downcast_mut::<T>()
            .unwrap_or_else(|| panic!("actor {id} is not a {}", std::any::type_name::<T>()))
    }

    /// Try typed access (None if the id holds a different type).
    pub fn try_get<T: Actor<M>>(&self, id: ActorId) -> Option<&T> {
        (self.actors[id].as_ref() as &dyn Any).downcast_ref::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::Time;

    #[derive(Debug, Clone, PartialEq)]
    enum TestMsg {
        Ping(u32),
        Tick,
    }

    /// Records every delivery with its timestamp.
    struct Recorder {
        seen: Vec<(Time, TestMsg)>,
    }

    impl Actor<TestMsg> for Recorder {
        fn handle(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
            self.seen.push((ctx.now(), msg));
        }
    }

    /// Forwards each Ping to a peer with +1 and 10ns delay, up to 5.
    struct Forwarder {
        peer: ActorId,
        sent: u32,
    }

    impl Actor<TestMsg> for Forwarder {
        fn handle(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
            if let TestMsg::Ping(n) = msg {
                if n < 5 {
                    ctx.send(self.peer, Time::from_ns(10), TestMsg::Ping(n + 1));
                    self.sent += 1;
                }
            }
        }
    }

    #[test]
    fn delivery_order_is_time_then_fifo() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        sim.schedule(Time::from_ns(20), rec, TestMsg::Ping(2));
        sim.schedule(Time::from_ns(10), rec, TestMsg::Ping(1));
        sim.schedule(Time::from_ns(20), rec, TestMsg::Ping(3)); // same time: after Ping(2)
        sim.run_to_completion();
        let r: &Recorder = sim.get(rec);
        assert_eq!(
            r.seen,
            vec![
                (Time::from_ns(10), TestMsg::Ping(1)),
                (Time::from_ns(20), TestMsg::Ping(2)),
                (Time::from_ns(20), TestMsg::Ping(3)),
            ]
        );
    }

    #[test]
    fn ping_pong_chain() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        let fwd = sim.add(Forwarder { peer: rec, sent: 0 });
        // drive the forwarder via self-chain: rec gets 1..=5
        // fwd forwards Ping(n)->rec; also need fwd to receive pings
        sim.schedule(Time::ZERO, fwd, TestMsg::Ping(0));
        sim.schedule(Time::from_ns(10), fwd, TestMsg::Ping(1));
        sim.schedule(Time::from_ns(20), fwd, TestMsg::Ping(2));
        sim.run_to_completion();
        let f: &Forwarder = sim.get(fwd);
        assert_eq!(f.sent, 3);
        let r: &Recorder = sim.get(rec);
        assert_eq!(r.seen.len(), 3);
        assert_eq!(r.seen[0], (Time::from_ns(10), TestMsg::Ping(1)));
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        for i in 0..10 {
            sim.schedule(Time::from_ns(i * 10), rec, TestMsg::Tick);
        }
        let n = sim.run_until(Time::from_ns(45));
        assert_eq!(n, 5); // t = 0,10,20,30,40
        assert_eq!(sim.now, Time::from_ns(45));
        assert_eq!(sim.pending(), 5);
        let n = sim.run_until(Time::from_ns(1000));
        assert_eq!(n, 5);
    }

    #[test]
    fn run_limit() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        for i in 0..100 {
            sim.schedule(Time::from_ns(i), rec, TestMsg::Tick);
        }
        assert_eq!(sim.run(30), 30);
        assert_eq!(sim.processed(), 30);
        assert_eq!(sim.pending(), 70);
    }

    #[test]
    fn clock_monotone() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        sim.schedule(Time::from_ns(5), rec, TestMsg::Tick);
        sim.schedule(Time::from_ns(1), rec, TestMsg::Tick);
        let mut last = Time::ZERO;
        while sim.step() {
            assert!(sim.now >= last);
            last = sim.now;
        }
    }

    #[test]
    fn self_messages() {
        struct Timer {
            fires: u32,
        }
        impl Actor<TestMsg> for Timer {
            fn handle(&mut self, _m: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
                self.fires += 1;
                if self.fires < 4 {
                    ctx.send_self(Time::from_ns(100), TestMsg::Tick);
                }
            }
        }
        let mut sim = Sim::new();
        let t = sim.add(Timer { fires: 0 });
        sim.schedule(Time::ZERO, t, TestMsg::Tick);
        sim.run_to_completion();
        assert_eq!(sim.get::<Timer>(t).fires, 4);
        assert_eq!(sim.now, Time::from_ns(300));
    }

    #[test]
    #[should_panic(expected = "not a")]
    fn typed_access_panics_on_wrong_type() {
        let mut sim: Sim<TestMsg> = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        let _ = sim.get::<Forwarder>(rec);
    }

    #[test]
    fn try_get_returns_none_on_wrong_type() {
        let mut sim: Sim<TestMsg> = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        assert!(sim.try_get::<Forwarder>(rec).is_none());
        assert!(sim.try_get::<Recorder>(rec).is_some());
    }
}
