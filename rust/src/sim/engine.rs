//! The discrete-event engine: actors, event queue, simulation loop.
//!
//! Components implement [`Actor`] and communicate exclusively via
//! timestamped messages delivered through the [`Sim`]'s event queue.
//! The full event-ordering and determinism contract is documented in
//! `docs/ARCHITECTURE.md`; in short:
//!
//! - events are delivered in nondecreasing `(timestamp, key)` order among
//!   the events currently pending,
//! - the tie-break `key` is a **partition-independent merge key**: the
//!   sending actor's id plus that actor's private send counter (external
//!   [`Sim::schedule`] calls use a reserved source id and their own
//!   counter). Because the key depends only on *who* sent a message and
//!   *how many* messages that sender emitted before it — never on how
//!   sends from different actors interleave in wall-clock execution — a
//!   trajectory is reproduced exactly whether the actors run in one
//!   [`Sim`] or are spread across the domains of a
//!   [`super::pdes::Partition`].
//!
//! Two interchangeable queue backends implement that contract (selected
//! by [`QueueKind`], A/B-benchmarked in `benches/bench_events.rs` — see
//! PERF.md):
//!
//! - [`QueueKind::Heap`] — a slab-backed binary heap, O(log n) per
//!   operation; the reference implementation.
//! - [`QueueKind::Wheel`] — a calendar queue (timing wheel) keyed on
//!   picosecond buckets. Spike traffic schedules almost everything within
//!   a few µs of "now", the classic O(1)-amortized sweet spot; far-future
//!   events overflow into a small auxiliary heap and are promoted as the
//!   cursor approaches them.

use std::any::Any;
use std::cell::Cell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::Time;

/// Index of an actor within a [`Sim`].
pub type ActorId = usize;

/// Number of low bits of a merge key holding the per-source send counter.
const KEY_CNT_BITS: u32 = 40;

/// Reserved merge-key source id for events scheduled from outside the
/// simulation ([`Sim::schedule`] and `Partition::schedule`). Also the
/// exclusive upper bound on actor ids (enforced by [`Sim::add`]).
pub(crate) const EXTERNAL_SRC: u64 = (1 << (64 - KEY_CNT_BITS)) - 1;

/// Compose the deterministic merge key for the `cnt`-th send of source
/// `src`: keys order ties by source id, then FIFO per source. See the
/// module docs (and `docs/ARCHITECTURE.md`) for why this key — unlike a
/// global push counter — is identical across PDES domain partitionings.
pub(crate) fn merge_key(src: u64, cnt: u64) -> u64 {
    debug_assert!(src <= EXTERNAL_SRC, "source id {src} overflows key space");
    debug_assert!(cnt < 1 << KEY_CNT_BITS, "send counter overflow for {src}");
    (src << KEY_CNT_BITS) | cnt
}

/// A scheduled message delivery. `seq` is the deterministic merge key
/// (source id ‖ per-source counter) that breaks timestamp ties.
#[derive(Debug)]
pub struct Event<M> {
    pub at: Time,
    pub seq: u64,
    pub dst: ActorId,
    pub msg: M,
}

impl<M> PartialEq for Event<M> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<M> Eq for Event<M> {}

impl<M> PartialOrd for Event<M> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<M> Ord for Event<M> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Which pending-event structure a [`Sim`] uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// Slab-backed binary heap: O(log n) push/pop. The reference
    /// implementation every other backend must match event-for-event.
    Heap,
    /// Calendar queue / timing wheel: amortized O(1) for workloads whose
    /// events cluster in time (spike traffic does). The default.
    #[default]
    Wheel,
}

impl QueueKind {
    pub fn parse(s: &str) -> Option<QueueKind> {
        match s {
            "heap" => Some(QueueKind::Heap),
            "wheel" => Some(QueueKind::Wheel),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            QueueKind::Heap => "heap",
            QueueKind::Wheel => "wheel",
        }
    }
}

/// Fixed-size queue entry: the message payload lives in a slab so that
/// heap sifts and bucket moves shuffle 24 bytes instead of the full `M`
/// (40% of a traffic simulation's time went into `BinaryHeap::pop`
/// before this — see PERF.md §Methodology).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct QueueEntry {
    at: Time,
    seq: u64,
    dst: u32,
    slot: u32,
}

impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// log2 of the wheel bucket width in picoseconds (8.192 ns per bucket).
const WHEEL_BUCKET_PS_LOG2: u32 = 13;
/// log2 of the bucket count (8192 buckets ≈ 67 µs horizon).
const WHEEL_N_BUCKETS_LOG2: u32 = 13;

/// Calendar-queue backend. Entries within the horizon live in
/// per-bucket vectors kept sorted latest-first (so the earliest entry is
/// a `Vec::pop` away); entries beyond it wait in an overflow heap and
/// are promoted as the cursor advances into their revolution.
///
/// Invariants (maintained by `push`/`pop`/`promote`):
/// - every in-wheel entry has `bucket_of(at) ∈ [cursor, cursor + N)`,
/// - every overflow entry has `bucket_of(at) ≥ cursor + N`,
/// - `cursor` never moves backwards (events are never scheduled into the
///   past, which `Ctx::send`/`Sim::schedule` enforce upstream).
///
/// Together these guarantee the earliest (time, seq) pair overall is the
/// last element of the first non-empty bucket at or after `cursor` — so
/// pop order is identical to the heap backend's.
#[derive(Debug)]
struct Wheel {
    buckets: Vec<Vec<QueueEntry>>,
    /// Absolute bucket index (`at.ps() >> WHEEL_BUCKET_PS_LOG2`) the
    /// drain cursor is currently parked on.
    cursor: u64,
    /// Entries at least one full revolution ahead, earliest first.
    overflow: BinaryHeap<QueueEntry>,
    /// Number of entries stored in `buckets` (excludes `overflow`).
    in_wheel: usize,
    /// Scan hint: no in-wheel entry has a bucket in `[cursor, hint)`.
    /// `peek_time` records how far it scanned so the following `pop`
    /// (e.g. `Sim::run_until`'s peek-then-step loop) skips the empty
    /// prefix instead of walking it twice. `Cell` because peek is `&self`.
    hint: Cell<u64>,
}

impl Wheel {
    fn new() -> Wheel {
        Wheel {
            buckets: (0..(1usize << WHEEL_N_BUCKETS_LOG2))
                .map(|_| Vec::new())
                .collect(),
            cursor: 0,
            overflow: BinaryHeap::new(),
            in_wheel: 0,
            hint: Cell::new(0),
        }
    }

    fn bucket_of(at: Time) -> u64 {
        at.ps() >> WHEEL_BUCKET_PS_LOG2
    }

    fn len(&self) -> usize {
        self.in_wheel + self.overflow.len()
    }

    fn push(&mut self, e: QueueEntry) {
        let n = self.buckets.len() as u64;
        // A past-dated entry (impossible via Ctx/Sim, but cheap to be safe)
        // clamps into the cursor bucket; in-bucket (time, seq) ordering
        // still delivers it first.
        let b = Self::bucket_of(e.at).max(self.cursor);
        if b >= self.cursor + n {
            self.overflow.push(e);
        } else {
            self.insert_bucket(b, e);
        }
    }

    fn insert_bucket(&mut self, b: u64, e: QueueEntry) {
        if b < self.hint.get() {
            self.hint.set(b);
        }
        let mask = self.buckets.len() as u64 - 1;
        let v = &mut self.buckets[(b & mask) as usize];
        // Sorted latest-first; the common case (monotonically increasing
        // times within a bucket) inserts at the front of a short vector.
        let p = v.partition_point(|x| (x.at, x.seq) > (e.at, e.seq));
        v.insert(p, e);
        self.in_wheel += 1;
    }

    /// Move overflow entries whose revolution the cursor has reached into
    /// their buckets.
    fn promote(&mut self) {
        let n = self.buckets.len() as u64;
        while let Some(top) = self.overflow.peek() {
            let b = Self::bucket_of(top.at);
            if b >= self.cursor + n {
                break;
            }
            let e = self.overflow.pop().expect("peeked entry vanished");
            self.insert_bucket(b.max(self.cursor), e);
        }
    }

    fn pop(&mut self) -> Option<QueueEntry> {
        if self.in_wheel == 0 {
            // Jump the cursor straight to the earliest far-future entry.
            let top = self.overflow.peek()?;
            self.cursor = Self::bucket_of(top.at);
            self.promote();
        }
        // Skip the empty prefix a preceding peek already scanned.
        if self.hint.get() > self.cursor {
            self.cursor = self.hint.get();
            self.promote();
        }
        let mask = self.buckets.len() as u64 - 1;
        loop {
            if let Some(e) = self.buckets[(self.cursor & mask) as usize].pop() {
                self.in_wheel -= 1;
                self.hint.set(self.cursor);
                return Some(e);
            }
            self.cursor += 1;
            self.promote();
        }
    }

    fn peek_time(&self) -> Option<Time> {
        if self.in_wheel == 0 {
            return self.overflow.peek().map(|e| e.at);
        }
        let n = self.buckets.len() as u64;
        let mask = n - 1;
        let start = self.cursor.max(self.hint.get());
        for d in 0..n {
            let b = start + d;
            if let Some(e) = self.buckets[(b & mask) as usize].last() {
                self.hint.set(b);
                return Some(e.at);
            }
        }
        unreachable!("in_wheel > 0 but no bucket holds an entry")
    }
}

/// Backend storage behind [`EventQueue`].
#[derive(Debug)]
enum Backend {
    Heap(BinaryHeap<QueueEntry>),
    Wheel(Wheel),
}

/// Priority queue of pending events (earliest timestamp first, FIFO ties).
#[derive(Debug)]
pub struct EventQueue<M> {
    backend: Backend,
    slab: Vec<Option<M>>,
    free: Vec<u32>,
    seq: u64,
}

impl<M> Default for EventQueue<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> EventQueue<M> {
    pub fn new() -> Self {
        Self::with_kind(QueueKind::default())
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        Self::with_capacity(kind, 0)
    }

    /// Pre-size the payload slab (and the heap, where applicable) for an
    /// expected number of simultaneously pending events, so warmup does
    /// not grow the slab one reallocation at a time.
    pub fn with_capacity(kind: QueueKind, capacity: usize) -> Self {
        let backend = match kind {
            QueueKind::Heap => Backend::Heap(BinaryHeap::with_capacity(capacity)),
            QueueKind::Wheel => Backend::Wheel(Wheel::new()),
        };
        EventQueue {
            backend,
            slab: Vec::with_capacity(capacity),
            free: Vec::with_capacity(capacity),
            seq: 0,
        }
    }

    /// Which backend this queue runs on.
    pub fn kind(&self) -> QueueKind {
        match &self.backend {
            Backend::Heap(_) => QueueKind::Heap,
            Backend::Wheel(_) => QueueKind::Wheel,
        }
    }

    /// Current payload-slab capacity (diagnostics / pre-sizing tests).
    pub fn capacity(&self) -> usize {
        self.slab.capacity()
    }

    /// Push with an auto-assigned key (monotone insertion counter): ties
    /// drain FIFO. This is the standalone-queue API (benches, fuzz tests);
    /// [`Sim`] always pushes through the crate-internal `push_keyed` with
    /// a partition-independent merge key, and the two must not be mixed
    /// on one queue (auto keys could collide with keyed ones).
    pub fn push(&mut self, at: Time, dst: ActorId, msg: M) {
        let seq = self.seq;
        self.seq += 1;
        self.push_keyed(at, seq, dst, msg);
    }

    /// Push with an explicit merge key (see [`merge_key`]).
    pub(crate) fn push_keyed(&mut self, at: Time, key: u64, dst: ActorId, msg: M) {
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(msg);
                s
            }
            None => {
                self.slab.push(Some(msg));
                (self.slab.len() - 1) as u32
            }
        };
        let e = QueueEntry {
            at,
            seq: key,
            dst: dst as u32,
            slot,
        };
        match &mut self.backend {
            Backend::Heap(h) => h.push(e),
            Backend::Wheel(w) => w.push(e),
        }
    }

    pub fn pop(&mut self) -> Option<Event<M>> {
        let e = match &mut self.backend {
            Backend::Heap(h) => h.pop()?,
            Backend::Wheel(w) => w.pop()?,
        };
        let msg = self.slab[e.slot as usize]
            .take()
            .expect("slab slot empty");
        self.free.push(e.slot);
        Some(Event {
            at: e.at,
            seq: e.seq,
            dst: e.dst as usize,
            msg,
        })
    }

    pub fn peek_time(&self) -> Option<Time> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|e| e.at),
            Backend::Wheel(w) => w.peek_time(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Wheel(w) => w.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A message bound for an actor owned by another PDES domain, captured in
/// the sending domain's outbox and exchanged at the next window barrier
/// (see [`super::pdes::Partition`]).
#[derive(Debug)]
pub(crate) struct Outgoing<M> {
    pub at: Time,
    pub key: u64,
    pub dst: ActorId,
    pub msg: M,
}

/// Per-domain routing state of a partitioned [`Sim`]: the global
/// actor → domain ownership map, this domain's id, and the outbox of
/// cross-domain messages produced since the last barrier.
pub(crate) struct DomainCtx<M> {
    pub owner: std::sync::Arc<Vec<u32>>,
    pub me: u32,
    pub outbox: Vec<Outgoing<M>>,
}

/// Scheduling context handed to an actor while it handles a message.
pub struct Ctx<'a, M> {
    now: Time,
    self_id: ActorId,
    queue: &'a mut EventQueue<M>,
    /// The handling actor's private send counter (merge-key low bits).
    send_cnt: &'a mut u64,
    /// Cross-domain routing (None when the whole system runs in one Sim).
    domain: Option<&'a mut DomainCtx<M>>,
}

impl<'a, M> Ctx<'a, M> {
    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// The id of the actor currently handling a message.
    pub fn self_id(&self) -> ActorId {
        self.self_id
    }

    /// Deliver `msg` to `dst` after `delay`.
    pub fn send(&mut self, dst: ActorId, delay: Time, msg: M) {
        let at = self.now + delay;
        self.push(dst, at, msg);
    }

    /// Deliver `msg` to `dst` at absolute time `at` (must be ≥ now).
    pub fn send_at(&mut self, dst: ActorId, at: Time, msg: M) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.push(dst, at.max(self.now), msg);
    }

    /// Schedule a message to self (timers, clock ticks).
    pub fn send_self(&mut self, delay: Time, msg: M) {
        let id = self.self_id;
        self.send(id, delay, msg);
    }

    fn push(&mut self, dst: ActorId, at: Time, msg: M) {
        let key = merge_key(self.self_id as u64, *self.send_cnt);
        *self.send_cnt += 1;
        match &mut self.domain {
            Some(d) if d.owner[dst] != d.me => d.outbox.push(Outgoing { at, key, dst, msg }),
            _ => self.queue.push_keyed(at, key, dst, msg),
        }
    }
}

/// Where an actor must live when the simulation is partitioned into PDES
/// domains (returned by [`Actor::placement`]). Sites are abstract indices;
/// the Extoll layer uses the torus node address
/// ([`crate::extoll::torus::NodeAddr`]`.0`) as the site id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// No placement constraint; such an actor cannot take part in a
    /// partitioned run (the partitioning driver rejects it).
    Free,
    /// Same domain as another actor (e.g. a generator rides with the FPGA
    /// it feeds — they exchange zero-latency messages).
    With(ActorId),
    /// A physical site (torus node) mapped to a domain by the partitioner.
    Site(u32),
}

/// A simulation component. `handle` consumes one message and may schedule
/// any number of future messages via the context.
///
/// `Send` is part of the contract: partitioned runs move each domain's
/// actors onto a worker thread (actors hold plain state, never shared
/// references, so this is automatic in practice).
pub trait Actor<M>: Any + Send {
    fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>);

    /// Human-readable name for traces and error messages.
    fn name(&self) -> String {
        "actor".to_string()
    }

    /// Domain-placement constraint for partitioned (PDES) execution; see
    /// [`Placement`]. Actors that exchange sub-lookahead-latency messages
    /// must resolve to the same site.
    fn placement(&self) -> Placement {
        Placement::Free
    }

    /// Restore this actor to its just-constructed state, keeping wiring
    /// (neighbor/uplink actor ids) intact, so a built simulation can be
    /// reused across executes via [`Sim::reset_to_epoch`] instead of being
    /// rebuilt. Returns `false` (the default) for actors that do not
    /// support reuse — one such actor makes the whole reset bail, and the
    /// caller falls back to a cold rebuild. An implementation returning
    /// `true` must leave the actor byte-identical to a fresh construction
    /// plus wiring: the reuse determinism gates
    /// (`rust/tests/reset_reuse.rs`, the `DiffMatrix` reuse axis) compare
    /// whole reports for equality.
    fn reset(&mut self) -> bool {
        false
    }
}

/// Snapshot of the [`Sim`] shape taken right after construction
/// ([`Sim::mark_epoch`]), sufficient for [`Sim::reset_to_epoch`] to
/// restore the simulation to its pre-run state without dropping actors.
#[derive(Clone, Copy, Debug)]
pub struct SimEpoch {
    /// Actor count at the epoch; actors added later (e.g. per-execute
    /// traffic generators) are dropped by the reset.
    pub n_actors: usize,
    /// Queue backend to restore.
    pub kind: QueueKind,
    /// Payload-slab capacity to restore (a merged post-PDES queue may
    /// have lost its pre-sizing; the epoch remembers it).
    pub capacity: usize,
}

/// The moveable state of a [`Sim`], used by [`super::pdes::Partition`] to
/// split a built simulation into per-domain instances and to merge them
/// back for post-run metric collection.
pub(crate) struct SimParts<M> {
    pub now: Time,
    pub actors: Vec<Option<Box<dyn Actor<M>>>>,
    pub queue: EventQueue<M>,
    pub processed: u64,
    pub send_seq: Vec<u64>,
    pub ext_seq: u64,
}

/// The simulation: a set of actors plus the event queue and clock.
///
/// In a partitioned (PDES) run there is one `Sim` per torus domain; actor
/// ids stay **global** — slots owned by other domains are `None`, and
/// sends addressed to them are diverted into the domain outbox.
pub struct Sim<M> {
    pub now: Time,
    actors: Vec<Option<Box<dyn Actor<M>>>>,
    queue: EventQueue<M>,
    processed: u64,
    /// Optional diagnostic hook invoked on every dispatched message.
    tracer: Option<Box<dyn FnMut(&M) + Send>>,
    /// Per-actor send counters (merge-key low bits), indexed by actor id.
    send_seq: Vec<u64>,
    /// Counter for externally scheduled events ([`Sim::schedule`]).
    ext_seq: u64,
    /// Cross-domain routing state (None outside partitioned runs).
    domain: Option<DomainCtx<M>>,
}

impl<M: 'static> Default for Sim<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M: 'static> Sim<M> {
    pub fn new() -> Self {
        Self::with_queue(EventQueue::new())
    }

    /// A simulation on the given queue backend.
    pub fn with_kind(kind: QueueKind) -> Self {
        Self::with_queue(EventQueue::with_kind(kind))
    }

    /// A simulation on a pre-configured (e.g. pre-sized) event queue.
    pub fn with_queue(queue: EventQueue<M>) -> Self {
        Sim {
            now: Time::ZERO,
            actors: Vec::new(),
            queue,
            processed: 0,
            tracer: None,
            send_seq: Vec::new(),
            ext_seq: 0,
            domain: None,
        }
    }

    /// Which queue backend this simulation runs on.
    pub fn queue_kind(&self) -> QueueKind {
        self.queue.kind()
    }

    /// Register an actor; returns its id for message addressing.
    pub fn add(&mut self, actor: impl Actor<M>) -> ActorId {
        self.add_boxed(Box::new(actor))
    }

    /// Register a pre-boxed actor.
    pub fn add_boxed(&mut self, actor: Box<dyn Actor<M>>) -> ActorId {
        assert!((self.actors.len() as u64) < EXTERNAL_SRC, "actor id space exhausted");
        self.actors.push(Some(actor));
        self.send_seq.push(0);
        self.actors.len() - 1
    }

    pub fn n_actors(&self) -> usize {
        self.actors.len()
    }

    /// Placement constraint of an actor (None for remote slots).
    pub(crate) fn placement_of(&self, id: ActorId) -> Option<Placement> {
        self.actors[id].as_ref().map(|a| a.placement())
    }

    /// Schedule an initial message from outside the simulation.
    pub fn schedule(&mut self, at: Time, dst: ActorId, msg: M) {
        debug_assert!(at >= self.now);
        let key = merge_key(EXTERNAL_SRC, self.ext_seq);
        self.ext_seq += 1;
        if let Some(d) = &self.domain {
            // cross-domain external schedules go through Partition::schedule
            debug_assert_eq!(d.owner[dst], d.me, "domain does not own actor {dst}");
        }
        self.queue.push_keyed(at, key, dst, msg);
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Timestamp of the next pending event.
    pub fn next_time(&self) -> Option<Time> {
        self.queue.peek_time()
    }

    /// Install a diagnostic tracer called with every dispatched message.
    pub fn set_tracer(&mut self, f: impl FnMut(&M) + Send + 'static) {
        self.tracer = Some(Box::new(f));
    }

    /// Process exactly one event. Returns false if the queue is empty.
    pub fn step(&mut self) -> bool {
        let Some(ev) = self.queue.pop() else {
            return false;
        };
        if let Some(t) = &mut self.tracer {
            t(&ev.msg);
        }
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        let actor = match self.actors.get_mut(ev.dst) {
            Some(Some(a)) => a,
            Some(None) => panic!("message to non-local actor {} (PDES routing bug)", ev.dst),
            None => panic!("message to unknown actor {}", ev.dst),
        };
        let mut ctx = Ctx {
            now: ev.at,
            self_id: ev.dst,
            queue: &mut self.queue,
            send_cnt: &mut self.send_seq[ev.dst],
            domain: self.domain.as_mut(),
        };
        actor.handle(ev.msg, &mut ctx);
        self.processed += 1;
        true
    }

    /// Run until the queue is empty or `limit` events were processed.
    /// Returns the number of events processed in this call.
    pub fn run(&mut self, limit: u64) -> u64 {
        let start = self.processed;
        while self.processed - start < limit {
            if !self.step() {
                break;
            }
        }
        self.processed - start
    }

    /// Process all events with timestamp ≤ `until`, then set the clock to
    /// `until`. Returns the number of events processed.
    pub fn run_until(&mut self, until: Time) -> u64 {
        let start = self.processed;
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            self.step();
        }
        self.now = self.now.max(until);
        self.processed - start
    }

    /// Process all events with timestamp **strictly before** `bound`; the
    /// clock is left at the last processed event. This is the PDES window
    /// primitive: a domain may only execute below its conservative bound
    /// `min(neighbor clocks) + lookahead`, exclusive, because a
    /// cross-domain message can arrive *at* the bound but never below it.
    pub fn run_before(&mut self, bound: Time) -> u64 {
        let start = self.processed;
        while let Some(t) = self.queue.peek_time() {
            if t >= bound {
                break;
            }
            self.step();
        }
        self.processed - start
    }

    /// Drain the queue completely (careful: self-perpetuating actors never
    /// terminate; prefer `run_until`). Returns events processed.
    pub fn run_to_completion(&mut self) -> u64 {
        let start = self.processed;
        while self.step() {}
        self.processed - start
    }

    /// Typed access to an actor (post-run metric collection).
    pub fn get<T: Actor<M>>(&self, id: ActorId) -> &T {
        let a = self.actors[id]
            .as_ref()
            .unwrap_or_else(|| panic!("actor {id} is not local to this domain"));
        (a.as_ref() as &dyn Any)
            .downcast_ref::<T>()
            .unwrap_or_else(|| panic!("actor {id} is not a {}", std::any::type_name::<T>()))
    }

    /// Typed mutable access to an actor.
    pub fn get_mut<T: Actor<M>>(&mut self, id: ActorId) -> &mut T {
        let a = self.actors[id]
            .as_mut()
            .unwrap_or_else(|| panic!("actor {id} is not local to this domain"));
        (a.as_mut() as &mut dyn Any)
            .downcast_mut::<T>()
            .unwrap_or_else(|| panic!("actor {id} is not a {}", std::any::type_name::<T>()))
    }

    /// Try typed access (None if the id holds a different type or the
    /// actor lives in another PDES domain).
    pub fn try_get<T: Actor<M>>(&self, id: ActorId) -> Option<&T> {
        (self.actors[id].as_ref()?.as_ref() as &dyn Any).downcast_ref::<T>()
    }

    // ---- epoch reset (System reuse across executes) ----------------------

    /// Capture the current shape as an epoch for [`Sim::reset_to_epoch`].
    /// Call right after construction/wiring, before any per-run actors
    /// (generators) are added or events scheduled.
    pub fn mark_epoch(&self) -> SimEpoch {
        SimEpoch {
            n_actors: self.actors.len(),
            kind: self.queue.kind(),
            capacity: self.queue.capacity(),
        }
    }

    /// Restore this simulation to the state captured by `epoch`: clock to
    /// zero, queue emptied (rebuilt on the epoch's backend and capacity),
    /// processed/send counters zeroed, actors added after the epoch
    /// dropped, and every surviving actor reset via [`Actor::reset`].
    ///
    /// Returns `false` — leaving the simulation in an unusable half-reset
    /// state the caller must discard — when reuse is not possible: a
    /// domain context or tracer is installed, an epoch actor is missing
    /// (still split across PDES domains), or any actor declines to reset.
    /// On `true`, re-running the identical workload from here produces a
    /// byte-identical trajectory to a cold rebuild: actor ids (and hence
    /// merge keys) are reassigned identically because per-run actors are
    /// re-added in the same order on a truncated actor table.
    pub fn reset_to_epoch(&mut self, epoch: &SimEpoch) -> bool {
        if self.domain.is_some() || self.tracer.is_some() {
            return false;
        }
        if self.actors.len() < epoch.n_actors {
            return false;
        }
        self.actors.truncate(epoch.n_actors);
        for slot in &mut self.actors {
            match slot {
                Some(a) => {
                    if !a.reset() {
                        return false;
                    }
                }
                None => return false,
            }
        }
        self.send_seq.truncate(epoch.n_actors);
        for s in &mut self.send_seq {
            *s = 0;
        }
        self.ext_seq = 0;
        self.now = Time::ZERO;
        self.processed = 0;
        self.queue = EventQueue::with_capacity(epoch.kind, epoch.capacity);
        true
    }

    // ---- partitioning plumbing (see sim/pdes.rs) -------------------------

    /// Decompose into raw parts for domain splitting. Panics if a tracer
    /// is installed (tracers observe the global dispatch order, which a
    /// partitioned run does not materialize).
    pub(crate) fn into_parts(self) -> SimParts<M> {
        assert!(self.tracer.is_none(), "PDES partitioning does not support tracers");
        SimParts {
            now: self.now,
            actors: self.actors,
            queue: self.queue,
            processed: self.processed,
            send_seq: self.send_seq,
            ext_seq: self.ext_seq,
        }
    }

    /// Reassemble a simulation from raw parts, optionally as one domain
    /// of a partition.
    pub(crate) fn from_parts(parts: SimParts<M>, domain: Option<DomainCtx<M>>) -> Sim<M> {
        Sim {
            now: parts.now,
            actors: parts.actors,
            queue: parts.queue,
            processed: parts.processed,
            tracer: None,
            send_seq: parts.send_seq,
            ext_seq: parts.ext_seq,
            domain,
        }
    }

    /// Insert a pre-keyed event (barrier delivery of a cross-domain
    /// message, or queue redistribution during split/merge).
    pub(crate) fn inject_keyed(&mut self, at: Time, key: u64, dst: ActorId, msg: M) {
        self.queue.push_keyed(at, key, dst, msg);
    }

    /// Drain the outbox of cross-domain messages (empty outside
    /// partitioned runs).
    pub(crate) fn take_outbox(&mut self) -> Vec<Outgoing<M>> {
        match &mut self.domain {
            Some(d) => std::mem::take(&mut d.outbox),
            None => Vec::new(),
        }
    }

    /// The domain's **earliest output time** in picoseconds — the value a
    /// partitioned run publishes as its channel clock (`sim/pdes.rs`):
    /// a lower bound on the send time of any message this domain may emit
    /// from now on, namely its earliest pending event. Every cross-domain
    /// message therefore arrives at `eot + channel lookahead` at the
    /// earliest, which is exactly the per-neighbor CMB bound. `u64::MAX`
    /// when the domain is idle (it cannot send anything until a message
    /// is injected). Only valid between windows: the outbox must be
    /// drained ([`Sim::take_outbox`]), since undelivered outbox messages
    /// are not covered by the pending-event minimum. One EOT serves every
    /// out-channel — refining it per channel would require the engine to
    /// know which domains an event's sends can reach, which only the
    /// hardware layer does.
    pub(crate) fn eot_ps(&self) -> u64 {
        debug_assert!(
            self.domain.as_ref().is_none_or(|d| d.outbox.is_empty()),
            "EOT published with undelivered outbox messages"
        );
        self.queue.peek_time().map_or(u64::MAX, |t| t.ps())
    }

    /// Advance the clock to at least `t` without processing events
    /// (window epilogue, mirroring [`Sim::run_until`]'s clock semantics).
    pub(crate) fn advance_clock(&mut self, t: Time) {
        self.now = self.now.max(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::Time;

    #[derive(Debug, Clone, PartialEq)]
    enum TestMsg {
        Ping(u32),
        Tick,
    }

    /// Records every delivery with its timestamp.
    struct Recorder {
        seen: Vec<(Time, TestMsg)>,
    }

    impl Actor<TestMsg> for Recorder {
        fn handle(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
            self.seen.push((ctx.now(), msg));
        }
    }

    /// Forwards each Ping to a peer with +1 and 10ns delay, up to 5.
    struct Forwarder {
        peer: ActorId,
        sent: u32,
    }

    impl Actor<TestMsg> for Forwarder {
        fn handle(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
            if let TestMsg::Ping(n) = msg {
                if n < 5 {
                    ctx.send(self.peer, Time::from_ns(10), TestMsg::Ping(n + 1));
                    self.sent += 1;
                }
            }
        }
    }

    #[test]
    fn delivery_order_is_time_then_fifo() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        sim.schedule(Time::from_ns(20), rec, TestMsg::Ping(2));
        sim.schedule(Time::from_ns(10), rec, TestMsg::Ping(1));
        sim.schedule(Time::from_ns(20), rec, TestMsg::Ping(3)); // same time: after Ping(2)
        sim.run_to_completion();
        let r: &Recorder = sim.get(rec);
        assert_eq!(
            r.seen,
            vec![
                (Time::from_ns(10), TestMsg::Ping(1)),
                (Time::from_ns(20), TestMsg::Ping(2)),
                (Time::from_ns(20), TestMsg::Ping(3)),
            ]
        );
    }

    #[test]
    fn ping_pong_chain() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        let fwd = sim.add(Forwarder { peer: rec, sent: 0 });
        // drive the forwarder via self-chain: rec gets 1..=5
        // fwd forwards Ping(n)->rec; also need fwd to receive pings
        sim.schedule(Time::ZERO, fwd, TestMsg::Ping(0));
        sim.schedule(Time::from_ns(10), fwd, TestMsg::Ping(1));
        sim.schedule(Time::from_ns(20), fwd, TestMsg::Ping(2));
        sim.run_to_completion();
        let f: &Forwarder = sim.get(fwd);
        assert_eq!(f.sent, 3);
        let r: &Recorder = sim.get(rec);
        assert_eq!(r.seen.len(), 3);
        assert_eq!(r.seen[0], (Time::from_ns(10), TestMsg::Ping(1)));
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        for i in 0..10 {
            sim.schedule(Time::from_ns(i * 10), rec, TestMsg::Tick);
        }
        let n = sim.run_until(Time::from_ns(45));
        assert_eq!(n, 5); // t = 0,10,20,30,40
        assert_eq!(sim.now, Time::from_ns(45));
        assert_eq!(sim.pending(), 5);
        let n = sim.run_until(Time::from_ns(1000));
        assert_eq!(n, 5);
    }

    #[test]
    fn run_limit() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        for i in 0..100 {
            sim.schedule(Time::from_ns(i), rec, TestMsg::Tick);
        }
        assert_eq!(sim.run(30), 30);
        assert_eq!(sim.processed(), 30);
        assert_eq!(sim.pending(), 70);
    }

    #[test]
    fn clock_monotone() {
        let mut sim = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        sim.schedule(Time::from_ns(5), rec, TestMsg::Tick);
        sim.schedule(Time::from_ns(1), rec, TestMsg::Tick);
        let mut last = Time::ZERO;
        while sim.step() {
            assert!(sim.now >= last);
            last = sim.now;
        }
    }

    #[test]
    fn self_messages() {
        struct Timer {
            fires: u32,
        }
        impl Actor<TestMsg> for Timer {
            fn handle(&mut self, _m: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
                self.fires += 1;
                if self.fires < 4 {
                    ctx.send_self(Time::from_ns(100), TestMsg::Tick);
                }
            }
        }
        let mut sim = Sim::new();
        let t = sim.add(Timer { fires: 0 });
        sim.schedule(Time::ZERO, t, TestMsg::Tick);
        sim.run_to_completion();
        assert_eq!(sim.get::<Timer>(t).fires, 4);
        assert_eq!(sim.now, Time::from_ns(300));
    }

    #[test]
    #[should_panic(expected = "not a")]
    fn typed_access_panics_on_wrong_type() {
        let mut sim: Sim<TestMsg> = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        let _ = sim.get::<Forwarder>(rec);
    }

    #[test]
    fn try_get_returns_none_on_wrong_type() {
        let mut sim: Sim<TestMsg> = Sim::new();
        let rec = sim.add(Recorder { seen: vec![] });
        assert!(sim.try_get::<Forwarder>(rec).is_none());
        assert!(sim.try_get::<Recorder>(rec).is_some());
    }

    // ---- epoch reset ------------------------------------------------------

    /// A counter actor that opts into reuse: reset restores the count.
    struct Counter {
        count: u32,
    }

    impl Actor<TestMsg> for Counter {
        fn handle(&mut self, _m: TestMsg, _ctx: &mut Ctx<'_, TestMsg>) {
            self.count += 1;
        }

        fn reset(&mut self) -> bool {
            self.count = 0;
            true
        }
    }

    #[test]
    fn reset_bails_on_non_resettable_actor() {
        // Recorder keeps the default reset() → the whole sim declines.
        let mut sim = Sim::new();
        sim.add(Recorder { seen: vec![] });
        let epoch = sim.mark_epoch();
        assert!(!sim.reset_to_epoch(&epoch));
    }

    #[test]
    fn reset_restores_clock_queue_and_counters() {
        let mut sim = Sim::with_queue(EventQueue::with_capacity(QueueKind::Heap, 64));
        let c = sim.add(Counter { count: 0 });
        let epoch = sim.mark_epoch();
        let run = |sim: &mut Sim<TestMsg>| {
            for i in 0..10u64 {
                sim.schedule(Time::from_ns(i * 7), c, TestMsg::Tick);
            }
            sim.run_to_completion();
            (sim.now, sim.processed(), sim.get::<Counter>(c).count)
        };
        let cold = run(&mut sim);
        assert_eq!(cold.2, 10);
        assert!(sim.reset_to_epoch(&epoch));
        assert_eq!(sim.now, Time::ZERO);
        assert_eq!(sim.processed(), 0);
        assert_eq!(sim.pending(), 0);
        assert_eq!(sim.queue_kind(), QueueKind::Heap);
        assert!(sim.queue.capacity() >= 64, "epoch capacity restored");
        assert_eq!(sim.get::<Counter>(c).count, 0);
        // the re-run trajectory is identical to the cold run
        assert_eq!(run(&mut sim), cold);
    }

    #[test]
    fn reset_drops_post_epoch_actors_and_reuses_their_ids() {
        let mut sim: Sim<TestMsg> = Sim::new();
        let a = sim.add(Counter { count: 0 });
        let epoch = sim.mark_epoch();
        // a per-run actor added after the epoch...
        let g1 = sim.add(Counter { count: 0 });
        sim.schedule(Time::ZERO, g1, TestMsg::Tick);
        sim.run_to_completion();
        assert_eq!(sim.n_actors(), 2);
        assert!(sim.reset_to_epoch(&epoch));
        // ...is dropped, and the next add reclaims the same id → the
        // merge-key space of the re-run matches the first run exactly
        assert_eq!(sim.n_actors(), 1);
        let g2 = sim.add(Counter { count: 0 });
        assert_eq!(g2, g1);
    }

    // ---- queue backends ---------------------------------------------------

    #[test]
    fn queue_kind_parse_roundtrip() {
        assert_eq!(QueueKind::parse("heap"), Some(QueueKind::Heap));
        assert_eq!(QueueKind::parse("wheel"), Some(QueueKind::Wheel));
        assert_eq!(QueueKind::parse("splay"), None);
        for k in [QueueKind::Heap, QueueKind::Wheel] {
            assert_eq!(QueueKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(QueueKind::default(), QueueKind::Wheel);
    }

    #[test]
    fn with_capacity_pre_sizes_slab() {
        let q = EventQueue::<u64>::with_capacity(QueueKind::Wheel, 1024);
        assert!(q.capacity() >= 1024);
        assert_eq!(q.kind(), QueueKind::Wheel);
        let q = EventQueue::<u64>::with_capacity(QueueKind::Heap, 16);
        assert!(q.capacity() >= 16);
        assert_eq!(q.kind(), QueueKind::Heap);
        let sim = Sim::<TestMsg>::with_kind(QueueKind::Heap);
        assert_eq!(sim.queue_kind(), QueueKind::Heap);
    }

    /// The wheel must agree with the heap pop-for-pop on a randomized
    /// hold-pattern workload with exact-tie timestamps and far-future
    /// (overflow-horizon) events.
    #[test]
    fn wheel_matches_heap_on_random_workload() {
        let mut heap = EventQueue::<u32>::with_kind(QueueKind::Heap);
        let mut wheel = EventQueue::<u32>::with_kind(QueueKind::Wheel);
        let mut state = 0x5EED_CAFE_u64;
        let mut next = move |m: u64| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (state >> 33) % m
        };
        let mut now = 0u64;
        let mut pending = 0usize;
        let mut pushed = 0u32;
        for step in 0..20_000 {
            if pending == 0 || next(100) < 55 {
                let delay = match next(10) {
                    // mostly ≤ 2 µs (in-wheel), some exact ties with now,
                    // some 0.1–1.1 ms ahead (overflow horizon)
                    0..=6 => next(2_000_000),
                    7 | 8 => 0,
                    _ => 100_000_000 + next(1_000_000_000),
                };
                let at = Time::from_ps(now + delay);
                heap.push(at, (pushed % 7) as usize, pushed);
                wheel.push(at, (pushed % 7) as usize, pushed);
                pushed += 1;
                pending += 1;
            } else {
                let a = heap.pop().unwrap();
                let b = wheel.pop().unwrap();
                assert_eq!(
                    (a.at, a.seq, a.dst, a.msg),
                    (b.at, b.seq, b.dst, b.msg),
                    "divergence at step {step}"
                );
                now = a.at.ps();
                pending -= 1;
            }
            assert_eq!(heap.len(), wheel.len());
            assert_eq!(heap.peek_time(), wheel.peek_time());
        }
        while let Some(a) = heap.pop() {
            let b = wheel.pop().unwrap();
            assert_eq!((a.at, a.seq, a.dst, a.msg), (b.at, b.seq, b.dst, b.msg));
        }
        assert!(wheel.pop().is_none());
        assert!(wheel.is_empty());
    }

    /// Events spread over many horizon revolutions drain in order.
    #[test]
    fn wheel_crosses_horizon_boundaries() {
        let mut q = EventQueue::<u32>::with_kind(QueueKind::Wheel);
        // horizon is ≈67 µs; spread pushes over ~12 ms
        for i in (0..16u64).rev() {
            q.push(Time::from_us(i * 800), 0, i as u32);
        }
        assert_eq!(q.len(), 16);
        let mut last = Time::ZERO;
        let mut popped = Vec::new();
        for _ in 0..8 {
            let e = q.pop().unwrap();
            assert!(e.at >= last);
            last = e.at;
            popped.push(e.msg);
        }
        // push more while partially drained, both near and far
        q.push(last + Time::from_ns(1), 0, 100);
        q.push(last + Time::from_ms(50), 0, 101);
        while let Some(e) = q.pop() {
            assert!(e.at >= last);
            last = e.at;
            popped.push(e.msg);
        }
        assert_eq!(popped.len(), 18);
        assert_eq!(popped[0..8], [0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(popped[8], 100); // the near event lands right after pop 8
        assert_eq!(*popped.last().unwrap(), 101); // the +50ms event drains last
    }

    /// Whole-sim trajectories must be identical across queue backends.
    #[test]
    fn sim_trajectory_identical_across_queue_kinds() {
        let run = |kind: QueueKind| {
            let mut sim = Sim::with_kind(kind);
            let rec = sim.add(Recorder { seen: vec![] });
            let fwd = sim.add(Forwarder { peer: rec, sent: 0 });
            for i in 0..50u64 {
                sim.schedule(Time::from_ns(i * 3), fwd, TestMsg::Ping((i % 4) as u32));
            }
            sim.run_to_completion();
            sim.get::<Recorder>(rec).seen.clone()
        };
        let a = run(QueueKind::Heap);
        let b = run(QueueKind::Wheel);
        assert!(!a.is_empty());
        assert_eq!(a, b);
    }
}
