//! Simulation time: a picosecond-resolution virtual clock.
//!
//! Picoseconds in a `u64` cover ~213 days of simulated time — far beyond
//! any experiment here — while representing both the 210 MHz FPGA clock
//! (≈4761.9 ps/cycle) and multi-Gbit/s serial lanes without losing
//! precision to rounding.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// The BrainScaleS communication FPGA clock (Kintex-7 logic, paper §3.1).
pub const FPGA_CLK_HZ: u64 = 210_000_000;

/// An instant or duration in simulated picoseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Time(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);
    pub const MAX: Time = Time(u64::MAX);

    // -- constructors ------------------------------------------------------

    pub const fn from_ps(ps: u64) -> Time {
        Time(ps)
    }

    pub const fn from_ns(ns: u64) -> Time {
        Time(ns * 1_000)
    }

    pub const fn from_us(us: u64) -> Time {
        Time(us * 1_000_000)
    }

    pub const fn from_ms(ms: u64) -> Time {
        Time(ms * 1_000_000_000)
    }

    pub const fn from_s(s: u64) -> Time {
        Time(s * 1_000_000_000_000)
    }

    /// Exact conversion from 210 MHz FPGA clock cycles.
    ///
    /// One cycle is `1e12 / 210e6 = 100000/21` ps; the division is done in
    /// u128 so that rounding error never exceeds one picosecond total.
    pub fn from_fpga_cycles(cycles: u64) -> Time {
        Time(((cycles as u128 * 100_000) / 21) as u64)
    }

    /// Convert from seconds (f64); used for config values like "2.5e-3 s".
    pub fn from_secs_f64(s: f64) -> Time {
        assert!(s >= 0.0 && s.is_finite(), "invalid time {s}");
        Time((s * 1e12).round() as u64)
    }

    // -- accessors -----------------------------------------------------------

    pub const fn ps(self) -> u64 {
        self.0
    }

    pub fn ns_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn us_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn ms_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn secs_f64(self) -> f64 {
        self.0 as f64 / 1e12
    }

    /// Whole FPGA clock cycles elapsed at this instant (floor).
    pub fn fpga_cycles(self) -> u64 {
        ((self.0 as u128 * 21) / 100_000) as u64
    }

    pub fn saturating_sub(self, rhs: Time) -> Time {
        Time(self.0.saturating_sub(rhs.0))
    }

    pub fn min(self, other: Time) -> Time {
        Time(self.0.min(other.0))
    }

    pub fn max(self, other: Time) -> Time {
        Time(self.0.max(other.0))
    }
}

impl Add for Time {
    type Output = Time;
    fn add(self, rhs: Time) -> Time {
        Time(self.0 + rhs.0)
    }
}

impl AddAssign for Time {
    fn add_assign(&mut self, rhs: Time) {
        self.0 += rhs.0;
    }
}

impl Sub for Time {
    type Output = Time;
    fn sub(self, rhs: Time) -> Time {
        Time(self.0 - rhs.0)
    }
}

impl SubAssign for Time {
    fn sub_assign(&mut self, rhs: Time) {
        self.0 -= rhs.0;
    }
}

impl Mul<u64> for Time {
    type Output = Time;
    fn mul(self, rhs: u64) -> Time {
        Time(self.0 * rhs)
    }
}

impl Div<u64> for Time {
    type Output = Time;
    fn div(self, rhs: u64) -> Time {
        Time(self.0 / rhs)
    }
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ps = self.0;
        if ps < 1_000 {
            write!(f, "{ps}ps")
        } else if ps < 1_000_000 {
            write!(f, "{:.2}ns", ps as f64 / 1e3)
        } else if ps < 1_000_000_000 {
            write!(f, "{:.2}us", ps as f64 / 1e6)
        } else if ps < 1_000_000_000_000 {
            write!(f, "{:.3}ms", ps as f64 / 1e9)
        } else {
            write!(f, "{:.3}s", ps as f64 / 1e12)
        }
    }
}

/// Serialization time for `bits` at `gbps` Gbit/s, rounded to ps.
///
/// `1 Gbit/s = 1 bit/ns`, so time = bits / gbps ns = bits * 1000 / gbps ps.
pub fn ps_for_bits(bits: u64, gbps: f64) -> Time {
    assert!(gbps > 0.0);
    Time((bits as f64 * 1000.0 / gbps).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(Time::from_ns(1).ps(), 1_000);
        assert_eq!(Time::from_us(1).ps(), 1_000_000);
        assert_eq!(Time::from_ms(1).ps(), 1_000_000_000);
        assert_eq!(Time::from_s(1).ps(), 1_000_000_000_000);
        assert!((Time::from_ms(2).ms_f64() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fpga_cycle_roundtrip() {
        // 210e6 cycles == exactly 1 second
        assert_eq!(Time::from_fpga_cycles(FPGA_CLK_HZ).ps(), 1_000_000_000_000);
        for c in [0u64, 1, 2, 21, 210, 1_000_000, 123_456_789] {
            let t = Time::from_fpga_cycles(c);
            let back = t.fpga_cycles();
            assert!(back == c || back + 1 == c, "c={c} back={back}");
        }
    }

    #[test]
    fn one_fpga_cycle_is_4761ps() {
        let t = Time::from_fpga_cycles(1);
        assert!(t.ps() == 4761 || t.ps() == 4762, "got {}", t.ps());
    }

    #[test]
    fn serialization_time() {
        // 8400 bits at 8.4 Gbit/s = 1000 ns
        assert_eq!(ps_for_bits(8400, 8.4), Time::from_ns(1000));
        // 1 bit at 1 Gbit/s = 1 ns
        assert_eq!(ps_for_bits(1, 1.0), Time::from_ns(1));
        // 496 B payload at 100.8 Gbit/s (12 lanes x 8.4)
        let t = ps_for_bits(496 * 8, 100.8);
        assert!((t.ns_f64() - 39.365).abs() < 0.01, "{}", t.ns_f64());
    }

    #[test]
    fn ordering_and_arith() {
        let a = Time::from_ns(5);
        let b = Time::from_ns(3);
        assert!(a > b);
        assert_eq!((a - b).ps(), 2_000);
        assert_eq!((a + b).ps(), 8_000);
        assert_eq!((a * 2).ps(), 10_000);
        assert_eq!((a / 5).ps(), 1_000);
        assert_eq!(b.saturating_sub(a), Time::ZERO);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", Time::from_ps(500)), "500ps");
        assert_eq!(format!("{}", Time::from_ns(1)), "1.00ns");
        assert_eq!(format!("{}", Time::from_us(2)), "2.00us");
        assert_eq!(format!("{}", Time::from_ms(3)), "3.000ms");
        assert_eq!(format!("{}", Time::from_s(4)), "4.000s");
    }

    #[test]
    fn from_secs_f64() {
        assert_eq!(Time::from_secs_f64(1e-3), Time::from_ms(1));
        assert_eq!(Time::from_secs_f64(0.0), Time::ZERO);
    }
}
