//! Discrete-event simulation core.
//!
//! The whole hardware model — Extoll fabric, FPGAs, hosts — runs on this
//! engine: a picosecond-resolution virtual clock, a deterministic event
//! queue (timestamp ties broken by a partition-independent merge key),
//! and an actor model where components communicate exclusively through
//! timestamped messages. [`pdes::Partition`] splits one simulation into
//! conservatively synchronized domains that advance on parallel worker
//! threads without changing any trajectory — lock-step global windows or
//! per-neighbor channel clocks, selected by [`pdes::SyncMode`].
//!
//! The core is generic over the message type `M`; the domain defines one
//! message enum per system (see [`crate::wafer::system`]). The engine
//! contract — ordering, determinism, the PDES lookahead invariant — is
//! documented in `docs/ARCHITECTURE.md`.

pub mod arena;
pub mod engine;
pub mod pdes;
pub mod time;

pub use arena::{Arena, F32Arena, F32Handle, Handle};
pub use engine::{Actor, ActorId, Ctx, Event, EventQueue, Placement, QueueKind, Sim, SimEpoch};
pub use pdes::{ChannelGraph, Partition, SyncMode};
pub use time::{ps_for_bits, Time, FPGA_CLK_HZ};
