//! Discrete-event simulation core.
//!
//! The whole hardware model — Extoll fabric, FPGAs, hosts — runs on this
//! engine: a picosecond-resolution virtual clock, a deterministic event
//! queue (ties broken by insertion sequence), and an actor model where
//! components communicate exclusively through timestamped messages.
//!
//! The core is generic over the message type `M`; the domain defines one
//! message enum per system (see [`crate::wafer::system`]).

pub mod engine;
pub mod time;

pub use engine::{Actor, ActorId, Ctx, Event, EventQueue, QueueKind, Sim};
pub use time::{ps_for_bits, Time, FPGA_CLK_HZ};
