//! Flat structure-of-arrays arenas with index-typed handles.
//!
//! Rack-scale configurations (20+ wafers, ~10⁵ neurons, ~10⁸ synapses)
//! do not fit — and do not iterate cache-friendly — when every actor's
//! hot state lives in its own `Box` and every shard's weight matrix is a
//! separately allocated `Vec<Vec<f32>>`. These arenas pack homogeneous
//! state contiguously and hand out small `Copy` handles instead of
//! pointers:
//!
//! - [`Arena<T>`] — a typed slab of `T` rows addressed by [`Handle<T>`];
//!   used for per-FPGA/NIC counter snapshots and other fixed-shape rows.
//! - [`F32Arena`] — a single flat `f32` buffer with a row table; one
//!   allocation holds every shard's weight matrix (or membrane-state
//!   block), addressed by [`F32Handle`] rows.
//!
//! Both report [`resident_bytes`](Arena::resident_bytes), which feeds the
//! byte-accounted `ResourceCache` LRU (`docs/ARCHITECTURE.md` §7/§8): a
//! cached `Prepared` that owns arenas accounts for their real footprint,
//! so eviction pressure reflects the rack-scale weight storage rather
//! than the default per-entry estimate.
//!
//! Handles are indices, not references: they stay valid across
//! `Sim::reset_to_epoch` (which never moves prepared storage) and across
//! threads (`F32Arena` is shared read-only via `Arc` by executes).

use std::marker::PhantomData;

/// Index-typed handle into an [`Arena<T>`]. `Copy`, 4 bytes, and typed:
/// a `Handle<FpgaCounters>` cannot address a `Handle<NicCounters>` arena.
pub struct Handle<T> {
    idx: u32,
    _marker: PhantomData<fn() -> T>,
}

impl<T> Handle<T> {
    fn new(idx: u32) -> Self {
        Handle {
            idx,
            _marker: PhantomData,
        }
    }

    /// Raw row index (stable for the arena's lifetime).
    pub fn index(self) -> usize {
        self.idx as usize
    }
}

// Manual impls: derive would bound them on `T: Clone`/`T: Copy` etc.,
// but a handle is always a plain index regardless of `T`.
impl<T> Clone for Handle<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for Handle<T> {}
impl<T> PartialEq for Handle<T> {
    fn eq(&self, other: &Self) -> bool {
        self.idx == other.idx
    }
}
impl<T> Eq for Handle<T> {}
impl<T> std::fmt::Debug for Handle<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Handle({})", self.idx)
    }
}

/// Contiguous typed slab: rows of `T` addressed by [`Handle<T>`].
#[derive(Clone, Debug, Default)]
pub struct Arena<T> {
    rows: Vec<T>,
}

impl<T> Arena<T> {
    pub fn new() -> Self {
        Arena { rows: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Arena {
            rows: Vec::with_capacity(n),
        }
    }

    /// Append a row; the returned handle is stable for the arena's life.
    pub fn push(&mut self, row: T) -> Handle<T> {
        assert!(self.rows.len() < u32::MAX as usize, "arena overflow");
        self.rows.push(row);
        Handle::new((self.rows.len() - 1) as u32)
    }

    pub fn get(&self, h: Handle<T>) -> &T {
        &self.rows[h.index()]
    }

    pub fn get_mut(&mut self, h: Handle<T>) -> &mut T {
        &mut self.rows[h.index()]
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// All rows, contiguous, in handle order (SoA sweep path).
    pub fn rows(&self) -> &[T] {
        &self.rows
    }

    pub fn rows_mut(&mut self) -> &mut [T] {
        &mut self.rows
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.rows.iter()
    }

    /// Drop all rows, keeping the allocation (refill-per-execute path).
    pub fn clear(&mut self) {
        self.rows.clear();
    }

    /// Heap footprint in bytes (capacity, not length — what the process
    /// actually holds resident).
    pub fn resident_bytes(&self) -> usize {
        self.rows.capacity() * std::mem::size_of::<T>()
    }
}

/// Row handle into an [`F32Arena`]: a `(offset, len)` view descriptor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct F32Handle {
    offset: u32,
    len: u32,
}

impl F32Handle {
    pub fn len(self) -> usize {
        self.len as usize
    }

    pub fn is_empty(self) -> bool {
        self.len == 0
    }
}

/// One flat `f32` buffer holding many variable-length rows (weight
/// matrices, membrane-state blocks). Rows are allocated append-only and
/// never move, so an [`F32Handle`] stays valid for the arena's lifetime —
/// including across `Sim::reset_to_epoch`, which does not touch prepared
/// storage.
#[derive(Clone, Debug, Default)]
pub struct F32Arena {
    data: Vec<f32>,
}

impl F32Arena {
    pub fn new() -> Self {
        F32Arena { data: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        F32Arena {
            data: Vec::with_capacity(n),
        }
    }

    /// Allocate a zeroed row of `len` floats.
    pub fn alloc(&mut self, len: usize) -> F32Handle {
        let offset = self.data.len();
        assert!(offset + len <= u32::MAX as usize, "f32 arena overflow");
        self.data.resize(offset + len, 0.0);
        F32Handle {
            offset: offset as u32,
            len: len as u32,
        }
    }

    /// Allocate a row and fill it via `fill` (e.g. the deterministic
    /// weight generator writing in place — no intermediate `Vec`).
    pub fn alloc_with(&mut self, len: usize, fill: impl FnOnce(&mut [f32])) -> F32Handle {
        let h = self.alloc(len);
        fill(self.row_mut(h));
        h
    }

    pub fn row(&self, h: F32Handle) -> &[f32] {
        &self.data[h.offset as usize..(h.offset + h.len) as usize]
    }

    pub fn row_mut(&mut self, h: F32Handle) -> &mut [f32] {
        &mut self.data[h.offset as usize..(h.offset + h.len) as usize]
    }

    /// Total floats stored across all rows.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Heap footprint in bytes (capacity, not length).
    pub fn resident_bytes(&self) -> usize {
        self.data.capacity() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_arena_pushes_and_indexes() {
        let mut a: Arena<u64> = Arena::with_capacity(4);
        let h0 = a.push(10);
        let h1 = a.push(20);
        assert_eq!(*a.get(h0), 10);
        assert_eq!(*a.get(h1), 20);
        *a.get_mut(h0) += 1;
        assert_eq!(a.rows(), &[11, 20]);
        assert_eq!(a.len(), 2);
        assert!(a.resident_bytes() >= 2 * 8);
        assert_eq!(h0.index(), 0);
        assert_ne!(h0, h1);
        a.clear();
        assert!(a.is_empty());
        assert!(a.resident_bytes() >= 2 * 8, "clear keeps the allocation");
    }

    #[test]
    fn handle_is_copy_and_comparable() {
        let mut a: Arena<String> = Arena::new();
        let h = a.push("x".to_string());
        let h2 = h; // Copy despite String not being Copy
        assert_eq!(h, h2);
        assert_eq!(format!("{h:?}"), "Handle(0)");
    }

    #[test]
    fn f32_arena_rows_are_contiguous_and_stable() {
        let mut a = F32Arena::new();
        let r0 = a.alloc(3);
        let r1 = a.alloc_with(4, |row| {
            for (i, w) in row.iter_mut().enumerate() {
                *w = i as f32;
            }
        });
        a.row_mut(r0).copy_from_slice(&[1.0, 2.0, 3.0]);
        // a later allocation must not move earlier rows' contents
        let _r2 = a.alloc(1000);
        assert_eq!(a.row(r0), &[1.0, 2.0, 3.0]);
        assert_eq!(a.row(r1), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(r0.len(), 3);
        assert_eq!(a.len(), 3 + 4 + 1000);
        assert!(a.resident_bytes() >= a.len() * 4);
    }

    #[test]
    fn f32_rows_start_zeroed() {
        let mut a = F32Arena::with_capacity(8);
        let r = a.alloc(8);
        assert!(a.row(r).iter().all(|&w| w == 0.0));
        assert!(!a.is_empty());
        assert!(!r.is_empty());
    }
}
