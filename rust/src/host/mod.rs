//! Host communication (paper §2): the RMA ring-buffer protocol between the
//! FPGAs and a compute-cluster host — write pointer/space registers,
//! notifications, credit-based flow control, driver polling.

#[allow(clippy::module_inception)]
pub mod host;
pub mod ringbuf;
pub mod stream;

pub use host::{ChannelConfig, Host, HostConfig, HostStats};
pub use ringbuf::{RingConsumer, RingProducer, WriteSegment};
pub use stream::{StreamConfig, StreamSource, StreamStats};
