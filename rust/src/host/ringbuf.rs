//! Ring-buffer communication protocol state machines (paper §2.1, Fig. 2a).
//!
//! "In order to avoid additional handshake messages, FPGAs write their data
//! to host memory in a predefined ring-buffer range for software
//! processing. [...] The ring-buffer is always tracked by FPGA logic
//! through the use of a write pointer and space registers. FPGAs exchange
//! notifications with the software, informing each other about the amount
//! of data written to or processed from memory. This implements a kind of
//! credit based flow control."
//!
//! [`RingProducer`] is the FPGA-side logic (write pointer + space register),
//! [`RingConsumer`] the host-side software view (read pointer + fill level
//! learned through DataWritten notifications). Both are pure state machines;
//! the actors in [`super::host`] and [`super::stream`] add timing.

/// FPGA-side ring-buffer tracking: write pointer + space register.
#[derive(Clone, Debug)]
pub struct RingProducer {
    /// Ring capacity in bytes.
    size: u64,
    /// Network logical address of the ring's base in host memory.
    nla_base: u64,
    /// Write pointer (offset into the ring).
    write_ptr: u64,
    /// Space register: bytes known free (credit).
    space: u64,
    // -- statistics --------------------------------------------------------
    pub bytes_written: u64,
    pub writes: u64,
    pub stalls: u64,
}

/// One physical write segment (wrap-around may split a logical write).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteSegment {
    /// Absolute NLA to PUT to.
    pub nla: u64,
    pub bytes: u64,
}

impl RingProducer {
    pub fn new(nla_base: u64, size: u64) -> Self {
        assert!(size > 0);
        RingProducer {
            size,
            nla_base,
            write_ptr: 0,
            space: size,
            bytes_written: 0,
            writes: 0,
            stalls: 0,
        }
    }

    /// Bytes currently available for writing (the space register).
    pub fn space(&self) -> u64 {
        self.space
    }

    pub fn write_ptr(&self) -> u64 {
        self.write_ptr
    }

    /// Try to reserve and address a write of `bytes`. Returns the physical
    /// segments (1 or 2, on wrap) or `None` if the space register is too
    /// low — the FPGA must stall until software frees memory (credit).
    pub fn write(&mut self, bytes: u64) -> Option<Vec<WriteSegment>> {
        assert!(bytes > 0 && bytes <= self.size, "write of {bytes} B into {} B ring", self.size);
        if bytes > self.space {
            self.stalls += 1;
            return None;
        }
        self.space -= bytes;
        let mut segs = Vec::with_capacity(2);
        let first = bytes.min(self.size - self.write_ptr);
        segs.push(WriteSegment {
            nla: self.nla_base + self.write_ptr,
            bytes: first,
        });
        if first < bytes {
            segs.push(WriteSegment {
                nla: self.nla_base,
                bytes: bytes - first,
            });
        }
        self.write_ptr = (self.write_ptr + bytes) % self.size;
        self.bytes_written += bytes;
        self.writes += 1;
        Some(segs)
    }

    /// Software freed `bytes` (SpaceFreed notification → credit return).
    pub fn credit(&mut self, bytes: u64) {
        self.space += bytes;
        assert!(
            self.space <= self.size,
            "space register overflow: {} > {}",
            self.space,
            self.size
        );
    }
}

/// Host-side software view of the ring.
#[derive(Clone, Debug)]
pub struct RingConsumer {
    size: u64,
    read_ptr: u64,
    /// Bytes known written but not yet processed.
    available: u64,
    // -- statistics --------------------------------------------------------
    pub bytes_consumed: u64,
    pub notifications_in: u64,
}

impl RingConsumer {
    pub fn new(size: u64) -> Self {
        RingConsumer {
            size,
            read_ptr: 0,
            available: 0,
            bytes_consumed: 0,
            notifications_in: 0,
        }
    }

    /// A DataWritten notification arrived: `bytes` more are readable.
    pub fn notify_written(&mut self, bytes: u64) {
        self.notifications_in += 1;
        self.available += bytes;
        assert!(
            self.available <= self.size,
            "ring overrun: {} > {} — producer wrote without credit",
            self.available,
            self.size
        );
    }

    /// Bytes ready for processing.
    pub fn available(&self) -> u64 {
        self.available
    }

    pub fn read_ptr(&self) -> u64 {
        self.read_ptr
    }

    /// Consume up to `max` bytes; returns how many were consumed — this is
    /// the amount to return to the FPGA as a SpaceFreed credit.
    pub fn consume(&mut self, max: u64) -> u64 {
        let n = self.available.min(max);
        self.available -= n;
        self.read_ptr = (self.read_ptr + n) % self.size;
        self.bytes_consumed += n;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_write_advances_pointer_and_space() {
        let mut p = RingProducer::new(0x1000, 1024);
        let segs = p.write(100).unwrap();
        assert_eq!(segs, vec![WriteSegment { nla: 0x1000, bytes: 100 }]);
        assert_eq!(p.space(), 924);
        assert_eq!(p.write_ptr(), 100);
    }

    #[test]
    fn wraparound_splits_segments() {
        let mut p = RingProducer::new(0, 1024);
        p.write(1000).unwrap();
        p.credit(1000); // software consumed everything
        let segs = p.write(100).unwrap();
        assert_eq!(
            segs,
            vec![
                WriteSegment { nla: 1000, bytes: 24 },
                WriteSegment { nla: 0, bytes: 76 },
            ]
        );
        assert_eq!(p.write_ptr(), 76);
    }

    #[test]
    fn stalls_without_credit() {
        let mut p = RingProducer::new(0, 256);
        assert!(p.write(200).is_some());
        assert!(p.write(100).is_none(), "must stall: only 56 B left");
        assert_eq!(p.stalls, 1);
        p.credit(200);
        assert!(p.write(100).is_some());
    }

    #[test]
    #[should_panic(expected = "space register overflow")]
    fn over_credit_is_a_protocol_violation() {
        let mut p = RingProducer::new(0, 256);
        p.credit(1);
    }

    #[test]
    fn consumer_tracks_available() {
        let mut c = RingConsumer::new(1024);
        c.notify_written(300);
        assert_eq!(c.available(), 300);
        assert_eq!(c.consume(100), 100);
        assert_eq!(c.consume(500), 200);
        assert_eq!(c.consume(10), 0);
        assert_eq!(c.bytes_consumed, 300);
    }

    #[test]
    #[should_panic(expected = "ring overrun")]
    fn consumer_detects_overrun() {
        let mut c = RingConsumer::new(128);
        c.notify_written(100);
        c.notify_written(100);
    }

    #[test]
    fn producer_consumer_conservation() {
        // classic invariant: space + written-unconsumed == size at every step
        let mut p = RingProducer::new(0, 4096);
        let mut c = RingConsumer::new(4096);
        let mut rng = crate::util::rng::Rng::new(3);
        let mut in_flight = 0u64; // written, not yet notified
        for _ in 0..10_000 {
            match rng.below(3) {
                0 => {
                    let n = rng.range(1, 512);
                    if p.write(n).is_some() {
                        in_flight += n;
                    }
                }
                1 => {
                    // notification delivery (batch everything in flight)
                    if in_flight > 0 {
                        c.notify_written(in_flight);
                        in_flight = 0;
                    }
                }
                _ => {
                    let freed = c.consume(rng.range(1, 1024));
                    if freed > 0 {
                        p.credit(freed);
                    }
                }
            }
            assert!(p.space() + in_flight + c.available() == 4096);
        }
        // drain
        if in_flight > 0 {
            c.notify_written(in_flight);
        }
        let freed = c.consume(u64::MAX);
        p.credit(freed);
        assert_eq!(p.space(), 4096);
        assert_eq!(p.bytes_written, c.bytes_consumed);
    }

    #[test]
    fn read_ptr_follows_write_ptr() {
        let mut p = RingProducer::new(0, 512);
        let mut c = RingConsumer::new(512);
        for _ in 0..100 {
            if p.write(96).is_some() {
                c.notify_written(96);
                let freed = c.consume(96);
                p.credit(freed);
                assert_eq!(p.write_ptr(), c.read_ptr());
            }
        }
    }
}
