//! Host-node actor (paper §2): the Extoll RMA target + driver software.
//!
//! "Data moving back to the host is written to main memory in the host.
//! The arrival of new data at the host is notified to the software by
//! making use of the notification system in the Extoll RMA unit and the
//! low-level driver software."
//!
//! The actor models: the RMA unit writing PUT payloads to ring-buffer
//! memory and raising notifications on flagged PUTs; driver software
//! polling the notification queue with a configurable period; a finite
//! software processing rate; and batched SpaceFreed credit notifications
//! back to the producing FPGA (paper §2.1 credit-based flow control).

use std::collections::VecDeque;

use crate::extoll::packet::{Packet, PacketKind};
use crate::extoll::rma::Notification;
use crate::extoll::torus::NodeAddr;
use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};
use crate::util::stats::Histogram;

use super::ringbuf::RingConsumer;

/// Timer tag: driver poll tick.
pub const TIMER_POLL: u32 = 10;

/// One receive channel: a ring buffer fed by one FPGA stream.
#[derive(Clone, Debug)]
pub struct ChannelConfig {
    /// Channel id (appears in notifications).
    pub id: u16,
    /// NLA window of the ring in host memory.
    pub nla_base: u64,
    pub ring_size: u64,
    /// Where SpaceFreed credits are sent (the producing FPGA's node).
    pub producer_node: NodeAddr,
    /// Send a SpaceFreed notification once this many bytes were freed.
    pub credit_batch: u64,
}

/// Per-channel runtime state.
struct Channel {
    cfg: ChannelConfig,
    consumer: RingConsumer,
    /// Bytes PUT since the last notification flag (completed by notify).
    pending_data: u64,
    /// Bytes freed since the last SpaceFreed credit message.
    freed_unsent: u64,
    /// FIFO of (bytes, created) for latency accounting.
    inflight: VecDeque<(u64, Time)>,
}

/// Host configuration.
#[derive(Clone, Copy, Debug)]
pub struct HostConfig {
    /// This host's torus node address.
    pub node: NodeAddr,
    /// Driver poll period (notification queue + ring processing).
    pub poll_period: Time,
    /// Software processing rate in bytes/s (0 = infinite).
    pub consume_rate: f64,
    /// PCIe + memory-write latency for an RMA PUT to land in memory.
    pub pcie_latency: Time,
}

impl Default for HostConfig {
    fn default() -> Self {
        HostConfig {
            node: NodeAddr(0),
            poll_period: Time::from_us(5),
            consume_rate: 0.0,
            pcie_latency: Time::from_ns(300),
        }
    }
}

/// Host statistics.
#[derive(Clone, Debug, Default)]
pub struct HostStats {
    pub puts_received: u64,
    pub bytes_received: u64,
    pub notifications: u64,
    pub credits_sent: u64,
    pub bytes_consumed: u64,
    /// Data latency: packet creation at the FPGA → consumed by software (ps).
    pub data_latency_ps: Histogram,
    /// Notification queue depth high-water mark.
    pub notify_queue_peak: usize,
}

/// The host actor.
pub struct Host {
    pub cfg: HostConfig,
    channels: Vec<Channel>,
    /// Hardware notification queue (drained by the driver poll).
    notify_q: VecDeque<(u16, u64)>, // (channel, bytes completed)
    /// Our NIC (for sending credit notifications).
    nic: Option<ActorId>,
    polling: bool,
    seq: u64,
    pub stats: HostStats,
}

impl Host {
    pub fn new(cfg: HostConfig) -> Self {
        Host {
            cfg,
            channels: Vec::new(),
            notify_q: VecDeque::new(),
            nic: None,
            polling: false,
            seq: (cfg.node.0 as u64) << 48,
            stats: HostStats::default(),
        }
    }

    pub fn attach_nic(&mut self, id: ActorId) {
        self.nic = Some(id);
    }

    /// Register a receive channel (ring buffer).
    pub fn add_channel(&mut self, cfg: ChannelConfig) {
        let ring_size = cfg.ring_size;
        self.channels.push(Channel {
            cfg,
            consumer: RingConsumer::new(ring_size),
            pending_data: 0,
            freed_unsent: 0,
            inflight: VecDeque::new(),
        });
    }

    fn channel_for_nla(&mut self, nla: u64) -> Option<&mut Channel> {
        self.channels
            .iter_mut()
            .find(|c| nla >= c.cfg.nla_base && nla < c.cfg.nla_base + c.cfg.ring_size)
    }

    fn start_polling(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if !self.polling {
            self.polling = true;
            ctx.send_self(self.cfg.poll_period, Msg::Timer(TIMER_POLL));
        }
    }

    /// One driver poll: drain the notification queue, process ring data,
    /// return credits.
    fn poll(&mut self, ctx: &mut Ctx<'_, Msg>) {
        // 1. notification queue → consumer fill levels
        while let Some((ch_id, bytes)) = self.notify_q.pop_front() {
            let ch = self
                .channels
                .iter_mut()
                .find(|c| c.cfg.id == ch_id)
                .expect("notification for unknown channel");
            ch.consumer.notify_written(bytes);
        }
        // 2. software processing, rate-limited per poll period
        let budget = if self.cfg.consume_rate <= 0.0 {
            u64::MAX
        } else {
            (self.cfg.consume_rate * self.cfg.poll_period.secs_f64()).max(1.0) as u64
        };
        let now = ctx.now();
        let mut consumed_now = vec![0u64; self.channels.len()];
        for (i, ch) in self.channels.iter_mut().enumerate() {
            let n = ch.consumer.consume(budget);
            consumed_now[i] = n;
            if n == 0 {
                continue;
            }
            self.stats.bytes_consumed += n;
            // latency accounting against the inflight FIFO
            let mut left = n;
            while left > 0 {
                match ch.inflight.front_mut() {
                    None => break,
                    Some((b, created)) => {
                        let take = (*b).min(left);
                        *b -= take;
                        left -= take;
                        let done = *b == 0;
                        let created = *created;
                        if done {
                            ch.inflight.pop_front();
                        }
                        self.stats
                            .data_latency_ps
                            .record(now.saturating_sub(created).ps());
                    }
                }
            }
            ch.freed_unsent += n;
        }
        // 3. batched credit return: send once the batch threshold is
        // reached, or on an idle poll (nothing consumed, nothing readable)
        // so trailing credit is never withheld from the producer.
        for i in 0..self.channels.len() {
            let idle = consumed_now[i] == 0 && self.channels[i].consumer.available() == 0;
            let ch = &mut self.channels[i];
            if ch.freed_unsent == 0 {
                continue;
            }
            if ch.freed_unsent >= ch.cfg.credit_batch || idle {
                let bytes = ch.freed_unsent;
                ch.freed_unsent = 0;
                self.seq += 1;
                let pkt = Notification::SpaceFreed {
                    channel: ch.cfg.id,
                    bytes,
                }
                .packet(self.cfg.node, ch.cfg.producer_node, now, self.seq);
                let nic = self.nic.expect("host has no nic attached");
                ctx.send(nic, Time::ZERO, Msg::Inject(pkt));
                self.stats.credits_sent += 1;
            }
        }
        // keep polling while data remains readable, notifications queue, or
        // unsent credit remains (the next idle poll will flush it)
        let busy = self
            .channels
            .iter()
            .any(|c| c.consumer.available() > 0 || c.freed_unsent > 0)
            || !self.notify_q.is_empty();
        if busy {
            ctx.send_self(self.cfg.poll_period, Msg::Timer(TIMER_POLL));
        } else {
            self.polling = false;
        }
    }
}

impl Actor<Msg> for Host {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Deliver(p) => match p.kind {
                PacketKind::RmaPut { nla, notify, bytes } => {
                    self.stats.puts_received += 1;
                    self.stats.bytes_received += bytes as u64;
                    let created = p.created;
                    let ch = self
                        .channel_for_nla(nla)
                        .unwrap_or_else(|| panic!("PUT to unmapped nla {nla:#x}"));
                    ch.pending_data += bytes as u64;
                    ch.inflight.push_back((bytes as u64, created));
                    if notify {
                        // RMA unit raises a notification completing the
                        // logical write
                        let done = ch.pending_data;
                        ch.pending_data = 0;
                        let id = ch.cfg.id;
                        self.notify_q.push_back((id, done));
                        self.stats.notifications += 1;
                        self.stats.notify_queue_peak =
                            self.stats.notify_queue_peak.max(self.notify_q.len());
                        self.start_polling(ctx);
                    }
                }
                PacketKind::Notification { code } => {
                    // hosts may also receive explicit notifications
                    let _ = Notification::decode(code);
                    self.start_polling(ctx);
                }
                other => panic!("host: unexpected packet kind {other:?}"),
            },
            Msg::Timer(TIMER_POLL) => self.poll(ctx),
            Msg::Credit { .. } => {}
            other => panic!("host: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        format!("host-{}", self.cfg.node)
    }

    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::Site(self.cfg.node.0 as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::rma::fragment_put;
    use crate::sim::Sim;

    /// Captures packets the host injects (credit notifications).
    struct NicStub {
        injected: Vec<(Time, Packet)>,
    }

    impl Actor<Msg> for NicStub {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::Inject(p) = msg {
                self.injected.push((ctx.now(), p));
            }
        }
    }

    fn setup(consume_rate: f64) -> (Sim<Msg>, ActorId, ActorId) {
        let mut sim = Sim::new();
        let host = sim.add(Host::new(HostConfig {
            node: NodeAddr(9),
            consume_rate,
            ..HostConfig::default()
        }));
        let nic = sim.add(NicStub { injected: vec![] });
        {
            let h = sim.get_mut::<Host>(host);
            h.attach_nic(nic);
            h.add_channel(ChannelConfig {
                id: 1,
                nla_base: 0x10000,
                ring_size: 65536,
                producer_node: NodeAddr(2),
                credit_batch: 4096,
            });
        }
        (sim, host, nic)
    }

    fn deliver_write(sim: &mut Sim<Msg>, host: ActorId, at: Time, nla: u64, bytes: u64) {
        for p in fragment_put(NodeAddr(2), NodeAddr(9), nla, bytes, true, at, 0) {
            sim.schedule(at, host, Msg::Deliver(p));
        }
    }

    #[test]
    fn put_notify_consume_credit_cycle() {
        let (mut sim, host, nic) = setup(0.0);
        deliver_write(&mut sim, host, Time::from_us(1), 0x10000, 8192);
        sim.run_to_completion();
        let h: &Host = sim.get(host);
        assert_eq!(h.stats.puts_received, 17); // ceil(8192/496)
        assert_eq!(h.stats.bytes_received, 8192);
        assert_eq!(h.stats.notifications, 1);
        assert_eq!(h.stats.bytes_consumed, 8192);
        let n: &NicStub = sim.get(nic);
        assert_eq!(n.injected.len(), 1, "one batched credit");
        match n.injected[0].1.kind {
            PacketKind::Notification { code } => {
                assert_eq!(
                    Notification::decode(code),
                    Some(Notification::SpaceFreed {
                        channel: 1,
                        bytes: 8192
                    })
                );
            }
            _ => panic!("expected notification"),
        }
        assert_eq!(n.injected[0].1.dst, NodeAddr(2));
    }

    #[test]
    fn small_writes_batch_credits() {
        let (mut sim, host, nic) = setup(0.0);
        // 8 writes of 512B; credit_batch 4096 → exactly 1 credit message
        for i in 0..8u64 {
            deliver_write(
                &mut sim,
                host,
                Time::from_us(1 + i),
                0x10000 + i * 512,
                512,
            );
        }
        sim.run_to_completion();
        let n: &NicStub = sim.get(nic);
        assert_eq!(n.injected.len(), 1);
        let h: &Host = sim.get(host);
        assert_eq!(h.stats.bytes_consumed, 4096);
    }

    #[test]
    fn finite_consume_rate_spreads_processing() {
        // 100 MB/s with 5us polls = 500B per poll
        let (mut sim, host, _) = setup(100e6);
        deliver_write(&mut sim, host, Time::from_us(1), 0x10000, 5000);
        sim.run_to_completion();
        let h: &Host = sim.get(host);
        assert_eq!(h.stats.bytes_consumed, 5000);
        // needs ~10 polls → at least 50us of simulated time
        assert!(sim.now >= Time::from_us(50), "finished too fast: {}", sim.now);
    }

    #[test]
    fn latency_histogram_populated() {
        let (mut sim, host, _) = setup(0.0);
        deliver_write(&mut sim, host, Time::from_us(3), 0x10000, 1024);
        sim.run_to_completion();
        let h: &Host = sim.get(host);
        assert!(h.stats.data_latency_ps.count() > 0);
        // consumed on the first poll after delivery: ≥ poll period
        assert!(h.stats.data_latency_ps.min() >= Time::from_us(3).ps() - Time::from_us(3).ps());
    }

    #[test]
    #[should_panic(expected = "unmapped nla")]
    fn put_outside_ring_panics() {
        let (mut sim, host, _) = setup(0.0);
        deliver_write(&mut sim, host, Time::from_us(1), 0xDEAD_0000, 64);
        sim.run_to_completion();
    }
}
