//! The system-wide message vocabulary for the discrete-event simulation.
//!
//! All actors — Tourmalet NICs, FPGAs, hosts, workload generators — exchange
//! these messages through [`crate::sim::Sim`]. Keeping one enum (instead of
//! per-module message types) lets heterogeneous components share a single
//! timeline without dynamic typing on the hot path.

use crate::extoll::packet::Packet;
use crate::fpga::event::SpikeEvent;
use crate::sim::ActorId;

/// One message in the system simulation.
#[derive(Clone, Debug)]
pub enum Msg {
    // ---- Extoll fabric ----------------------------------------------------
    /// A packet arriving at a NIC over a torus link (fully serialized).
    Packet(Packet),
    /// Local unit → NIC: inject a packet into the fabric.
    Inject(Packet),
    /// NIC → local unit: a packet addressed to this node, after traversing
    /// the local (7th) Tourmalet link.
    Deliver(Packet),
    /// Self-message: the serializer of `port` finished the current packet.
    TxDone { port: u8 },
    /// Link-level credit return for (`port`, `vc`) — the downstream input
    /// buffer slot was freed. Also used on the local port to signal the
    /// attached unit that an injection slot is free again.
    Credit { port: u8, vc: u8 },
    /// Link-reliability cumulative acknowledgement (`reliability=link`):
    /// the receiver on the far end of `port` has accepted every sequence
    /// below `ack`. Like [`Msg::Credit`], control frames occupy no input
    /// buffer and consume no credits.
    Ack { port: u8, ack: u64 },
    /// Link-reliability retransmission request: the receiver on the far
    /// end of `port` detected a CRC failure or sequence gap and expects
    /// sequence `expect` next (go-back-N from there).
    Nack { port: u8, expect: u64 },
    /// Link-reliability give-up notice: `sender` (on our `port`) exhausted
    /// the retry budget for everything below `expect`; the receiver must
    /// skip forward instead of NACKing the abandoned prefix forever.
    SeqSkip { sender: ActorId, port: u8, expect: u64 },
    /// Self-message: the retransmission timer of `port` may have expired
    /// (the handler checks actual progress — stale timers re-arm for the
    /// remainder instead of replaying).
    RetxTimer { port: u8 },

    // ---- FPGA / HICANN ----------------------------------------------------
    /// A spike event arriving from one of the FPGA's 8 HICANN links.
    HicannEvent(SpikeEvent),

    // ---- generic timers ---------------------------------------------------
    /// A tagged timer wake-up (bucket-deadline scan, host poll, generator
    /// ticks...). The tag disambiguates multiple timer streams per actor.
    Timer(u32),
}
