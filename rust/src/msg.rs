//! The system-wide message vocabulary for the discrete-event simulation.
//!
//! All actors — Tourmalet NICs, FPGAs, hosts, workload generators — exchange
//! these messages through [`crate::sim::Sim`]. Keeping one enum (instead of
//! per-module message types) lets heterogeneous components share a single
//! timeline without dynamic typing on the hot path.

use crate::extoll::packet::Packet;
use crate::fpga::event::SpikeEvent;

/// One message in the system simulation.
#[derive(Clone, Debug)]
pub enum Msg {
    // ---- Extoll fabric ----------------------------------------------------
    /// A packet arriving at a NIC over a torus link (fully serialized).
    Packet(Packet),
    /// Local unit → NIC: inject a packet into the fabric.
    Inject(Packet),
    /// NIC → local unit: a packet addressed to this node, after traversing
    /// the local (7th) Tourmalet link.
    Deliver(Packet),
    /// Self-message: the serializer of `port` finished the current packet.
    TxDone { port: u8 },
    /// Link-level credit return for (`port`, `vc`) — the downstream input
    /// buffer slot was freed. Also used on the local port to signal the
    /// attached unit that an injection slot is free again.
    Credit { port: u8, vc: u8 },

    // ---- FPGA / HICANN ----------------------------------------------------
    /// A spike event arriving from one of the FPGA's 8 HICANN links.
    HicannEvent(SpikeEvent),

    // ---- generic timers ---------------------------------------------------
    /// A tagged timer wake-up (bucket-deadline scan, host poll, generator
    /// ticks...). The tag disambiguates multiple timer streams per actor.
    Timer(u32),
}
