//! Cooperative per-job quotas and cancellation for service mode.
//!
//! The execute loops of the batch scenarios were written long before
//! service mode existed, so quota enforcement is **cooperative**: the
//! long-running loops (the fabric driver's workload window, the
//! microcircuit's step loop) call [`checkpoint`] at natural slice
//! boundaries. With no job control installed on the thread — every
//! batch CLI / sweep / test path — a checkpoint is a nearly-free no-op
//! and changes nothing about the run (gated byte-identical in
//! `rust/tests/serve_mode.rs`). Under a worker-pool job the checkpoint
//!
//! 1. publishes the job's simulated-event progress (for `running`
//!    status events, rate-limited),
//! 2. stops the run with a typed [`Interrupt`] when the job was
//!    cancelled or its wall-clock / simulated-event budget is spent.
//!
//! The control block is installed per worker thread via [`activate`]
//! and removed by the returned RAII [`QuotaGuard`] — a panicking
//! execute can never leak one job's control onto the next job that
//! runs on the same worker.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

/// Why a [`checkpoint`] stopped the run. Carried as the error type so
/// the worker pool can map each outcome to its protocol status
/// (`cancelled` vs `rejected{quota ...}`) via `downcast_ref`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Interrupt {
    /// The client (or server shutdown) cancelled the job.
    Cancelled,
    /// The wall-clock budget is spent.
    WallQuota,
    /// The simulated-event budget is spent.
    EventQuota,
}

impl std::fmt::Display for Interrupt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Interrupt::Cancelled => write!(f, "job cancelled"),
            Interrupt::WallQuota => write!(f, "wall-clock quota exceeded"),
            Interrupt::EventQuota => write!(f, "simulated-event quota exceeded"),
        }
    }
}

impl std::error::Error for Interrupt {}

/// Shared control block of one job: the cancellation flag flipped by
/// the connection thread and the progress gauge read for `stats`.
#[derive(Default)]
pub struct JobCtl {
    cancelled: AtomicBool,
    events_done: AtomicU64,
}

impl JobCtl {
    pub fn new() -> JobCtl {
        JobCtl::default()
    }

    /// Request cancellation; takes effect at the job's next checkpoint.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Simulated events processed, as of the last checkpoint.
    pub fn events_done(&self) -> u64 {
        self.events_done.load(Ordering::Relaxed)
    }
}

/// Per-job budgets. `None` = unlimited.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuotaSpec {
    pub max_wall: Option<Duration>,
    pub max_events: Option<u64>,
}

impl QuotaSpec {
    /// Tighten this spec by a server-wide cap: a job may ask for less
    /// than the cap, never more.
    pub fn capped_by(self, cap: QuotaSpec) -> QuotaSpec {
        fn min_opt<T: Ord>(a: Option<T>, b: Option<T>) -> Option<T> {
            match (a, b) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, None) => a,
                (None, b) => b,
            }
        }
        QuotaSpec {
            max_wall: min_opt(self.max_wall, cap.max_wall),
            max_events: min_opt(self.max_events, cap.max_events),
        }
    }
}

/// Rate-limited progress callback (wired to `running{events_done}`
/// status events by the worker pool).
type ProgressFn = Box<dyn FnMut(u64)>;

struct ActiveJob {
    ctl: Arc<JobCtl>,
    quota: QuotaSpec,
    started: Instant,
    progress: Option<ProgressFn>,
    last_progress: Instant,
}

thread_local! {
    static ACTIVE: RefCell<Option<ActiveJob>> = const { RefCell::new(None) };
}

/// Minimum spacing of progress-callback invocations.
const PROGRESS_INTERVAL: Duration = Duration::from_millis(200);

/// Install a job control on the current thread for the duration of the
/// returned guard. Panics if one is already installed (jobs never
/// nest — one worker runs one execute at a time).
pub fn activate(
    ctl: Arc<JobCtl>,
    quota: QuotaSpec,
    progress: Option<ProgressFn>,
) -> QuotaGuard {
    ACTIVE.with(|a| {
        let mut slot = a.borrow_mut();
        assert!(slot.is_none(), "nested quota::activate");
        let now = Instant::now();
        *slot = Some(ActiveJob {
            ctl,
            quota,
            started: now,
            progress,
            last_progress: now,
        });
    });
    QuotaGuard { _private: () }
}

/// Whether a job control is installed on this thread (the execute
/// loops use this to skip checkpoint slicing in batch runs).
pub fn is_active() -> bool {
    ACTIVE.with(|a| a.borrow().is_some())
}

/// Cooperative quota checkpoint, called from the execute loops with the
/// current simulated-event count. A no-op returning `Ok` when no job
/// control is installed; otherwise publishes progress and fails with a
/// typed [`Interrupt`] on cancellation or an exhausted budget.
pub fn checkpoint(events_done: u64) -> Result<()> {
    ACTIVE.with(|a| {
        let mut slot = a.borrow_mut();
        let Some(job) = slot.as_mut() else {
            return Ok(());
        };
        job.ctl.events_done.store(events_done, Ordering::Relaxed);
        if job.ctl.is_cancelled() {
            return Err(anyhow::Error::new(Interrupt::Cancelled));
        }
        if let Some(max) = job.quota.max_events {
            if events_done > max {
                return Err(anyhow::Error::new(Interrupt::EventQuota));
            }
        }
        if let Some(max) = job.quota.max_wall {
            if job.started.elapsed() > max {
                return Err(anyhow::Error::new(Interrupt::WallQuota));
            }
        }
        if let Some(progress) = job.progress.as_mut() {
            if job.last_progress.elapsed() >= PROGRESS_INTERVAL {
                job.last_progress = Instant::now();
                progress(events_done);
            }
        }
        Ok(())
    })
}

/// RAII guard of [`activate`]: clears the thread's job control on drop
/// (including during unwinding from a panicked execute).
pub struct QuotaGuard {
    _private: (),
}

impl Drop for QuotaGuard {
    fn drop(&mut self) {
        ACTIVE.with(|a| {
            // take() instead of assert: stay panic-tolerant
            a.borrow_mut().take();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_is_a_noop_without_a_job() {
        assert!(!is_active());
        for n in [0, 1, u64::MAX] {
            assert!(checkpoint(n).is_ok());
        }
    }

    #[test]
    fn guard_installs_and_clears_the_control() {
        let ctl = Arc::new(JobCtl::new());
        {
            let _g = activate(ctl.clone(), QuotaSpec::default(), None);
            assert!(is_active());
            checkpoint(42).unwrap();
            assert_eq!(ctl.events_done(), 42);
        }
        assert!(!is_active());
        // a later checkpoint no longer touches the old control
        checkpoint(99).unwrap();
        assert_eq!(ctl.events_done(), 42);
    }

    #[test]
    fn cancellation_interrupts_at_the_next_checkpoint() {
        let ctl = Arc::new(JobCtl::new());
        let _g = activate(ctl.clone(), QuotaSpec::default(), None);
        checkpoint(1).unwrap();
        ctl.cancel();
        let err = checkpoint(2).unwrap_err();
        assert_eq!(
            err.downcast_ref::<Interrupt>(),
            Some(&Interrupt::Cancelled)
        );
    }

    #[test]
    fn event_quota_interrupts() {
        let ctl = Arc::new(JobCtl::new());
        let quota = QuotaSpec {
            max_events: Some(100),
            ..QuotaSpec::default()
        };
        let _g = activate(ctl, quota, None);
        checkpoint(100).unwrap(); // at the budget is still fine
        let err = checkpoint(101).unwrap_err();
        assert_eq!(
            err.downcast_ref::<Interrupt>(),
            Some(&Interrupt::EventQuota)
        );
    }

    #[test]
    fn wall_quota_interrupts() {
        let ctl = Arc::new(JobCtl::new());
        let quota = QuotaSpec {
            max_wall: Some(Duration::ZERO),
            ..QuotaSpec::default()
        };
        let _g = activate(ctl, quota, None);
        std::thread::sleep(Duration::from_millis(2));
        let err = checkpoint(1).unwrap_err();
        assert_eq!(
            err.downcast_ref::<Interrupt>(),
            Some(&Interrupt::WallQuota)
        );
    }

    #[test]
    fn progress_is_rate_limited() {
        let seen = std::rc::Rc::new(RefCell::new(Vec::new()));
        let sink = seen.clone();
        let _g = activate(
            Arc::new(JobCtl::new()),
            QuotaSpec::default(),
            Some(Box::new(move |n| sink.borrow_mut().push(n))),
        );
        // immediately after activate the interval has not elapsed
        checkpoint(1).unwrap();
        checkpoint(2).unwrap();
        assert!(seen.borrow().is_empty());
    }

    #[test]
    fn quota_caps_compose() {
        let job = QuotaSpec {
            max_wall: Some(Duration::from_secs(60)),
            max_events: None,
        };
        let server = QuotaSpec {
            max_wall: Some(Duration::from_secs(10)),
            max_events: Some(1_000),
        };
        let eff = job.capped_by(server);
        assert_eq!(eff.max_wall, Some(Duration::from_secs(10)));
        assert_eq!(eff.max_events, Some(1_000));
        assert_eq!(
            QuotaSpec::default().capped_by(QuotaSpec::default()),
            QuotaSpec::default()
        );
    }
}
