//! Experiment service mode: a long-running job server over plain TCP.
//!
//! `bss-extoll serve` turns the batch experiment runner into a
//! service: clients connect, submit experiment configurations as
//! JSON lines ([`protocol`]), and receive a streamed lifecycle of
//! status events (`queued → preparing → running{events_done} →
//! done{report}`, or `cancelled` / `rejected{reason}`). Submissions
//! from *all* connections land in one FIFO [`queue::JobQueue`] drained
//! by a bounded [`pool::WorkerPool`], and every job resolves its
//! prepared resources through one shared
//! [`ResourceCache`](crate::coordinator::ResourceCache) — the
//! cross-submission cache that makes N clients running the same
//! machine shape pay for one prepare. The cache is byte-budgeted
//! (`--cache-bytes`, LRU eviction); the `CacheKey ⇒ Prepared`
//! interchangeability contract is what keeps an evict-then-re-prepare
//! byte-identical to a cache hit.
//!
//! Per-job quotas (wall clock, simulated events) and cancellation are
//! cooperative, enforced at [`quota`] checkpoints inside the execute
//! loops; the batch CLI paths run with no job control installed, where
//! the checkpoints are no-ops.
//!
//! Everything is built on `std` networking (`TcpListener`/`TcpStream`)
//! and the repo's hand-rolled JSON — no new dependencies.
//!
//! See `docs/ARCHITECTURE.md` §7 for the protocol grammar and the
//! queue/pool/quota lifecycle, and [`client`] for the programmatic
//! client plus the `loadgen` throughput driver.

pub mod client;
pub mod pool;
pub mod protocol;
pub mod queue;
pub mod quota;

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{self, ExperimentConfig, ResourceCache};
use crate::util::json::Json;

use self::protocol::{
    ev_bye, ev_cancelled, ev_error, ev_queued, ev_rejected, Request, Submission,
};
use self::queue::{CancelOutcome, Job, JobQueue};
use self::quota::{JobCtl, QuotaSpec};

/// Server configuration (CLI flags of `bss-extoll serve`). The numeric
/// knobs use `0` = unlimited, mirroring their flag defaults.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7411`; port 0 binds ephemeral.
    pub addr: String,
    /// Worker-pool size (`--workers`).
    pub workers: usize,
    /// Resource-cache byte budget (`--cache-bytes`, 0 = unbounded).
    pub cache_bytes: u64,
    /// Server-wide per-job wall-clock cap in ms (`--max-wall-ms`).
    pub max_wall_ms: u64,
    /// Server-wide per-job simulated-event cap (`--max-events`).
    pub max_events: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            cache_bytes: 0,
            max_wall_ms: 0,
            max_events: 0,
        }
    }
}

impl ServeConfig {
    fn server_quota(&self) -> QuotaSpec {
        QuotaSpec {
            max_wall: (self.max_wall_ms > 0)
                .then(|| Duration::from_millis(self.max_wall_ms)),
            max_events: (self.max_events > 0).then_some(self.max_events),
        }
    }
}

/// Shared state handed to every connection thread.
#[derive(Clone)]
struct ConnCtx {
    queue: Arc<JobQueue>,
    cache: Arc<ResourceCache>,
    stop: Arc<AtomicBool>,
    server_quota: QuotaSpec,
}

/// A bound (not yet running) server.
pub struct Server {
    cfg: ServeConfig,
    listener: TcpListener,
    addr: SocketAddr,
    queue: Arc<JobQueue>,
    cache: Arc<ResourceCache>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind the listen socket (port 0 picks an ephemeral port; read it
    /// back with [`local_addr`](Server::local_addr)).
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let cache = Arc::new(ResourceCache::with_budget(cfg.cache_bytes));
        Ok(Server {
            cfg,
            listener,
            addr,
            queue: Arc::new(JobQueue::new()),
            cache,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until a `shutdown` request (or an external
    /// [`ServerHandle::stop`]); then stop accepting, drain the queue
    /// and join the workers. Connection threads exit on their own when
    /// their client hangs up.
    pub fn run(self) -> Result<()> {
        let pool = pool::WorkerPool::spawn(
            self.cfg.workers,
            self.queue.clone(),
            self.cache.clone(),
        );
        let ctx = ConnCtx {
            queue: self.queue.clone(),
            cache: self.cache.clone(),
            stop: self.stop.clone(),
            server_quota: self.cfg.server_quota(),
        };
        self.listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let ctx = ctx.clone();
                    std::thread::Builder::new()
                        .name("serve-conn".to_string())
                        .spawn(move || handle_conn(stream, &ctx))
                        .context("spawn connection thread")?;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    // transient accept errors (ECONNABORTED etc.) are
                    // not worth taking the server down for
                    let _ = e;
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
        self.queue.shutdown();
        pool.join();
        Ok(())
    }

    /// Run on a background thread; the returned handle stops and joins
    /// it. This is what the in-process tests, `serve --smoke` and the
    /// `serve_throughput` bench use.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.addr;
        let stop = self.stop.clone();
        let queue = self.queue.clone();
        let thread = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || self.run())
            .expect("spawn server thread");
        ServerHandle {
            addr,
            stop,
            queue,
            thread,
        }
    }
}

/// Handle to a [`Server::spawn`]ed background server.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<JobQueue>,
    thread: JoinHandle<Result<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown (equivalent to a client `shutdown` command):
    /// stop accepting, drain queued jobs, join workers.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.shutdown();
    }

    /// Wait for the server to exit (after [`stop`](ServerHandle::stop)
    /// or a client `shutdown`).
    pub fn join(self) -> Result<()> {
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("server thread panicked"),
        }
    }
}

/// One client connection: a reader loop on this thread plus a writer
/// thread draining the status-line channel. Jobs keep clones of the
/// channel sender, so the writer stays alive until every job of this
/// connection reached a terminal status — even if the reader saw EOF.
fn handle_conn(stream: TcpStream, ctx: &ConnCtx) {
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let (tx, rx) = mpsc::channel::<String>();
    let writer = std::thread::Builder::new()
        .name("serve-conn-writer".to_string())
        .spawn(move || {
            let mut w = BufWriter::new(write_half);
            for line in rx {
                if w.write_all(line.as_bytes()).is_err()
                    || w.write_all(b"\n").is_err()
                    || w.flush().is_err()
                {
                    break;
                }
            }
        });
    let Ok(writer) = writer else { return };

    for line in BufReader::new(stream).lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        if !handle_line(&line, &tx, ctx) {
            break;
        }
    }
    drop(tx);
    let _ = writer.join();
}

/// Dispatch one request line. Returns `false` when the connection
/// should close (after `shutdown`). Malformed lines cost an `error`
/// event, never the connection — let alone the server.
fn handle_line(line: &str, tx: &Sender<String>, ctx: &ConnCtx) -> bool {
    match Request::parse(line) {
        Err(e) => {
            let _ = tx.send(ev_error(&e.to_string()));
            true
        }
        Ok(Request::Submit(sub)) => {
            submit(&sub, tx, ctx);
            true
        }
        Ok(Request::Cancel { job }) => {
            match ctx.queue.cancel(job) {
                // never ran: this is the terminal event, sent to the
                // submitter through the job's own sender
                CancelOutcome::Dequeued(j) => {
                    let _ = j.out.send(ev_cancelled(j.id));
                }
                // running: the worker emits `cancelled` at the job's
                // next quota checkpoint
                CancelOutcome::Signalled => {}
                CancelOutcome::Unknown => {
                    let _ = tx.send(ev_error(&format!("no such job {job}")));
                }
            }
            true
        }
        Ok(Request::Stats) => {
            let _ = tx.send(stats_line(ctx));
            true
        }
        Ok(Request::Shutdown) => {
            let _ = tx.send(ev_bye());
            ctx.stop.store(true, Ordering::Relaxed);
            false
        }
    }
}

/// Validate and enqueue one submission.
fn submit(sub: &Submission, tx: &Sender<String>, ctx: &ConnCtx) {
    let Some(scenario) = coordinator::find(&sub.scenario) else {
        let _ = tx.send(ev_rejected(
            None,
            &sub.tag,
            &format!("unknown scenario '{}'", sub.scenario),
        ));
        return;
    };
    let mut cfg = match &sub.config {
        Some(j) => match ExperimentConfig::from_json(j) {
            Ok(cfg) => cfg,
            Err(e) => {
                let _ = tx.send(ev_rejected(None, &sub.tag, &format!("bad config: {e}")));
                return;
            }
        },
        None => scenario.default_config(),
    };
    if let Err(e) = cfg.apply_set(&sub.set) {
        let _ = tx.send(ev_rejected(None, &sub.tag, &format!("bad set: {e}")));
        return;
    }
    let id = ctx.queue.next_id();
    // `queued` goes out before the queue insert so a fast worker's
    // `preparing` can never beat it onto the wire
    let _ = tx.send(ev_queued(id, &sub.tag));
    let accepted = ctx.queue.submit(Job {
        id,
        tag: sub.tag.clone(),
        scenario,
        cfg,
        quota: sub.quota.to_spec().capped_by(ctx.server_quota),
        ctl: Arc::new(JobCtl::new()),
        out: tx.clone(),
    });
    if !accepted {
        let _ = tx.send(ev_rejected(Some(id), &sub.tag, "server shutting down"));
    }
}

fn stats_line(ctx: &ConnCtx) -> String {
    let st = ctx.cache.stats();
    Json::obj()
        .set("event", "stats")
        .set("queue_depth", ctx.queue.depth() as u64)
        .set("running", ctx.queue.running() as u64)
        .set(
            "cache",
            Json::obj()
                .set("prepared", st.misses)
                .set("reused", st.hits)
                .set("evicted", st.evictions)
                .set("resident_bytes", st.resident_bytes),
        )
        .to_string()
}
