//! FIFO job queue shared between connection threads and the worker
//! pool.
//!
//! Connections [`submit`](JobQueue::submit) jobs; workers block in
//! [`pop`](JobQueue::pop) until one is ready. Cancellation is
//! two-faced: a job still sitting in the queue is dequeued on the spot
//! (the connection emits `cancelled` itself), a job already claimed by
//! a worker only gets its [`JobCtl`] flag flipped and stops at its next
//! quota checkpoint. [`shutdown`](JobQueue::shutdown) is graceful:
//! already-queued jobs still drain, workers exit once the queue is
//! empty.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::{ExperimentConfig, Scenario};
use crate::serve::quota::{JobCtl, QuotaSpec};

/// One accepted submission, queued for a worker.
pub struct Job {
    pub id: u64,
    pub tag: String,
    pub scenario: &'static dyn Scenario,
    pub cfg: ExperimentConfig,
    pub quota: QuotaSpec,
    pub ctl: Arc<JobCtl>,
    /// Status-line sink of the submitting connection; sends fail
    /// silently once the client hangs up.
    pub out: Sender<String>,
}

/// Outcome of a cancel request.
pub enum CancelOutcome {
    /// Removed from the queue before any worker saw it; the caller
    /// emits the terminal `cancelled` event through the returned job's
    /// own sender (so the submitter is the one notified).
    Dequeued(Arc<Job>),
    /// Already running (or claimed); the control flag is set and the
    /// job stops at its next checkpoint.
    Signalled,
    /// No queued or running job with that id.
    Unknown,
}

#[derive(Default)]
struct QueueInner {
    fifo: VecDeque<Arc<Job>>,
    /// Every live job (queued or running), for cancel-by-id.
    jobs: HashMap<u64, Arc<Job>>,
    next_id: u64,
    shutdown: bool,
}

/// The shared FIFO queue.
#[derive(Default)]
pub struct JobQueue {
    inner: Mutex<QueueInner>,
    ready: Condvar,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// Reserve the next job id (ids are per-server, monotonically
    /// increasing from 1).
    pub fn next_id(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.next_id += 1;
        inner.next_id
    }

    /// Enqueue a job. Returns `false` (job dropped) after shutdown.
    pub fn submit(&self, job: Job) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.shutdown {
            return false;
        }
        let job = Arc::new(job);
        inner.jobs.insert(job.id, job.clone());
        inner.fifo.push_back(job);
        drop(inner);
        self.ready.notify_one();
        true
    }

    /// Block until a job is ready; `None` once the queue is shut down
    /// AND drained (workers use this as their exit signal).
    pub fn pop(&self) -> Option<Arc<Job>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(job) = inner.fifo.pop_front() {
                return Some(job);
            }
            if inner.shutdown {
                return None;
            }
            inner = self.ready.wait(inner).unwrap();
        }
    }

    /// Cancel a job by id (see [`CancelOutcome`]).
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let mut inner = self.inner.lock().unwrap();
        let Some(job) = inner.jobs.remove(&id) else {
            return CancelOutcome::Unknown;
        };
        if let Some(pos) = inner.fifo.iter().position(|j| j.id == id) {
            inner.fifo.remove(pos);
            CancelOutcome::Dequeued(job)
        } else {
            // claimed by a worker: flag it and let finish() already
            // having removed it from `jobs` be harmless
            job.ctl.cancel();
            inner.jobs.insert(id, job);
            CancelOutcome::Signalled
        }
    }

    /// Remove a finished job from the live set (worker calls this for
    /// every terminal outcome).
    pub fn finish(&self, id: u64) {
        self.inner.lock().unwrap().jobs.remove(&id);
    }

    /// Jobs waiting in the FIFO (excludes running ones).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().fifo.len()
    }

    /// Live jobs currently claimed by workers.
    pub fn running(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.jobs.len() - inner.fifo.len()
    }

    /// Stop accepting new jobs and wake all workers; queued jobs still
    /// drain before `pop` starts returning `None`.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator;
    use std::sync::mpsc;

    fn job(q: &JobQueue) -> (Job, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        let scenario = coordinator::find("traffic").unwrap();
        let job = Job {
            id: q.next_id(),
            tag: String::new(),
            scenario,
            cfg: scenario.default_config(),
            quota: QuotaSpec::default(),
            ctl: Arc::new(JobCtl::new()),
            out: tx,
        };
        (job, rx)
    }

    #[test]
    fn fifo_order_and_drain_on_shutdown() {
        let q = JobQueue::new();
        let (a, _ra) = job(&q);
        let (b, _rb) = job(&q);
        let (ida, idb) = (a.id, b.id);
        assert!(q.submit(a));
        assert!(q.submit(b));
        assert_eq!(q.depth(), 2);
        q.shutdown();
        // queued jobs still drain in order after shutdown
        assert_eq!(q.pop().unwrap().id, ida);
        assert_eq!(q.pop().unwrap().id, idb);
        assert!(q.pop().is_none());
        // and new submissions are refused
        let (c, _rc) = job(&q);
        assert!(!q.submit(c));
    }

    #[test]
    fn cancel_dequeues_or_signals() {
        let q = JobQueue::new();
        let (a, _ra) = job(&q);
        let (b, _rb) = job(&q);
        let (ida, idb) = (a.id, b.id);
        q.submit(a);
        q.submit(b);
        // cancel while queued: dequeued, never reaches a worker
        match q.cancel(ida) {
            CancelOutcome::Dequeued(j) => assert_eq!(j.id, ida),
            _ => panic!("expected Dequeued"),
        }
        assert_eq!(q.depth(), 1);
        // claim b like a worker would, then cancel: signalled
        let claimed = q.pop().unwrap();
        assert_eq!(claimed.id, idb);
        assert_eq!(q.running(), 1);
        assert!(matches!(q.cancel(idb), CancelOutcome::Signalled));
        assert!(claimed.ctl.is_cancelled());
        q.finish(idb);
        assert_eq!(q.running(), 0);
        // unknown id
        assert!(matches!(q.cancel(9999), CancelOutcome::Unknown));
    }

    #[test]
    fn pop_blocks_until_submit() {
        let q = Arc::new(JobQueue::new());
        let q2 = q.clone();
        let popper = std::thread::spawn(move || q2.pop().map(|j| j.id));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let (a, _ra) = job(&q);
        let id = a.id;
        q.submit(a);
        assert_eq!(popper.join().unwrap(), Some(id));
    }
}
