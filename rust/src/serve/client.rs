//! Programmatic service client and the `loadgen` throughput driver.
//!
//! [`Client`] is the minimal blocking client: connect, send
//! [`Request`]s, read streamed [`Event`]s one line at a time (see
//! `examples/serve_client.rs` for end-to-end usage).
//!
//! [`run_loadgen`] replays hundreds of concurrent submissions against a
//! server from multiple pipelined connections with a seeded arrival
//! process — the load generator behind `bss-extoll loadgen`, the
//! `serve_throughput` bench section and `serve --smoke`. With
//! `verify: true` it re-runs every unique submission through the batch
//! `Scenario::run` path in-process and checks the served reports
//! byte-identical — the acceptance gate tying service mode to the
//! repo's determinism invariant.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator;
use crate::serve::protocol::{Event, QuotaReq, Request, Submission};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Histogram;

/// Minimal blocking JSON-lines client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let writer = stream.try_clone().context("clone stream")?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// Send one request line.
    pub fn send(&mut self, req: &Request) -> Result<()> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("send request")?;
        Ok(())
    }

    /// Block for the next status event (skips blank lines; errors on
    /// EOF).
    pub fn next_event(&mut self) -> Result<Event> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line).context("read event")? == 0 {
                bail!("server closed the connection");
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            return Event::parse(trimmed);
        }
    }
}

/// Load-generator parameters (CLI flags of `bss-extoll loadgen`).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address to drive.
    pub addr: String,
    /// Total submissions across all connections.
    pub submissions: usize,
    /// Concurrent pipelined connections.
    pub connections: usize,
    /// Scenario names cycled across submissions.
    pub scenarios: Vec<String>,
    /// Seed of the arrival-jitter / parameter-variation process.
    pub seed: u64,
    /// Overrides applied to every submission (shrinks the default
    /// machine so a single run is a few milliseconds).
    pub base_set: String,
    /// Re-run every unique submission via the batch path in-process
    /// and compare the served reports byte-for-byte.
    pub verify: bool,
    /// Send `shutdown` once done (used by `serve --smoke`).
    pub shutdown_after: bool,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: String::new(),
            submissions: 120,
            connections: 8,
            scenarios: vec!["traffic".into(), "burst".into(), "hotspot".into()],
            seed: 1,
            base_set: default_base_set().to_string(),
            verify: false,
            shutdown_after: false,
        }
    }
}

/// The default `--base-set`: a 2-wafer machine and a 200 µs window, so
/// one submission costs milliseconds, not seconds.
pub fn default_base_set() -> &'static str {
    "n_wafers=2;torus=2x2x1;fpgas_per_wafer=4;concentrators_per_wafer=2;\
     sources_per_fpga=8;duration_s=0.0002;rate_hz=2e6"
}

/// Aggregated result of one loadgen round.
pub struct LoadgenOutcome {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub cancelled: u64,
    /// Submit-to-done turnaround per completed job, in µs.
    pub turnaround_us: Histogram,
    pub wall: Duration,
    /// Unique (scenario, set) pairs re-run locally for verification
    /// (0 when `verify` was off).
    pub verified: u64,
    /// Served reports that differed from the batch path (must be 0).
    pub mismatches: u64,
    /// Final server cache counters (`stats` event body).
    pub cache: Option<Json>,
}

impl LoadgenOutcome {
    pub fn subs_per_s(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Whether every verified report matched the batch path
    /// byte-for-byte (vacuously true when `verify` was off).
    pub fn byte_identical(&self) -> bool {
        self.mismatches == 0
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("cancelled", self.cancelled)
            .set("wall_s", self.wall.as_secs_f64())
            .set("subs_per_s", self.subs_per_s())
            .set("turnaround_p50_us", self.turnaround_us.p50())
            .set("turnaround_p95_us", self.turnaround_us.quantile(0.95))
            .set("verified", self.verified)
            .set("mismatches", self.mismatches)
            .set("reports_byte_identical", self.byte_identical());
        if let Some(cache) = &self.cache {
            if let Some(c) = cache.get("cache") {
                j = j.set("cache", c.clone());
            }
        }
        j
    }
}

/// What one connection thread brings home.
struct ConnResult {
    completed: u64,
    rejected: u64,
    cancelled: u64,
    turnarounds_us: Vec<u64>,
    /// (scenario, set, served report JSON) per completed job.
    reports: Vec<(String, String, String)>,
}

/// One planned submission.
#[derive(Clone)]
struct PlannedSub {
    scenario: String,
    set: String,
    /// Pre-send pause in µs (seeded arrival process).
    gap_us: u64,
}

/// Drive one loadgen round against a running server.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenOutcome> {
    if cfg.submissions == 0 || cfg.scenarios.is_empty() {
        bail!("loadgen needs at least one submission and one scenario");
    }
    let connections = cfg.connections.clamp(1, cfg.submissions);

    // Plan all submissions up-front (deterministic given the seed):
    // scenarios cycle, the seed knob varies over a small pool so
    // distinct cache keys stay far below the submission count, and
    // arrivals get a small exponential-ish gap.
    let mut rng = Rng::new(cfg.seed);
    let plan: Vec<PlannedSub> = (0..cfg.submissions)
        .map(|i| {
            let scenario = cfg.scenarios[i % cfg.scenarios.len()].clone();
            let seed = 1 + rng.below(3);
            let rate_scale = 1 + rng.below(2);
            let set = format!(
                "{};seed={};rate_hz={}e6",
                cfg.base_set, seed, rate_scale
            );
            PlannedSub {
                scenario,
                set,
                gap_us: rng.below(500),
            }
        })
        .collect();

    let started = Instant::now();
    let results: Vec<ConnResult> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..connections)
            .map(|c| {
                // round-robin striping of the plan over connections
                let mine: Vec<(usize, PlannedSub)> = plan
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % connections == c)
                    .map(|(i, p)| (i, p.clone()))
                    .collect();
                let addr = cfg.addr.clone();
                s.spawn(move || drive_connection(&addr, &mine))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen connection thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall = started.elapsed();

    let mut outcome = LoadgenOutcome {
        submitted: cfg.submissions as u64,
        completed: 0,
        rejected: 0,
        cancelled: 0,
        turnaround_us: Histogram::new(),
        wall,
        verified: 0,
        mismatches: 0,
        cache: None,
    };
    let mut reports = Vec::new();
    for r in results {
        outcome.completed += r.completed;
        outcome.rejected += r.rejected;
        outcome.cancelled += r.cancelled;
        for t in r.turnarounds_us {
            outcome.turnaround_us.record(t);
        }
        reports.extend(r.reports);
    }

    if cfg.verify {
        let (verified, mismatches) = verify_reports(&reports)?;
        outcome.verified = verified;
        outcome.mismatches = mismatches;
    }

    // Final counters (and optional shutdown) over a fresh connection.
    let mut client = Client::connect(&cfg.addr)?;
    client.send(&Request::Stats)?;
    if let Event::Stats { body } = client.next_event()? {
        outcome.cache = Some(body);
    }
    if cfg.shutdown_after {
        client.send(&Request::Shutdown)?;
        loop {
            match client.next_event() {
                Ok(Event::Bye) | Err(_) => break,
                Ok(_) => continue,
            }
        }
    }
    Ok(outcome)
}

/// Pipeline `mine` down one connection: send everything up-front (with
/// the planned gaps), then read events until every submission reached a
/// terminal status.
fn drive_connection(addr: &str, mine: &[(usize, PlannedSub)]) -> Result<ConnResult> {
    let mut client = Client::connect(addr)?;
    let mut sent_at: HashMap<String, Instant> = HashMap::new();
    for (idx, sub) in mine {
        if sub.gap_us > 0 {
            std::thread::sleep(Duration::from_micros(sub.gap_us));
        }
        let tag = format!("lg-{idx}");
        sent_at.insert(tag.clone(), Instant::now());
        client.send(&Request::Submit(Submission {
            scenario: sub.scenario.clone(),
            set: sub.set.clone(),
            config: None,
            tag,
            quota: QuotaReq::default(),
        }))?;
    }

    let by_tag: HashMap<String, &PlannedSub> = mine
        .iter()
        .map(|(idx, sub)| (format!("lg-{idx}"), sub))
        .collect();
    let mut job_tag: HashMap<u64, String> = HashMap::new();
    let mut result = ConnResult {
        completed: 0,
        rejected: 0,
        cancelled: 0,
        turnarounds_us: Vec::new(),
        reports: Vec::new(),
    };
    let mut terminal = 0usize;
    while terminal < mine.len() {
        match client.next_event()? {
            Event::Queued { job, tag } => {
                job_tag.insert(job, tag);
            }
            Event::Preparing { .. } | Event::Running { .. } => {}
            Event::Done { job, report } => {
                terminal += 1;
                result.completed += 1;
                let Some(tag) = job_tag.get(&job) else {
                    bail!("done for unknown job {job}");
                };
                if let Some(at) = sent_at.get(tag) {
                    result
                        .turnarounds_us
                        .push(at.elapsed().as_micros() as u64);
                }
                let sub = by_tag[tag.as_str()];
                result.reports.push((
                    sub.scenario.clone(),
                    sub.set.clone(),
                    report.to_string(),
                ));
            }
            Event::Rejected { .. } => {
                terminal += 1;
                result.rejected += 1;
            }
            Event::Cancelled { .. } => {
                terminal += 1;
                result.cancelled += 1;
            }
            Event::Stats { .. } | Event::Bye => {}
            Event::Error { reason } => bail!("server error: {reason}"),
        }
    }
    Ok(result)
}

/// Re-run every unique (scenario, set) through the batch path and count
/// served reports that differ byte-for-byte.
fn verify_reports(reports: &[(String, String, String)]) -> Result<(u64, u64)> {
    let mut expected: HashMap<(String, String), String> = HashMap::new();
    let mut mismatches = 0u64;
    for (scenario_name, set, served) in reports {
        let key = (scenario_name.clone(), set.clone());
        if !expected.contains_key(&key) {
            let scenario = coordinator::find(scenario_name)
                .with_context(|| format!("unknown scenario '{scenario_name}'"))?;
            let mut cfg = scenario.default_config();
            cfg.apply_set(set)?;
            let report = scenario.run(&cfg)?;
            expected.insert(key.clone(), report.to_json().to_string());
        }
        if expected[&key] != *served {
            mismatches += 1;
        }
    }
    Ok((expected.len() as u64, mismatches))
}
