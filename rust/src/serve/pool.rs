//! Bounded worker pool draining the [`JobQueue`].
//!
//! Each worker claims one job at a time, resolves its prepared
//! resources through the server-wide shared [`ResourceCache`] (this is
//! what makes the cache *cross-submission*: two clients submitting the
//! same cache key share one prepare), installs the job's quota control
//! and runs `Scenario::execute`, streaming status lines back through
//! the job's connection sender. A panicking execute is contained with
//! `catch_unwind` — it costs the job, never the worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::ResourceCache;
use crate::serve::protocol::{
    ev_cancelled, ev_done, ev_preparing, ev_rejected, ev_running,
};
use crate::serve::queue::{Job, JobQueue};
use crate::serve::quota::{self, Interrupt};

/// The running pool; [`join`](WorkerPool::join) after the queue's
/// shutdown to wait for in-flight jobs.
pub struct WorkerPool {
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads draining `queue` against the shared
    /// `cache`.
    pub fn spawn(workers: usize, queue: Arc<JobQueue>, cache: Arc<ResourceCache>) -> WorkerPool {
        let workers = (0..workers.max(1))
            .map(|i| {
                let queue = queue.clone();
                let cache = cache.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&queue, &cache))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { workers }
    }

    /// Wait for all workers to exit (they do once the queue is shut
    /// down and drained).
    pub fn join(self) {
        for w in self.workers {
            // a worker panicking would be a pool bug, not a job error
            // (job panics are contained inside the loop)
            let _ = w.join();
        }
    }
}

fn worker_loop(queue: &JobQueue, cache: &ResourceCache) {
    while let Some(job) = queue.pop() {
        run_job(&job, cache);
        queue.finish(job.id);
    }
}

/// Run one job to a terminal status line. Send failures are ignored
/// throughout: a vanished client must not take the worker with it.
fn run_job(job: &Job, cache: &ResourceCache) {
    if job.ctl.is_cancelled() {
        // cancelled between claim and start
        let _ = job.out.send(ev_cancelled(job.id));
        return;
    }

    // Label only (racy by nature, see ResourceCache::contains): whether
    // this key was already resident when we got here.
    let key = job.scenario.cache_key(&job.cfg);
    let _ = job
        .out
        .send(ev_preparing(job.id, cache.contains(&key)));

    let prepared = match cache.get_or_prepare(job.scenario, &job.cfg) {
        Ok(p) => p,
        Err(e) => {
            let _ = job
                .out
                .send(ev_rejected(Some(job.id), &job.tag, &format!("prepare failed: {e}")));
            return;
        }
    };

    let _ = job.out.send(ev_running(job.id, 0));
    let progress_out = job.out.clone();
    let progress_id = job.id;
    let guard = quota::activate(
        job.ctl.clone(),
        job.quota,
        Some(Box::new(move |events_done| {
            let _ = progress_out.send(ev_running(progress_id, events_done));
        })),
    );

    let result = catch_unwind(AssertUnwindSafe(|| {
        job.scenario.execute(prepared.as_ref(), &job.cfg)
    }));
    drop(guard);

    let line = match result {
        Ok(Ok(report)) => ev_done(job.id, report.to_json()),
        Ok(Err(e)) => match e.downcast_ref::<Interrupt>() {
            Some(Interrupt::Cancelled) => ev_cancelled(job.id),
            Some(i @ (Interrupt::WallQuota | Interrupt::EventQuota)) => {
                ev_rejected(Some(job.id), &job.tag, &format!("quota: {i}"))
            }
            None => ev_rejected(Some(job.id), &job.tag, &format!("execute failed: {e}")),
        },
        Err(_panic) => ev_rejected(Some(job.id), &job.tag, "execute panicked"),
    };
    let _ = job.out.send(line);
}
