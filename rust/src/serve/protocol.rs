//! JSON-lines wire protocol of the experiment service.
//!
//! Every message is one JSON object per line, newline-terminated, in
//! both directions. Requests carry a `"cmd"` discriminator, responses
//! an `"event"` discriminator. The grammar (also documented in
//! `docs/ARCHITECTURE.md` §7):
//!
//! ```text
//! client → server
//!   {"cmd":"submit","scenario":S,"set":OVR?,"config":{..}?,"tag":T?,
//!    "quota":{"max_wall_ms":N?,"max_events":N?}?}
//!   {"cmd":"cancel","job":ID}
//!   {"cmd":"stats"}
//!   {"cmd":"shutdown"}
//!
//! server → client
//!   {"event":"queued","job":ID,"tag":T}
//!   {"event":"preparing","job":ID,"cache":"prepare"|"reuse"}
//!   {"event":"running","job":ID,"events_done":N}
//!   {"event":"done","job":ID,"report":{..}}
//!   {"event":"cancelled","job":ID}
//!   {"event":"rejected","job":ID?,"tag":T?,"reason":R}
//!   {"event":"stats","queue_depth":N,"running":N,
//!    "cache":{"prepared":N,"reused":N,"evicted":N,"resident_bytes":N}}
//!   {"event":"error","reason":R}
//!   {"event":"bye"}
//! ```
//!
//! The parser is deliberately forgiving about unknown keys (forward
//! compatibility) and strict about the discriminator and types.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::serve::quota::QuotaSpec;
use crate::util::json::Json;

/// One experiment submission.
#[derive(Clone, Debug, Default)]
pub struct Submission {
    /// Registered scenario name (`traffic`, `microcircuit`, ...).
    pub scenario: String,
    /// `key=value;key=value` overrides applied on top of the config
    /// (same grammar as the CLI `--set` flag).
    pub set: String,
    /// Optional full experiment config; defaults to the scenario's
    /// default config when absent.
    pub config: Option<Json>,
    /// Client-chosen label echoed back in `queued` (correlates the
    /// submission with its job id on pipelined connections).
    pub tag: String,
    /// Requested budgets; the server caps them by its own limits.
    pub quota: QuotaReq,
}

/// Wire form of a quota request. `None` = "no limit requested".
#[derive(Clone, Copy, Debug, Default)]
pub struct QuotaReq {
    pub max_wall_ms: Option<u64>,
    pub max_events: Option<u64>,
}

impl QuotaReq {
    pub fn to_spec(self) -> QuotaSpec {
        QuotaSpec {
            max_wall: self.max_wall_ms.map(Duration::from_millis),
            max_events: self.max_events,
        }
    }
}

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    Submit(Submission),
    Cancel { job: u64 },
    Stats,
    Shutdown,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).map_err(|e| anyhow!("bad request JSON: {e}"))?;
        let cmd = j
            .get("cmd")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request is missing string key 'cmd'"))?;
        match cmd {
            "submit" => {
                let scenario = j
                    .get("scenario")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("submit is missing string key 'scenario'"))?
                    .to_string();
                let set = j.str_or("set", "").to_string();
                let config = j.get("config").filter(|c| !matches!(c, Json::Null)).cloned();
                let tag = j.str_or("tag", "").to_string();
                let quota = match j.get("quota") {
                    Some(q) => QuotaReq {
                        max_wall_ms: q.get("max_wall_ms").and_then(Json::as_u64),
                        max_events: q.get("max_events").and_then(Json::as_u64),
                    },
                    None => QuotaReq::default(),
                };
                Ok(Request::Submit(Submission {
                    scenario,
                    set,
                    config,
                    tag,
                    quota,
                }))
            }
            "cancel" => {
                let job = j
                    .get("job")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("cancel is missing integer key 'job'"))?;
                Ok(Request::Cancel { job })
            }
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown cmd '{other}'"),
        }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit(s) => {
                let mut j = Json::obj()
                    .set("cmd", "submit")
                    .set("scenario", s.scenario.as_str());
                if !s.set.is_empty() {
                    j = j.set("set", s.set.as_str());
                }
                if let Some(cfg) = &s.config {
                    j = j.set("config", cfg.clone());
                }
                if !s.tag.is_empty() {
                    j = j.set("tag", s.tag.as_str());
                }
                if s.quota.max_wall_ms.is_some() || s.quota.max_events.is_some() {
                    let mut q = Json::obj();
                    if let Some(ms) = s.quota.max_wall_ms {
                        q = q.set("max_wall_ms", ms);
                    }
                    if let Some(ev) = s.quota.max_events {
                        q = q.set("max_events", ev);
                    }
                    j = j.set("quota", q);
                }
                j
            }
            Request::Cancel { job } => Json::obj().set("cmd", "cancel").set("job", *job),
            Request::Stats => Json::obj().set("cmd", "stats"),
            Request::Shutdown => Json::obj().set("cmd", "shutdown"),
        }
    }
}

/// A parsed server status event (client side).
#[derive(Clone, Debug)]
pub enum Event {
    Queued { job: u64, tag: String },
    Preparing { job: u64, reused: bool },
    Running { job: u64, events_done: u64 },
    Done { job: u64, report: Json },
    Cancelled { job: u64 },
    Rejected { job: Option<u64>, tag: String, reason: String },
    Stats { body: Json },
    Error { reason: String },
    Bye,
}

impl Event {
    /// Parse one status line.
    pub fn parse(line: &str) -> Result<Event> {
        let j = Json::parse(line).map_err(|e| anyhow!("bad event JSON: {e}"))?;
        let event = j
            .get("event")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("status line is missing string key 'event'"))?;
        let job = || {
            j.get("job")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("'{event}' event is missing integer key 'job'"))
        };
        match event {
            "queued" => Ok(Event::Queued {
                job: job()?,
                tag: j.str_or("tag", "").to_string(),
            }),
            "preparing" => Ok(Event::Preparing {
                job: job()?,
                reused: j.str_or("cache", "prepare") == "reuse",
            }),
            "running" => Ok(Event::Running {
                job: job()?,
                events_done: j.u64_or("events_done", 0),
            }),
            "done" => Ok(Event::Done {
                job: job()?,
                report: j
                    .get("report")
                    .cloned()
                    .ok_or_else(|| anyhow!("'done' event is missing key 'report'"))?,
            }),
            "cancelled" => Ok(Event::Cancelled { job: job()? }),
            "rejected" => Ok(Event::Rejected {
                job: j.get("job").and_then(Json::as_u64),
                tag: j.str_or("tag", "").to_string(),
                reason: j.str_or("reason", "").to_string(),
            }),
            "stats" => Ok(Event::Stats { body: j }),
            "error" => Ok(Event::Error {
                reason: j.str_or("reason", "").to_string(),
            }),
            "bye" => Ok(Event::Bye),
            other => bail!("unknown event '{other}'"),
        }
    }
}

// ---- server-side event constructors (single source of wire shapes) ----

pub fn ev_queued(job: u64, tag: &str) -> String {
    Json::obj()
        .set("event", "queued")
        .set("job", job)
        .set("tag", tag)
        .to_string()
}

pub fn ev_preparing(job: u64, reused: bool) -> String {
    Json::obj()
        .set("event", "preparing")
        .set("job", job)
        .set("cache", if reused { "reuse" } else { "prepare" })
        .to_string()
}

pub fn ev_running(job: u64, events_done: u64) -> String {
    Json::obj()
        .set("event", "running")
        .set("job", job)
        .set("events_done", events_done)
        .to_string()
}

pub fn ev_done(job: u64, report: Json) -> String {
    Json::obj()
        .set("event", "done")
        .set("job", job)
        .set("report", report)
        .to_string()
}

pub fn ev_cancelled(job: u64) -> String {
    Json::obj()
        .set("event", "cancelled")
        .set("job", job)
        .to_string()
}

pub fn ev_rejected(job: Option<u64>, tag: &str, reason: &str) -> String {
    let mut j = Json::obj().set("event", "rejected");
    if let Some(id) = job {
        j = j.set("job", id);
    }
    if !tag.is_empty() {
        j = j.set("tag", tag);
    }
    j.set("reason", reason).to_string()
}

pub fn ev_error(reason: &str) -> String {
    Json::obj()
        .set("event", "error")
        .set("reason", reason)
        .to_string()
}

pub fn ev_bye() -> String {
    Json::obj().set("event", "bye").to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trips() {
        let sub = Submission {
            scenario: "traffic".into(),
            set: "seed=7;rate_hz=1e6".into(),
            config: None,
            tag: "t-3".into(),
            quota: QuotaReq {
                max_wall_ms: Some(5_000),
                max_events: None,
            },
        };
        let line = Request::Submit(sub).to_json().to_string();
        match Request::parse(&line).unwrap() {
            Request::Submit(s) => {
                assert_eq!(s.scenario, "traffic");
                assert_eq!(s.set, "seed=7;rate_hz=1e6");
                assert_eq!(s.tag, "t-3");
                assert_eq!(s.quota.max_wall_ms, Some(5_000));
                assert_eq!(s.quota.max_events, None);
                assert!(s.config.is_none());
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn cancel_stats_shutdown_round_trip() {
        for (req, want_cmd) in [
            (Request::Cancel { job: 12 }, "cancel"),
            (Request::Stats, "stats"),
            (Request::Shutdown, "shutdown"),
        ] {
            let line = req.to_json().to_string();
            assert!(line.contains(want_cmd));
            Request::parse(&line).unwrap();
        }
        match Request::parse("{\"cmd\":\"cancel\",\"job\":12}").unwrap() {
            Request::Cancel { job } => assert_eq!(job, 12),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        for bad in [
            "not json at all",
            "{}",
            "{\"cmd\":\"frobnicate\"}",
            "{\"cmd\":\"submit\"}",
            "{\"cmd\":\"cancel\"}",
        ] {
            assert!(Request::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn events_round_trip() {
        match Event::parse(&ev_queued(4, "a")).unwrap() {
            Event::Queued { job, tag } => {
                assert_eq!((job, tag.as_str()), (4, "a"));
            }
            other => panic!("parsed {other:?}"),
        }
        match Event::parse(&ev_preparing(4, true)).unwrap() {
            Event::Preparing { reused, .. } => assert!(reused),
            other => panic!("parsed {other:?}"),
        }
        match Event::parse(&ev_running(4, 777)).unwrap() {
            Event::Running { events_done, .. } => assert_eq!(events_done, 777),
            other => panic!("parsed {other:?}"),
        }
        match Event::parse(&ev_done(4, Json::obj().set("x", 1u64))).unwrap() {
            Event::Done { job, report } => {
                assert_eq!(job, 4);
                assert_eq!(report.u64_or("x", 0), 1);
            }
            other => panic!("parsed {other:?}"),
        }
        match Event::parse(&ev_rejected(None, "t", "nope")).unwrap() {
            Event::Rejected { job, tag, reason } => {
                assert_eq!(job, None);
                assert_eq!(tag, "t");
                assert_eq!(reason, "nope");
            }
            other => panic!("parsed {other:?}"),
        }
        assert!(matches!(Event::parse(&ev_cancelled(4)).unwrap(), Event::Cancelled { job: 4 }));
        assert!(matches!(Event::parse(&ev_error("x")).unwrap(), Event::Error { .. }));
        assert!(matches!(Event::parse(&ev_bye()).unwrap(), Event::Bye));
    }
}
