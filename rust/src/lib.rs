//! # bss-extoll — BrainScaleS large-scale spike communication over Extoll
//!
//! A production-quality reproduction of *"BrainScaleS Large Scale Spike
//! Communication using Extoll"* (Thommes et al., NICE 2020/2021): a
//! cycle-approximate discrete-event simulator of the Extoll network fabric
//! (Tourmalet NIC, 3D torus), the BrainScaleS FPGA communication logic
//! (event aggregation buckets with renaming, map table, free-bucket list,
//! deadline arbiter), the RMA ring-buffer host protocol, and a multi-wafer
//! neuromorphic experiment coordinator that drives AOT-compiled JAX/Pallas
//! LIF neuron models — Python never on the request path.
//!
//! ## Layer map
//!
//! (The full architecture book — layer responsibilities, the
//! event-ordering/determinism contract, PDES lookahead invariant, and a
//! spike's end-to-end walkthrough — is `docs/ARCHITECTURE.md`; runtime
//! knob guidance is `docs/TUNING.md`.)
//!
//! - **L3 (this crate)** — coordination, simulation, routing, batching.
//!   Experiments are `Scenario`s dispatched from a registry
//!   (`bss-extoll run <scenario>`), reporting into one metric-keyed
//!   [`util::report::Report`]; parameter grids run through
//!   [`coordinator::sweep::SweepRunner`].
//! - **L2** — `python/compile/model.py`: JAX wafer-shard step function,
//!   lowered once to `artifacts/*.hlo.txt` (+ manifest).
//! - **L1** — `python/compile/kernels/`: Pallas LIF + synapse kernels.
//!   This offline build executes the artifact semantics with a native
//!   interpreter (see [`runtime::client`]); the PJRT backend slots back
//!   in behind the same `Runtime`/`ShardModel` surface.
//!
//! ## Module overview
//!
//! | module | role |
//! |---|---|
//! | [`util`] | zero-dependency substrates: args, json, rng, stats, report, bench |
//! | [`sim`] | discrete-event simulation engine (ps clock, actors) |
//! | [`extoll`] | Tourmalet NIC, links, 3D torus, routing, RMA, baselines |
//! | [`fault`] | fault injection: link failure/degradation schedules, loss, jitter |
//! | [`fpga`] | spike events, lookup tables, aggregation buckets, manager |
//! | [`host`] | ring-buffer host communication and driver model |
//! | [`wafer`] | wafer modules, concentrators, system builder + fabric reports |
//! | [`workload`] | Poisson/regular/burst generators, cortical microcircuit |
//! | [`runtime`] | artifact loader + shard-step execution backend |
//! | [`neuro`] | LIF shard state bridging runtime artifacts ⇄ the simulation |
//! | [`coordinator`] | config, `Scenario` trait + registry, sweep runner, reports |
//! | [`serve`] | experiment service mode: TCP job server, queue, worker pool, quotas, loadgen |

pub mod coordinator;
pub mod extoll;
pub mod fault;
pub mod msg;
pub mod fpga;
pub mod host;
pub mod neuro;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod wafer;
pub mod workload;

/// Crate version string (from Cargo).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
