//! Minimal JSON parser/emitter (RFC 8259 subset, no external deps).
//!
//! Used for experiment configuration files, metric reports and benchmark
//! result records. Numbers are kept as `f64` (plus an exact-integer fast
//! path on emit); strings support the standard escapes including `\uXXXX`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic emission order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Insert into an object in place.
    pub fn insert(&mut self, key: &str, value: impl Into<Json>) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::insert on non-object"),
        }
    }

    /// Push onto an array in place.
    pub fn push(&mut self, value: impl Into<Json>) {
        match self {
            Json::Arr(v) => v.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `cfg.at(&["network", "torus", "x"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed lookup helpers with defaults (config ergonomics).
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ---- parse -----------------------------------------------------------

    /// Parse a JSON document. Returns a descriptive error with byte offset.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- emit ------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x.fract() == 0.0 && x.abs() < 9.2e18 {
        // exact integer path avoids "1e6"-style output for counters
        let _ = fmt::Write::write_fmt(out, format_args!("{}", x as i64));
    } else if x.is_finite() {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", x));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => {
                    self.pos += 1;
                }
                _ => break,
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // fast path: run of plain bytes
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                s.push_str(
                    std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8 in string"))?,
                );
            }
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d as u32;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---- From conversions ----------------------------------------------------

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, false, null], "c": {"d": "hi\n", "e": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["c", "e"]).unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(v.get("a").unwrap().as_u64().unwrap(), 1);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn builder_and_accessors() {
        let j = Json::obj()
            .set("name", "bucket")
            .set("size", 124u64)
            .set("ratio", 0.5)
            .set("on", true);
        assert_eq!(j.str_or("name", ""), "bucket");
        assert_eq!(j.u64_or("size", 0), 124);
        assert_eq!(j.f64_or("ratio", 0.0), 0.5);
        assert!(j.bool_or("on", false));
        assert_eq!(j.u64_or("missing", 7), 7);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndAé");
        // re-emit and re-parse
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn surrogate_pairs() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integer_emission_is_exact() {
        let j = Json::from(1_000_000u64);
        assert_eq!(j.to_string(), "1000000");
        let j = Json::from(0.5);
        assert_eq!(j.to_string(), "0.5");
    }

    #[test]
    fn nan_becomes_null() {
        let j = Json::Num(f64::NAN);
        assert_eq!(j.to_string(), "null");
    }

    #[test]
    fn pretty_output_parses_back() {
        let j = Json::obj().set(
            "rows",
            vec![
                Json::obj().set("x", 1u64).set("y", 2u64),
                Json::obj().set("x", 3u64).set("y", 4u64),
            ],
        );
        let p = j.pretty();
        assert!(p.contains('\n'));
        assert_eq!(Json::parse(&p).unwrap(), j);
    }

    #[test]
    fn deep_nesting() {
        let mut src = String::new();
        for _ in 0..100 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..100 {
            src.push(']');
        }
        let v = Json::parse(&src).unwrap();
        let mut cur = &v;
        for _ in 0..100 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_u64().unwrap(), 1);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"grüßen 中文\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "grüßen 中文");
    }
}
