//! Declarative command-line argument parsing (offline replacement for clap).
//!
//! Supports subcommands, `--key value`, `--key=value`, boolean `--flag`s,
//! positional arguments, defaults, and auto-generated `--help` text.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the rpath to libxla_extension)
//! use bss_extoll::util::args::ArgSpec;
//! let spec = ArgSpec::new("simulate", "run a spike-communication simulation")
//!     .opt("wafers", "4", "number of wafer modules")
//!     .flag("verbose", "chatty output")
//!     .pos("config", "path to experiment config JSON");
//! let parsed = spec.parse(&["--wafers".into(), "2".into(), "cfg.json".into()]).unwrap();
//! assert_eq!(parsed.get_u64("wafers"), 2);
//! assert_eq!(parsed.positional("config").unwrap(), "cfg.json");
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// One named option (with default) or boolean flag.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    default: Option<String>, // None ⇒ boolean flag
    help: String,
}

/// Declarative specification of a (sub)command's arguments.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    pub name: String,
    pub about: String,
    opts: Vec<Opt>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed argument values.
#[derive(Clone, Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: BTreeMap<String, String>,
}

/// Argument parsing error (unknown option, missing value, bad number ...).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgError(pub String);

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl ArgSpec {
    pub fn new(name: &str, about: &str) -> Self {
        ArgSpec {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Add a valued option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            default: Some(default.to_string()),
            help: help.to_string(),
        });
        self
    }

    /// Add a boolean flag (defaults to false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
        });
        self
    }

    /// Add a required positional argument.
    pub fn pos(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                match &o.default {
                    Some(d) => s.push_str(&format!(
                        "  --{} <value>  {} [default: {}]\n",
                        o.name, o.help, d
                    )),
                    None => s.push_str(&format!("  --{}  {}\n", o.name, o.help)),
                }
            }
        }
        s
    }

    /// Parse a token list (not including argv[0] / the subcommand name).
    pub fn parse(&self, tokens: &[String]) -> Result<Parsed, ArgError> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for o in &self.opts {
            match &o.default {
                Some(d) => {
                    values.insert(o.name.clone(), d.clone());
                }
                None => {
                    flags.insert(o.name.clone(), false);
                }
            }
        }
        let mut positionals = BTreeMap::new();
        let mut pos_idx = 0usize;

        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if key == "help" {
                    return Err(ArgError(self.help()));
                }
                if flags.contains_key(&key) {
                    if let Some(v) = inline_val {
                        let b = v
                            .parse::<bool>()
                            .map_err(|_| ArgError(format!("--{key} expects true/false")))?;
                        flags.insert(key, b);
                    } else {
                        flags.insert(key, true);
                    }
                } else if values.contains_key(&key) {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| ArgError(format!("--{key} requires a value")))?
                        }
                    };
                    values.insert(key, v);
                } else {
                    return Err(ArgError(format!(
                        "unknown option --{key} (see --help for {})",
                        self.name
                    )));
                }
            } else {
                let slot = self
                    .positionals
                    .get(pos_idx)
                    .ok_or_else(|| ArgError(format!("unexpected positional argument '{tok}'")))?;
                positionals.insert(slot.0.clone(), tok.clone());
                pos_idx += 1;
            }
            i += 1;
        }

        if pos_idx < self.positionals.len() {
            return Err(ArgError(format!(
                "missing required argument <{}>",
                self.positionals[pos_idx].0
            )));
        }

        Ok(Parsed {
            values,
            flags,
            positionals,
        })
    }
}

impl Parsed {
    /// Raw string value of an option (panics on unknown name — spec bug).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not in spec"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} is not a valid integer: {}", self.get(name)))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get_u64(name) as usize
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} is not a valid number: {}", self.get(name)))
    }

    /// Checked variants (for user-facing error messages).
    pub fn try_u64(&self, name: &str) -> Result<u64, ArgError> {
        self.get(name)
            .parse()
            .map_err(|_| ArgError(format!("--{name}: expected integer, got '{}'", self.get(name))))
    }

    pub fn try_f64(&self, name: &str) -> Result<f64, ArgError> {
        self.get(name)
            .parse()
            .map_err(|_| ArgError(format!("--{name}: expected number, got '{}'", self.get(name))))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not in spec"))
    }

    pub fn positional(&self, name: &str) -> Option<&str> {
        self.positionals.get(name).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "test command")
            .opt("wafers", "4", "wafer count")
            .opt("rate", "0.5", "event rate")
            .flag("verbose", "chatty")
            .pos("config", "config path")
    }

    fn toks(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&toks(&["cfg.json"])).unwrap();
        assert_eq!(p.get_u64("wafers"), 4);
        assert_eq!(p.get_f64("rate"), 0.5);
        assert!(!p.flag("verbose"));
        assert_eq!(p.positional("config").unwrap(), "cfg.json");
    }

    #[test]
    fn space_and_equals_forms() {
        let p = spec()
            .parse(&toks(&["--wafers", "8", "--rate=0.9", "c.json"]))
            .unwrap();
        assert_eq!(p.get_u64("wafers"), 8);
        assert_eq!(p.get_f64("rate"), 0.9);
    }

    #[test]
    fn flags_set() {
        let p = spec().parse(&toks(&["--verbose", "c.json"])).unwrap();
        assert!(p.flag("verbose"));
        let p = spec().parse(&toks(&["--verbose=false", "c.json"])).unwrap();
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        let e = spec().parse(&toks(&["--nope", "1", "c.json"])).unwrap_err();
        assert!(e.0.contains("unknown option"));
    }

    #[test]
    fn missing_value_errors() {
        let e = spec().parse(&toks(&["c.json", "--wafers"])).unwrap_err();
        assert!(e.0.contains("requires a value"));
    }

    #[test]
    fn missing_positional_errors() {
        let e = spec().parse(&toks(&["--wafers", "2"])).unwrap_err();
        assert!(e.0.contains("missing required argument"));
    }

    #[test]
    fn extra_positional_errors() {
        let e = spec().parse(&toks(&["a.json", "b.json"])).unwrap_err();
        assert!(e.0.contains("unexpected positional"));
    }

    #[test]
    fn help_lists_everything() {
        let h = spec().help();
        assert!(h.contains("--wafers"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("<config>"));
        assert!(h.contains("[default: 4]"));
    }

    #[test]
    fn try_parsers_report_errors() {
        let p = spec().parse(&toks(&["--wafers", "abc", "c.json"])).unwrap();
        assert!(p.try_u64("wafers").is_err());
    }
}
