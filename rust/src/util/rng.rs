//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component in the simulator (workload generators,
//! property-test case generation, topology shuffles) draws from [`Rng`], a
//! PCG32 generator seeded via SplitMix64. Determinism is a hard requirement:
//! the same experiment config must produce the same spike traffic on every
//! run, so that benchmark rows and regression tests are reproducible.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR variant): small, fast, statistically solid, reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Rng {
    /// Create a generator from a seed; stream id is derived from the seed so
    /// distinct seeds give independent sequences.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1; // must be odd
        let mut rng = Rng { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        let _ = rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-actor streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut s))
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed f64 with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        mean + std * r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small lambda, normal approximation with
    /// rounding for large lambda (error negligible for traffic generation).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal(lambda, lambda.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Zipf-distributed index in `[0, n)` with exponent `s` (s=0 → uniform).
    ///
    /// Uses inverse-CDF over precomputed weights when called through
    /// [`Zipf`]; this convenience constructor builds the table each call and
    /// is only for one-shot use.
    pub fn zipf_once(&mut self, n: usize, s: f64) -> usize {
        Zipf::new(n, s).sample(self)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Precomputed Zipf sampler over `[0, n)` with exponent `s`.
///
/// Models skewed spike-destination distributions: a few "hot" target FPGAs
/// receive most of the traffic, the regime in which bucket renaming
/// (Fig. 2c of the paper) is stressed.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the inverse-CDF table. O(n) setup, O(log n) per sample.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf over empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw one index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).expect("NaN in zipf cdf"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            // expected 10_000 each; allow 5% deviation
            assert!((9_500..10_500).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 8;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(11);
        for &lambda in &[0.5, 3.0, 20.0, 100.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(13);
        let lambda = 4.0;
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exponential(lambda)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_skew_orders_frequencies() {
        let mut r = Rng::new(17);
        let z = Zipf::new(16, 1.2);
        let mut counts = [0u32; 16];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[1] > counts[8]);
        // all indices reachable
        assert!(counts.iter().all(|&c| c > 0));
    }

    #[test]
    fn zipf_s0_is_uniformish() {
        let mut r = Rng::new(19);
        let z = Zipf::new(8, 0.0);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[z.sample(&mut r)] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(31);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(37);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }
}
