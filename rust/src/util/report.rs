//! Unified, metric-keyed experiment reports.
//!
//! Every scenario (see [`crate::coordinator::scenario`]) collects its
//! results into a [`Report`]: an insertion-ordered list of
//! `metric → value` entries with units. One container replaces the
//! per-driver report structs, so the CLI, the JSON emitter, the table
//! renderer and the sweep runner all handle every scenario generically.
//!
//! Values are typed ([`Value::Count`], [`Value::Real`], [`Value::Text`])
//! so counters emit as exact integers and rates as floats; JSON
//! round-trips through [`Report::to_json`] / [`Report::from_json`] up to
//! numeric normalization (JSON cannot distinguish `17.0` from `17`, so
//! integral non-negative numbers parse back as [`Value::Count`]).
//!
//! ## Declared metric schemas
//!
//! Scenarios declare what they will report as a static
//! `&'static [MetricDecl]` (name, unit, kind — see
//! `coordinator::scenario::Scenario::metrics`). A report built with
//! [`Report::with_schema`] **validates every push** against that
//! declaration: pushing an undeclared metric, the wrong [`MetricKind`],
//! or a mismatched unit panics — declaring the schema and then drifting
//! from it is a programming error, not a data condition. The sweep
//! runner uses the same declarations for stable CSV column ordering.

use crate::util::bench::{eng, Table};
use crate::util::json::Json;
use crate::util::stats::Histogram;

/// The value shape a declared metric must be pushed with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Exact counter ([`Value::Count`]).
    Count,
    /// Real-valued measurement ([`Value::Real`]).
    Real,
    /// Non-numeric metric ([`Value::Text`]).
    Text,
    /// Bucketed distribution with percentiles ([`Value::Hist`]).
    Histogram,
}

impl MetricKind {
    /// Lowercase label for listings (`run --list`).
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Count => "count",
            MetricKind::Real => "real",
            MetricKind::Text => "text",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One declared metric of a scenario's schema.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricDecl {
    /// Stable metric key (report entry / CSV column name).
    pub name: &'static str,
    /// Unit label; empty when unitless.
    pub unit: &'static str,
    pub kind: MetricKind,
}

impl MetricDecl {
    /// Declare an exact counter.
    pub const fn count(name: &'static str, unit: &'static str) -> MetricDecl {
        MetricDecl {
            name,
            unit,
            kind: MetricKind::Count,
        }
    }

    /// Declare a real-valued measurement.
    pub const fn real(name: &'static str, unit: &'static str) -> MetricDecl {
        MetricDecl {
            name,
            unit,
            kind: MetricKind::Real,
        }
    }

    /// Declare a non-numeric (text) metric.
    pub const fn text(name: &'static str) -> MetricDecl {
        MetricDecl {
            name,
            unit: "",
            kind: MetricKind::Text,
        }
    }

    /// Declare a bucketed-distribution metric. `unit` labels the
    /// histogram's recorded values (e.g. `"ps"`).
    pub const fn histogram(name: &'static str, unit: &'static str) -> MetricDecl {
        MetricDecl {
            name,
            unit,
            kind: MetricKind::Histogram,
        }
    }
}

/// Serialized view of a [`Histogram`]: the sparse non-empty buckets plus
/// the scalar statistics and percentiles consumers want, all computed at
/// construction so a JSON round-trip is byte-stable (nothing is
/// recomputed on parse).
///
/// Bucket indices refer to the histogram's fixed log-linear geometry;
/// `Histogram::bucket_low(i)` maps an index back to its lower edge, and
/// `bucket_of(bucket_low(i)) == i`, so the sparse pairs reconstruct the
/// bucket counts exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSummary {
    /// Non-empty buckets as `(bucket_index, count)`, ascending by index.
    pub buckets: Vec<(u32, u64)>,
    /// Total recorded values.
    pub n: u64,
    /// Smallest recorded value (0 when empty).
    pub min: u64,
    /// Largest recorded value (0 when empty).
    pub max: u64,
    /// Exact mean of recorded values (0.0 when empty — kept finite so
    /// report equality survives a JSON round-trip).
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
}

impl HistSummary {
    /// Summarize a histogram (percentiles are fixed here, at collection
    /// time).
    pub fn of(h: &Histogram) -> HistSummary {
        HistSummary {
            buckets: h.nonzero_buckets().map(|(i, c)| (i as u32, c)).collect(),
            n: h.count(),
            min: h.min(),
            max: h.max(),
            mean: if h.is_empty() { 0.0 } else { h.mean() },
            p50: h.p50(),
            p95: h.quantile(0.95),
            p99: h.p99(),
        }
    }

    fn to_json(&self) -> Json {
        let mut buckets = Json::arr();
        for &(i, c) in &self.buckets {
            let mut pair = Json::arr();
            pair.push(Json::from(i as u64));
            pair.push(Json::from(c));
            buckets.push(pair);
        }
        Json::obj()
            .set("buckets", buckets)
            .set("n", self.n)
            .set("min", self.min)
            .set("max", self.max)
            .set("mean", Json::Num(self.mean))
            .set("p50", self.p50)
            .set("p95", self.p95)
            .set("p99", self.p99)
    }

    fn from_json(j: &Json) -> Result<HistSummary, String> {
        fn int(j: &Json, what: &str) -> Result<u64, String> {
            match j {
                Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= u64::MAX as f64 => {
                    Ok(*x as u64)
                }
                other => Err(format!("histogram field '{what}' is not an integer: {other:?}")),
            }
        }
        fn field<'a>(j: &'a Json, what: &str) -> Result<&'a Json, String> {
            j.get(what)
                .ok_or_else(|| format!("histogram value missing '{what}'"))
        }
        let rows = field(j, "buckets")?
            .as_arr()
            .ok_or("histogram 'buckets' is not an array")?;
        let mut buckets = Vec::with_capacity(rows.len());
        for row in rows {
            let pair = row.as_arr().ok_or("histogram bucket is not a pair")?;
            if pair.len() != 2 {
                return Err("histogram bucket is not a pair".to_string());
            }
            buckets.push((int(&pair[0], "bucket index")? as u32, int(&pair[1], "bucket count")?));
        }
        let mean = match field(j, "mean")? {
            Json::Num(x) => *x,
            other => return Err(format!("histogram field 'mean' is not a number: {other:?}")),
        };
        Ok(HistSummary {
            buckets,
            n: int(field(j, "n")?, "n")?,
            min: int(field(j, "min")?, "min")?,
            max: int(field(j, "max")?, "max")?,
            mean,
            p50: int(field(j, "p50")?, "p50")?,
            p95: int(field(j, "p95")?, "p95")?,
            p99: int(field(j, "p99")?, "p99")?,
        })
    }

    /// Compact one-line rendering for tables and CSV cells (no commas,
    /// so CSV cells never need quoting).
    pub fn render(&self) -> String {
        format!(
            "n={} p50={} p95={} p99={} max={}",
            self.n, self.p50, self.p95, self.p99, self.max
        )
    }
}

/// One metric value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Exact event/packet/... counter.
    Count(u64),
    /// Real-valued measurement (rate, utilization, seconds, ...).
    Real(f64),
    /// Non-numeric metric (policy name, bottleneck description, ...).
    Text(String),
    /// Bucketed distribution with precomputed percentiles.
    Hist(HistSummary),
}

impl Value {
    /// Numeric view (counts widen to f64; text and histograms are `None`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Count(c) => Some(*c as f64),
            Value::Real(x) => Some(*x),
            Value::Text(_) | Value::Hist(_) => None,
        }
    }

    /// Render for tables and CSV cells.
    pub fn render(&self) -> String {
        match self {
            Value::Count(c) => c.to_string(),
            Value::Real(x) => eng(*x),
            Value::Text(s) => s.clone(),
            Value::Hist(h) => h.render(),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Value::Count(c) => Json::from(*c),
            Value::Real(x) => Json::Num(*x),
            Value::Text(s) => Json::from(s.as_str()),
            Value::Hist(h) => h.to_json(),
        }
    }

    fn from_json(j: &Json) -> Result<Value, String> {
        match j {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= u64::MAX as f64 => {
                Ok(Value::Count(*x as u64))
            }
            Json::Num(x) => Ok(Value::Real(*x)),
            Json::Str(s) => Ok(Value::Text(s.clone())),
            Json::Null => Ok(Value::Real(f64::NAN)),
            obj @ Json::Obj(_) if obj.get("buckets").is_some() => {
                Ok(Value::Hist(HistSummary::from_json(obj)?))
            }
            other => Err(format!("unsupported metric value {other:?}")),
        }
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::Count(v)
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::Count(v as u64)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Value {
        Value::Count(v as u64)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::Real(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Value {
        Value::Text(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Value {
        Value::Text(v)
    }
}

impl From<HistSummary> for Value {
    fn from(v: HistSummary) -> Value {
        Value::Hist(v)
    }
}

impl From<&Histogram> for Value {
    fn from(h: &Histogram) -> Value {
        Value::Hist(HistSummary::of(h))
    }
}

/// One `metric → value` entry.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub key: String,
    pub value: Value,
    /// Unit label (`"events"`, `"ns"`, `"1"`, ...); empty when unitless.
    pub unit: String,
}

/// An insertion-ordered, metric-keyed experiment report.
#[derive(Clone, Debug, Default)]
pub struct Report {
    scenario: String,
    entries: Vec<Entry>,
    /// Declared schema; every push is validated against it when present.
    schema: Option<&'static [MetricDecl]>,
}

/// Schema is a validation aid, not data: two reports are equal when their
/// scenario and entries agree, regardless of how they were validated
/// (e.g. a [`Report::from_json`] round-trip carries no schema).
impl PartialEq for Report {
    fn eq(&self, other: &Report) -> bool {
        self.scenario == other.scenario && self.entries == other.entries
    }
}

impl Report {
    pub fn new(scenario: &str) -> Report {
        Report {
            scenario: scenario.to_string(),
            entries: Vec::new(),
            schema: None,
        }
    }

    /// A report that validates every push against `schema` (see the
    /// module docs): undeclared keys, kind mismatches and unit mismatches
    /// panic at push time.
    pub fn with_schema(scenario: &str, schema: &'static [MetricDecl]) -> Report {
        Report {
            scenario: scenario.to_string(),
            entries: Vec::new(),
            schema: Some(schema),
        }
    }

    /// Name of the scenario that produced this report.
    pub fn scenario(&self) -> &str {
        &self.scenario
    }

    /// The schema this report validates against (None = unvalidated).
    pub fn schema(&self) -> Option<&'static [MetricDecl]> {
        self.schema
    }

    /// Insert (or replace) a unitless metric. Insertion order is kept;
    /// replacing keeps the original position.
    pub fn push(&mut self, key: &str, value: impl Into<Value>) {
        self.push_unit(key, value, "");
    }

    fn validate(&self, key: &str, value: &Value, unit: &str) {
        let Some(schema) = self.schema else {
            return;
        };
        let Some(decl) = schema.iter().find(|d| d.name == key) else {
            panic!(
                "scenario '{}' pushed undeclared metric '{key}' — declare it \
                 in the scenario's metrics() schema",
                self.scenario
            );
        };
        let kind_ok = matches!(
            (value, decl.kind),
            (Value::Count(_), MetricKind::Count)
                | (Value::Real(_), MetricKind::Real)
                | (Value::Text(_), MetricKind::Text)
                | (Value::Hist(_), MetricKind::Histogram)
        );
        assert!(
            kind_ok,
            "scenario '{}', metric '{key}': declared kind {:?}, pushed {value:?}",
            self.scenario, decl.kind
        );
        assert!(
            decl.unit == unit,
            "scenario '{}', metric '{key}': declared unit '{}', pushed '{unit}'",
            self.scenario, decl.unit
        );
    }

    /// Insert (or replace) a metric with a unit label.
    pub fn push_unit(&mut self, key: &str, value: impl Into<Value>, unit: &str) {
        let value = value.into();
        self.validate(key, &value, unit);
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == key) {
            e.value = value;
            e.unit = unit.to_string();
        } else {
            self.entries.push(Entry {
                key: key.to_string(),
                value,
                unit: unit.to_string(),
            });
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|e| e.key == key).map(|e| &e.value)
    }

    /// Numeric metric lookup (counts widen to f64).
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    /// Counter lookup.
    pub fn get_count(&self, key: &str) -> Option<u64> {
        match self.get(key) {
            Some(Value::Count(c)) => Some(*c),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Metric keys in insertion order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.key.as_str())
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    /// Serialize: `{"scenario": .., "metrics": [{key, value, unit}, ..]}`.
    /// The metrics array preserves insertion order (a flat object would
    /// not: [`Json`] objects sort their keys).
    pub fn to_json(&self) -> Json {
        let mut metrics = Json::arr();
        for e in &self.entries {
            let mut row = Json::obj()
                .set("key", e.key.as_str())
                .set("value", e.value.to_json());
            if !e.unit.is_empty() {
                row = row.set("unit", e.unit.as_str());
            }
            metrics.push(row);
        }
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("metrics", metrics)
    }

    /// Flat `metric → value` object (lossy: drops order and units).
    /// Convenient for sweep rows and ad-hoc scripting.
    pub fn to_flat_json(&self) -> Json {
        let mut obj = Json::obj();
        for e in &self.entries {
            obj.insert(&e.key, e.value.to_json());
        }
        obj
    }

    /// Inverse of [`Report::to_json`] up to numeric normalization:
    /// a [`Value::Real`] whose value is a non-negative integer parses
    /// back as [`Value::Count`] (JSON carries no int/float distinction).
    /// Use [`Report::get_f64`] rather than [`Report::get_count`] when a
    /// metric's integrality is value-dependent.
    pub fn from_json(j: &Json) -> Result<Report, String> {
        let scenario = j
            .get("scenario")
            .and_then(Json::as_str)
            .ok_or("missing 'scenario'")?;
        let rows = j
            .get("metrics")
            .and_then(Json::as_arr)
            .ok_or("missing 'metrics' array")?;
        let mut report = Report::new(scenario);
        for row in rows {
            let key = row
                .get("key")
                .and_then(Json::as_str)
                .ok_or("metric missing 'key'")?;
            let value = Value::from_json(row.get("value").ok_or("metric missing 'value'")?)?;
            report.push_unit(key, value, row.str_or("unit", ""));
        }
        Ok(report)
    }

    /// Render as a metric/value/unit table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("{} report", self.scenario),
            &["metric", "value", "unit"],
        );
        for e in &self.entries {
            t.row(vec![e.key.clone(), e.value.render(), e.unit.clone()]);
        }
        t
    }

    pub fn print(&self) {
        self.table().print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new("traffic");
        r.push_unit("events_generated", 12345u64, "events");
        r.push_unit("mean_batch", 17.25, "events/packet");
        r.push_unit("latency_p99", 1234.5, "ns");
        r.push("eviction", "most_urgent");
        r
    }

    #[test]
    fn insertion_order_preserved() {
        let r = sample();
        let keys: Vec<&str> = r.keys().collect();
        assert_eq!(
            keys,
            vec!["events_generated", "mean_batch", "latency_p99", "eviction"]
        );
    }

    #[test]
    fn replace_keeps_position() {
        let mut r = sample();
        r.push_unit("mean_batch", 99.5, "events/packet");
        let keys: Vec<&str> = r.keys().collect();
        assert_eq!(keys[1], "mean_batch");
        assert_eq!(r.get_f64("mean_batch"), Some(99.5));
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn typed_accessors() {
        let r = sample();
        assert_eq!(r.get_count("events_generated"), Some(12345));
        assert_eq!(r.get_f64("events_generated"), Some(12345.0));
        assert_eq!(r.get_count("mean_batch"), None);
        assert_eq!(r.get("eviction"), Some(&Value::Text("most_urgent".into())));
        assert!(r.get("nope").is_none());
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let j = r.to_json();
        let r2 = Report::from_json(&j).unwrap();
        assert_eq!(r, r2);
        // and through actual text
        let r3 = Report::from_json(&Json::parse(&j.pretty()).unwrap()).unwrap();
        assert_eq!(r, r3);
    }

    #[test]
    fn flat_json_has_plain_keys() {
        let r = sample();
        let f = r.to_flat_json();
        assert_eq!(f.u64_or("events_generated", 0), 12345);
        assert_eq!(f.f64_or("mean_batch", 0.0), 17.25);
        assert_eq!(f.str_or("eviction", ""), "most_urgent");
    }

    #[test]
    fn table_renders_all_rows() {
        let r = sample();
        let s = r.table().render();
        assert!(s.contains("traffic report"));
        assert!(s.contains("events_generated"));
        assert!(s.contains("12345"));
        assert!(s.contains("events/packet"));
    }

    const SCHEMA: &[MetricDecl] = &[
        MetricDecl::count("events", "events"),
        MetricDecl::real("rate", "events/s"),
        MetricDecl::text("policy"),
    ];

    #[test]
    fn schema_accepts_declared_pushes() {
        let mut r = Report::with_schema("unit", SCHEMA);
        r.push_unit("events", 7u64, "events");
        r.push_unit("rate", 2.5, "events/s");
        r.push("policy", "fullest");
        assert_eq!(r.len(), 3);
        assert_eq!(r.schema().unwrap().len(), 3);
        // a schema-validated report equals its schemaless twin
        let mut plain = Report::new("unit");
        plain.push_unit("events", 7u64, "events");
        plain.push_unit("rate", 2.5, "events/s");
        plain.push("policy", "fullest");
        assert_eq!(r, plain);
    }

    #[test]
    #[should_panic(expected = "undeclared metric")]
    fn schema_rejects_undeclared_metric() {
        let mut r = Report::with_schema("unit", SCHEMA);
        r.push_unit("surprise", 1u64, "events");
    }

    #[test]
    #[should_panic(expected = "declared kind")]
    fn schema_rejects_kind_mismatch() {
        let mut r = Report::with_schema("unit", SCHEMA);
        r.push_unit("events", 1.5, "events");
    }

    #[test]
    #[should_panic(expected = "declared unit")]
    fn schema_rejects_unit_mismatch() {
        let mut r = Report::with_schema("unit", SCHEMA);
        r.push_unit("events", 1u64, "packets");
    }

    #[test]
    fn histogram_value_roundtrips_byte_identically() {
        let mut h = Histogram::new();
        for v in [70_000u64, 70_000, 120_000, 5_000_000, 5_000_000, 9_999_999] {
            h.record(v);
        }
        let mut r = Report::new("latency_dist");
        r.push_unit("latency_hist", &h, "ps");
        let text = r.to_json().to_string();
        let r2 = Report::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(r, r2);
        assert_eq!(text, r2.to_json().to_string());
        match r2.get("latency_hist") {
            Some(Value::Hist(s)) => {
                assert_eq!(s.n, 6);
                assert_eq!(s.max, 9_999_999);
                assert_eq!(s.p50, h.p50());
                assert_eq!(s.p95, h.quantile(0.95));
                assert_eq!(s.p99, h.p99());
                assert!(!s.buckets.is_empty());
            }
            other => panic!("expected histogram value, got {other:?}"),
        }
    }

    #[test]
    fn empty_histogram_value_roundtrips() {
        let h = Histogram::new();
        let mut r = Report::new("latency_dist");
        r.push_unit("latency_hist", &h, "ps");
        let r2 = Report::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(r, r2, "empty histogram must survive (finite mean)");
    }

    #[test]
    fn histogram_render_is_csv_safe() {
        let mut h = Histogram::new();
        h.record_n(1_000, 100);
        let s = Value::from(&h).render();
        assert!(s.contains("p50=") && s.contains("p95=") && s.contains("p99="));
        assert!(!s.contains(','), "histogram cells must not need CSV quoting");
    }

    #[test]
    fn schema_accepts_histogram_kind() {
        const H_SCHEMA: &[MetricDecl] = &[MetricDecl::histogram("latency_hist", "ps")];
        assert_eq!(MetricKind::Histogram.as_str(), "histogram");
        let mut r = Report::with_schema("unit", H_SCHEMA);
        r.push_unit("latency_hist", &Histogram::new(), "ps");
        assert_eq!(r.len(), 1);
    }

    #[test]
    #[should_panic(expected = "declared kind")]
    fn schema_rejects_scalar_for_histogram() {
        const H_SCHEMA: &[MetricDecl] = &[MetricDecl::histogram("latency_hist", "ps")];
        let mut r = Report::with_schema("unit", H_SCHEMA);
        r.push_unit("latency_hist", 5u64, "ps");
    }

    #[test]
    fn nan_real_survives_as_null() {
        let mut r = Report::new("x");
        r.push("mean_batch", f64::NAN);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = Report::from_json(&j).unwrap();
        assert!(r2.get_f64("mean_batch").unwrap().is_nan());
    }
}
