//! Statistics primitives used by the metrics layer and the bench harness.
//!
//! [`OnlineStats`] — streaming mean/variance (Welford).
//! [`Histogram`] — HDR-style log-linear histogram with percentile queries,
//! used for latency distributions (ps resolution, bounded relative error).
//! [`Counter`]/[`RateMeter`] — event counting and rate computation.

/// Streaming mean / variance / min / max (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Log-linear histogram over `u64` values (e.g. picosecond latencies).
///
/// Values are bucketed by (exponent, linear-subbucket) with
/// `SUB_BITS`-bit sub-buckets per power of two, giving a bounded relative
/// error of `2^-SUB_BITS` ≈ 1.6% — plenty for latency percentiles while
/// keeping the table small and allocation-free after construction.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 6; // 64 sub-buckets per octave
const SUB: u64 = 1 << SUB_BITS;
const OCTAVES: u32 = 64 - SUB_BITS + 1; // octave index ranges 0..=58 for u64

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; (OCTAVES as usize) * SUB as usize],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let octave = msb - SUB_BITS + 1;
        let sub = (v >> (octave - 1)) - SUB; // top SUB_BITS+1 bits, minus implied one
        (octave as usize) * SUB as usize + sub as usize
    }

    /// Lower edge of bucket `i` (representative value reported back).
    ///
    /// Public so serialized histograms ([`crate::util::report::HistSummary`])
    /// can round-trip sparse `(bucket, count)` pairs exactly:
    /// `bucket_of(bucket_low(i)) == i` for every valid index.
    pub fn bucket_low(i: usize) -> u64 {
        let octave = (i / SUB as usize) as u32;
        let sub = (i % SUB as usize) as u64;
        if octave == 0 {
            sub
        } else {
            (SUB + sub) << (octave - 1)
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record `n` occurrences of `v`.
    pub fn record_n(&mut self, v: u64, n: u64) {
        self.counts[Self::bucket_of(v)] += n;
        self.total += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Exact sum of all recorded values (unlike the bucketed quantiles,
    /// this carries no approximation).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Value at quantile `q` in `[0,1]` (bucket lower edge; ≤1.6% rel. err).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return Self::bucket_low(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Iterate the non-empty buckets as `(bucket_index, count)` pairs, in
    /// ascending value order — the sparse form used when a histogram is
    /// serialized into a report.
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
    }

    /// Merge another histogram (same geometry by construction).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// One-line human summary (ns assumed if values are ps/1000 — caller
    /// decides units; this prints raw numbers).
    pub fn summary(&self) -> String {
        if self.total == 0 {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.1} p50={} p90={} p99={} p99.9={} max={}",
            self.total,
            self.mean(),
            self.p50(),
            self.p90(),
            self.p99(),
            self.p999(),
            self.max()
        )
    }
}

/// Simple monotonically increasing counter with a name, for metric tables.
#[derive(Clone, Debug, Default)]
pub struct Counter(pub u64);

impl Counter {
    #[inline]
    pub fn inc(&mut self) {
        self.0 += 1;
    }

    #[inline]
    pub fn add(&mut self, n: u64) {
        self.0 += n;
    }

    pub fn get(&self) -> u64 {
        self.0
    }
}

/// Rate = count / wall-or-sim time window. Used for events/s, Gbit/s rows.
#[derive(Clone, Debug, Default)]
pub struct RateMeter {
    pub count: u64,
    pub window_seconds: f64,
}

impl RateMeter {
    pub fn per_second(&self) -> f64 {
        if self.window_seconds <= 0.0 {
            f64::NAN
        } else {
            self.count as f64 / self.window_seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..40] {
            a.push(x);
        }
        for &x in &xs[40..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn histogram_exact_small_values() {
        let mut h = Histogram::new();
        for v in 0..SUB {
            h.record(v);
        }
        assert_eq!(h.count(), SUB);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUB - 1);
        // small values are exact buckets
        assert_eq!(h.quantile(0.0), 0);
    }

    #[test]
    fn histogram_quantiles_bounded_error() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for &(q, expect) in &[(0.5, 50_000u64), (0.9, 90_000), (0.99, 99_000)] {
            let got = h.quantile(q);
            let rel = (got as f64 - expect as f64).abs() / expect as f64;
            assert!(rel < 0.03, "q={q} got={got} expect={expect} rel={rel}");
        }
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = Histogram::new();
        h.record_n(10, 5);
        h.record_n(20, 5);
        assert!((h.mean() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 0..1000 {
            a.record(v);
            b.record(v + 1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        assert_eq!(a.min(), 0);
        assert_eq!(a.max(), 1999);
    }

    #[test]
    fn histogram_huge_values_dont_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
        let q = h.quantile(1.0);
        assert!(q >= u64::MAX / 4);
    }

    #[test]
    fn bucket_monotone() {
        // bucket index must be monotonically non-decreasing in value
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < u64::MAX / 2 {
            let b = Histogram::bucket_of(v);
            assert!(b >= prev, "v={v} bucket={b} prev={prev}");
            prev = b;
            v = v.saturating_mul(3) / 2 + 1;
        }
    }

    #[test]
    fn bucket_low_is_left_inverse_of_bucket_of() {
        // the sparse (bucket, count) serialization in util::report relies
        // on reconstructing counts via record_n(bucket_low(i), c)
        let mut v = 0u64;
        while v < u64::MAX / 2 {
            let b = Histogram::bucket_of(v);
            assert_eq!(Histogram::bucket_of(Histogram::bucket_low(b)), b, "v={v}");
            v = v.saturating_mul(3) / 2 + 1;
        }
    }

    #[test]
    fn nonzero_buckets_reconstruct_counts() {
        let mut h = Histogram::new();
        for v in [3u64, 3, 70_000, 123_456_789, 123_456_789, 123_456_789] {
            h.record(v);
        }
        let mut rebuilt = Histogram::new();
        for (i, c) in h.nonzero_buckets() {
            rebuilt.record_n(Histogram::bucket_low(i), c);
        }
        assert_eq!(rebuilt.count(), h.count());
        assert_eq!(
            rebuilt.nonzero_buckets().collect::<Vec<_>>(),
            h.nonzero_buckets().collect::<Vec<_>>()
        );
    }

    #[test]
    fn rate_meter() {
        let r = RateMeter {
            count: 500,
            window_seconds: 0.25,
        };
        assert!((r.per_second() - 2000.0).abs() < 1e-9);
    }
}
