//! Zero-dependency substrates: CLI argument parsing, JSON, deterministic
//! RNG, statistics, metric reports, and a micro-benchmark harness.
//!
//! This build is fully offline (only a minimal `anyhow` is vendored), so
//! the conveniences usually imported from crates.io — `clap`, `serde_json`,
//! `rand`, `criterion` — are implemented here as small, well-tested modules.

pub mod args;
pub mod bench;
pub mod json;
pub mod report;
pub mod rng;
pub mod stats;
