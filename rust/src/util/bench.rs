//! Micro-benchmark harness (offline replacement for criterion).
//!
//! Each `[[bench]]` target (with `harness = false`) builds a [`BenchSuite`],
//! registers closures, and calls [`BenchSuite::run`]. The harness does
//! warmup, timed batches, outlier-robust summary (median of batch means),
//! and prints aligned rows plus JSON records for the perf trajectory (PERF.md).
//!
//! Throughput-style benches (events/s over simulated time) don't fit the
//! ns/op mold; those use [`Row`]/[`Table`] to print paper-style result
//! tables directly.
//!
//! Results serialize to JSON ([`BenchResult::to_json`] /
//! [`BenchSuite::to_json`]) so bench binaries can emit machine-readable
//! trajectory artifacts like `BENCH_PR2.json` (see PERF.md and
//! `benches/bench_events.rs`); the CI `bench-smoke` job regenerates them
//! in fast mode and fails on any `SKIPPED` row.

use std::time::Instant;

use super::json::Json;
use super::stats::OnlineStats;

/// True when `BSS_BENCH_FAST` is set: ~10× smaller timing budgets, for
/// CI smoke runs and quick local iteration.
pub fn fast_mode() -> bool {
    std::env::var("BSS_BENCH_FAST").is_ok()
}

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// median ns per iteration
    pub ns_per_iter: f64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub iters: u64,
    /// optional caller-provided "items per iteration" for throughput
    pub items_per_iter: f64,
}

impl BenchResult {
    /// items/second implied by median time (NaN if items_per_iter unset).
    pub fn items_per_sec(&self) -> f64 {
        self.items_per_iter / (self.ns_per_iter * 1e-9)
    }

    /// Machine-readable record for trajectory artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("ns_per_iter", self.ns_per_iter)
            .set("mean_ns", self.mean_ns)
            .set("std_ns", self.std_ns)
            .set("iters", self.iters)
            .set("items_per_iter", self.items_per_iter)
            .set("items_per_sec", self.items_per_sec())
    }
}

/// Micro-benchmark suite: warmup + batched timing.
pub struct BenchSuite {
    pub title: String,
    pub results: Vec<BenchResult>,
    /// Benches that could not run: (name, reason). CI fails on these.
    pub skipped: Vec<(String, String)>,
    min_batches: u32,
    target_batch_ns: f64,
    warmup_ns: f64,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // Allow quick runs: BSS_BENCH_FAST=1 shrinks timing budget ~10x.
        let fast = fast_mode();
        BenchSuite {
            title: title.to_string(),
            results: Vec::new(),
            skipped: Vec::new(),
            min_batches: if fast { 5 } else { 15 },
            target_batch_ns: if fast { 2e6 } else { 2e7 },
            warmup_ns: if fast { 5e6 } else { 5e7 },
        }
    }

    /// Record (and print) a benchmark that could not run. The CI
    /// `bench-smoke` job greps the output for `SKIPPED` and fails, so a
    /// committed trajectory artifact can never silently go stale.
    pub fn skip(&mut self, name: &str, reason: &str) {
        println!("  {name:<48} SKIPPED: {reason}");
        self.skipped.push((name.to_string(), reason.to_string()));
    }

    /// Machine-readable record of the whole suite.
    pub fn to_json(&self) -> Json {
        let mut results = Json::arr();
        for r in &self.results {
            results.push(r.to_json());
        }
        let mut skipped = Json::arr();
        for (name, reason) in &self.skipped {
            skipped.push(
                Json::obj()
                    .set("name", name.as_str())
                    .set("reason", reason.as_str()),
            );
        }
        Json::obj()
            .set("suite", self.title.as_str())
            .set("results", results)
            .set("skipped", skipped)
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_items(name, 1.0, move || {
            f();
        })
    }

    /// Time `f` and attach an items-per-iteration count for throughput rows.
    pub fn bench_items(
        &mut self,
        name: &str,
        items_per_iter: f64,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // Warmup and per-call cost estimate.
        let mut calls_done = 0u64;
        let warm_start = Instant::now();
        loop {
            f();
            calls_done += 1;
            if warm_start.elapsed().as_nanos() as f64 >= self.warmup_ns {
                break;
            }
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / calls_done as f64).max(0.5);
        let batch_iters = (self.target_batch_ns / est_ns).ceil().max(1.0) as u64;

        // Timed batches; summary = median of batch means (outlier-robust).
        let mut batch_means: Vec<f64> = Vec::with_capacity(self.min_batches as usize);
        let mut stats = OnlineStats::new();
        for _ in 0..self.min_batches {
            let t0 = Instant::now();
            for _ in 0..batch_iters {
                f();
            }
            let per_iter = t0.elapsed().as_nanos() as f64 / batch_iters as f64;
            batch_means.push(per_iter);
            stats.push(per_iter);
        }
        batch_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = batch_means[batch_means.len() / 2];

        self.results.push(BenchResult {
            name: name.to_string(),
            ns_per_iter: median,
            mean_ns: stats.mean(),
            std_ns: stats.std(),
            iters: batch_iters * self.min_batches as u64,
            items_per_iter,
        });
        let r = self.results.last().unwrap();
        let thr = if items_per_iter > 1.0 {
            format!("  ({:.3e} items/s)", r.items_per_sec())
        } else {
            String::new()
        };
        println!(
            "  {:<48} {:>12.1} ns/iter  ±{:>8.1}{}",
            r.name, r.ns_per_iter, r.std_ns, thr
        );
        r
    }

    /// Print the header; call before benches for nice grouping.
    pub fn header(&self) {
        println!("\n== {} ==", self.title);
    }

    /// Final one-line summary per result (already printed incrementally).
    pub fn finish(&self) {
        println!(
            "== {}: {} benchmarks done ==\n",
            self.title,
            self.results.len()
        );
    }
}

/// A paper-style results table (fixed columns, aligned, markdown-friendly).
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a markdown table (also pleasant in a terminal).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.columns, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        s.push_str(&sep);
        for row in &self.rows {
            s.push_str(&fmt_row(row, &widths));
        }
        s
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a f64 with engineering-style precision for table cells.
pub fn eng(x: f64) -> String {
    if !x.is_finite() {
        return "-".to_string();
    }
    let a = x.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 1e9 {
        format!("{:.3e}", x)
    } else if a >= 100.0 {
        format!("{:.0}", x)
    } else if a >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.4}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("BSS_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("selftest");
        let mut acc = 0u64;
        let r = suite
            .bench("noop-ish", || {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            })
            .clone();
        assert!(r.ns_per_iter > 0.0);
        assert!(r.ns_per_iter < 1e6, "a multiply took {} ns?!", r.ns_per_iter);
        assert!(acc != 0);
    }

    #[test]
    fn suite_json_records_results_and_skips() {
        std::env::set_var("BSS_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("jsontest");
        suite.bench("spin", || {
            std::hint::black_box(1 + 1);
        });
        suite.skip("needs-artifacts", "artifacts not built");
        let j = suite.to_json();
        assert_eq!(j.str_or("suite", ""), "jsontest");
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].str_or("name", ""), "spin");
        assert!(results[0].f64_or("ns_per_iter", 0.0) > 0.0);
        let skipped = j.get("skipped").unwrap().as_arr().unwrap();
        assert_eq!(skipped.len(), 1);
        assert_eq!(skipped[0].str_or("name", ""), "needs-artifacts");
        // the JSON must parse back (valid document)
        assert!(Json::parse(&j.pretty()).is_ok());
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            ns_per_iter: 100.0,
            mean_ns: 100.0,
            std_ns: 0.0,
            iters: 1,
            items_per_iter: 10.0,
        };
        assert!((r.items_per_sec() - 1e8).abs() < 1.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "column_b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("| a   | column_b |"));
        assert!(s.contains("| 333 | 4        |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn eng_formatting() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(1234.0), "1234");
        assert_eq!(eng(12.345), "12.35");
        assert_eq!(eng(0.01234), "0.0123");
        assert_eq!(eng(f64::NAN), "-");
        assert!(eng(3.2e12).contains('e'));
    }
}
