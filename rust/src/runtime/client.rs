//! PJRT runtime: load and execute AOT-compiled HLO artifacts.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. The rust
//! request path never touches Python — artifacts are produced once by
//! `make artifacts` (see `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Artifact manifest (`<name>.json` next to `<name>.hlo.txt`).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub n_local: usize,
    pub n_global: usize,
    pub dtype: String,
    pub hlo_sha256: String,
    /// LIF parameters baked into the artifact.
    pub decay: f64,
    pub v_th: f64,
    pub v_reset: f64,
    pub refrac_steps: f64,
    pub i_ext: f64,
}

impl Manifest {
    /// Parse a manifest JSON file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let params = j.get("params").context("manifest missing 'params'")?;
        Ok(Manifest {
            name: j.str_or("name", "?").to_string(),
            n_local: j.usize_or("n_local", 0),
            n_global: j.usize_or("n_global", 0),
            dtype: j.str_or("dtype", "f32").to_string(),
            hlo_sha256: j.str_or("hlo_sha256", "").to_string(),
            decay: params.f64_or("decay", 0.99),
            v_th: params.f64_or("v_th", 1.0),
            v_reset: params.f64_or("v_reset", 0.0),
            refrac_steps: params.f64_or("refrac_steps", 20.0),
            i_ext: params.f64_or("i_ext", 0.0),
        })
    }
}

/// The PJRT client (one per process; compiled executables borrow it).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name from a directory (expects
    /// `<dir>/<name>.hlo.txt` and `<dir>/<name>.json`).
    pub fn load_shard_model(&self, dir: &Path, name: &str) -> Result<ShardModel> {
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let man_path = dir.join(format!("{name}.json"));
        if !hlo_path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo_path.display()
            );
        }
        let manifest = Manifest::load(&man_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .context("artifact path is not valid UTF-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling artifact {name}: {e:?}"))?;
        Ok(ShardModel {
            exe,
            client: self.client.clone(),
            manifest,
            path: hlo_path,
        })
    }
}

/// A compiled wafer-shard step function.
///
/// Signature (see `python/compile/model.py`):
/// `state f32[3, n_local] × spikes_in f32[n_global] × w f32[n_local, n_global]
///  → state' f32[3, n_local]` — row 2 of the output holds this step's spikes.
pub struct ShardModel {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub manifest: Manifest,
    pub path: PathBuf,
}

impl ShardModel {
    pub fn n_local(&self) -> usize {
        self.manifest.n_local
    }

    pub fn n_global(&self) -> usize {
        self.manifest.n_global
    }

    /// Execute one timestep. `state` is `3 * n_local` floats (packed rows),
    /// `spikes_in` is `n_global`, `w` is `n_local * n_global` (row-major).
    ///
    /// Returns the packed new state (`3 * n_local` floats).
    pub fn step(&self, state: &[f32], spikes_in: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        anyhow::ensure!(state.len() == 3 * n_local, "state length");
        anyhow::ensure!(spikes_in.len() == n_global, "spikes length");
        anyhow::ensure!(w.len() == n_local * n_global, "weights length");
        let state_l = xla::Literal::vec1(state).reshape(&[3, n_local as i64])?;
        let spikes_l = xla::Literal::vec1(spikes_in);
        let w_l = xla::Literal::vec1(w).reshape(&[n_local as i64, n_global as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[state_l, spikes_l, w_l])?;
        let out = result[0][0].to_literal_sync()?;
        let out = normalize_result(out)?;
        Ok(out)
    }

    /// Extract the spike row from a packed state.
    pub fn spikes_of(state: &[f32], n_local: usize) -> &[f32] {
        &state[2 * n_local..3 * n_local]
    }

    /// Upload the (step-invariant) weight matrix to the device once.
    ///
    /// Perf: `step` re-marshals all three inputs as Literals on every call;
    /// the weight matrix is by far the largest (n_local×n_global f32) and
    /// never changes, so keeping it device-side and using [`Self::step_with`]
    /// removes ~99% of the per-step host→device traffic.
    pub fn upload_weights(&self, w: &[f32]) -> Result<xla::PjRtBuffer> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        anyhow::ensure!(w.len() == n_local * n_global, "weights length");
        Ok(self
            .client
            .buffer_from_host_buffer(w, &[n_local, n_global], None)?)
    }

    /// Execute one timestep against a pre-uploaded weight buffer.
    pub fn step_with(
        &self,
        state: &[f32],
        spikes_in: &[f32],
        w_buf: &xla::PjRtBuffer,
    ) -> Result<Vec<f32>> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        anyhow::ensure!(state.len() == 3 * n_local, "state length");
        anyhow::ensure!(spikes_in.len() == n_global, "spikes length");
        let state_b = self
            .client
            .buffer_from_host_buffer(state, &[3, n_local], None)?;
        let spikes_b = self
            .client
            .buffer_from_host_buffer(spikes_in, &[n_global], None)?;
        let result = self.exe.execute_b(&[&state_b, &spikes_b, w_buf])?;
        let out = result[0][0].to_literal_sync()?;
        normalize_result(out)
    }
}

/// The AOT path lowers with `return_tuple=False`, so the root is the bare
/// array; tolerate a 1-tuple anyway (older lowering paths wrap it).
fn normalize_result(lit: xla::Literal) -> Result<Vec<f32>> {
    match lit.to_vec::<f32>() {
        Ok(v) => Ok(v),
        Err(_) => {
            let inner = lit
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("unwrapping result tuple: {e:?}"))?;
            Ok(inner.to_vec::<f32>()?)
        }
    }
}

/// Locate the artifacts directory: `$BSS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("BSS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when the artifact suite has been built.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("shard_256x1024.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        // tests run from the crate root
        artifacts_dir()
    }

    fn skip() -> bool {
        if !artifacts_available() {
            eprintln!("skipping runtime test: artifacts not built (make artifacts)");
            return true;
        }
        false
    }

    #[test]
    fn manifest_parses() {
        if skip() {
            return;
        }
        let m = Manifest::load(&dir().join("shard_256x1024.json")).unwrap();
        assert_eq!(m.n_local, 256);
        assert_eq!(m.n_global, 1024);
        assert_eq!(m.dtype, "f32");
        assert!(m.decay > 0.9 && m.decay < 1.0);
        assert!(!m.hlo_sha256.is_empty());
    }

    #[test]
    fn load_and_step_shard() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        // all neurons start at rest with zero input: one step charges the
        // membrane by i_ext*(1-decay) — far below threshold, no spikes
        let state = vec![0.0f32; 3 * n_local];
        let spikes = vec![0.0f32; n_global];
        let w = vec![0.0f32; n_local * n_global];
        let out = model.step(&state, &spikes, &w).unwrap();
        assert_eq!(out.len(), 3 * n_local);
        let m = &model.manifest;
        let expect_v = (m.i_ext * (1.0 - m.decay)) as f32;
        for i in 0..n_local {
            assert!((out[i] - expect_v).abs() < 1e-5, "v[{i}] = {}", out[i]);
            assert_eq!(out[2 * n_local + i], 0.0, "unexpected spike at {i}");
        }
    }

    #[test]
    fn spikes_propagate_through_weights() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        // one incoming spike at global index 7 with a huge weight to
        // local neuron 3: neuron 3 must fire this step
        let state = vec![0.0f32; 3 * n_local];
        let mut spikes = vec![0.0f32; n_global];
        spikes[7] = 1.0;
        let mut w = vec![0.0f32; n_local * n_global];
        w[3 * n_global + 7] = 500.0;
        let out = model.step(&state, &spikes, &w).unwrap();
        let s = ShardModel::spikes_of(&out, n_local);
        assert_eq!(s[3], 1.0, "neuron 3 should spike");
        assert_eq!(s.iter().filter(|&&x| x > 0.0).count(), 1);
        // and be reset + refractory
        assert_eq!(out[3], model.manifest.v_reset as f32);
        assert_eq!(out[n_local + 3], model.manifest.refrac_steps as f32);
    }

    #[test]
    fn repeated_steps_are_deterministic() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        let state = vec![0.1f32; 3 * n_local];
        let spikes = vec![0.0f32; n_global];
        let w = vec![0.01f32; n_local * n_global];
        let a = model.step(&state, &spikes, &w).unwrap();
        let b = model.step(&state, &spikes, &w).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn missing_artifact_is_friendly_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_shard_model(&dir(), "no_such_artifact") {
            Ok(_) => panic!("expected an error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "got: {err}");
    }
}
