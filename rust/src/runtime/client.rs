//! Execution runtime for the AOT-compiled shard step artifacts.
//!
//! Artifacts are produced once by `make artifacts` (see
//! `python/compile/aot.py`): each is a `<name>.hlo.txt` lowered HLO module
//! plus a `<name>.json` manifest recording shapes and the LIF parameters
//! baked into the module.
//!
//! This offline build executes the artifacts with a **native reference
//! interpreter**: the shard step semantics are fixed by the manifest (see
//! `python/compile/kernels/ref.py` — `shard_step_ref`), so the interpreter
//! reproduces the compiled module exactly:
//!
//! ```text
//! i_total = w @ spikes_in + i_ext
//! active  = refrac <= 0
//! v'      = active ? v * decay + i_total * (1 - decay) : v
//! spike   = active && v' >= v_th
//! v_out   = spike ? v_reset : v'
//! r_out   = spike ? refrac_steps : max(refrac - 1, 0)
//! ```
//!
//! The PJRT C-API backend (`xla` crate: `PjRtClient::cpu()` → compile →
//! execute) used the same public surface — `Runtime`, `ShardModel`,
//! [`ShardModel::step`] / [`ShardModel::step_with`] — so it can be
//! re-vendored later without touching any caller.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Artifact manifest (`<name>.json` next to `<name>.hlo.txt`).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub n_local: usize,
    pub n_global: usize,
    pub dtype: String,
    pub hlo_sha256: String,
    /// LIF parameters baked into the artifact.
    pub decay: f64,
    pub v_th: f64,
    pub v_reset: f64,
    pub refrac_steps: f64,
    pub i_ext: f64,
}

impl Manifest {
    /// Parse a manifest JSON file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let params = j.get("params").context("manifest missing 'params'")?;
        Ok(Manifest {
            name: j.str_or("name", "?").to_string(),
            n_local: j.usize_or("n_local", 0),
            n_global: j.usize_or("n_global", 0),
            dtype: j.str_or("dtype", "f32").to_string(),
            hlo_sha256: j.str_or("hlo_sha256", "").to_string(),
            decay: params.f64_or("decay", 0.99),
            v_th: params.f64_or("v_th", 1.0),
            v_reset: params.f64_or("v_reset", 0.0),
            refrac_steps: params.f64_or("refrac_steps", 20.0),
            i_ext: params.f64_or("i_ext", 0.0),
        })
    }
}

/// The execution runtime (one per process).
pub struct Runtime {
    platform: String,
}

impl Runtime {
    /// Create a CPU runtime.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            platform: "cpu (native LIF interpreter)".to_string(),
        })
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Load one artifact by name from a directory (expects
    /// `<dir>/<name>.hlo.txt` and `<dir>/<name>.json`).
    pub fn load_shard_model(&self, dir: &Path, name: &str) -> Result<ShardModel> {
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let man_path = dir.join(format!("{name}.json"));
        if !hlo_path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo_path.display()
            );
        }
        let manifest = Manifest::load(&man_path)?;
        anyhow::ensure!(
            manifest.n_local > 0 && manifest.n_global > 0,
            "artifact {name}: degenerate shapes in manifest"
        );
        anyhow::ensure!(
            manifest.dtype == "f32",
            "artifact {name}: unsupported dtype {}",
            manifest.dtype
        );
        Ok(ShardModel {
            manifest,
            path: hlo_path,
        })
    }
}

/// Step-invariant weights retained by the runtime (the analogue of a
/// device-resident `PjRtBuffer` on the PJRT backend).
pub struct WeightBuffer {
    w: Vec<f32>,
}

impl WeightBuffer {
    /// Row-major `[n_local, n_global]` host view.
    pub fn as_slice(&self) -> &[f32] {
        &self.w
    }
}

/// A loaded wafer-shard step function.
///
/// Signature (see `python/compile/model.py`):
/// `state f32[3, n_local] × spikes_in f32[n_global] × w f32[n_local, n_global]
///  → state' f32[3, n_local]` — row 2 of the output holds this step's spikes.
///
/// `Clone` is cheap (manifest + path, no tensors): the two-phase
/// `Scenario` lifecycle loads an artifact once in `prepare` and clones
/// the handle per [`crate::neuro::shard::ShardSim`] in `execute`.
#[derive(Clone)]
pub struct ShardModel {
    pub manifest: Manifest,
    pub path: PathBuf,
}

impl ShardModel {
    pub fn n_local(&self) -> usize {
        self.manifest.n_local
    }

    pub fn n_global(&self) -> usize {
        self.manifest.n_global
    }

    /// Execute one timestep. `state` is `3 * n_local` floats (packed rows),
    /// `spikes_in` is `n_global`, `w` is `n_local * n_global` (row-major).
    ///
    /// Returns the packed new state (`3 * n_local` floats).
    pub fn step(&self, state: &[f32], spikes_in: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        anyhow::ensure!(state.len() == 3 * n_local, "state length");
        anyhow::ensure!(spikes_in.len() == n_global, "spikes length");
        anyhow::ensure!(w.len() == n_local * n_global, "weights length");
        Ok(self.execute(state, spikes_in, w))
    }

    /// Extract the spike row from a packed state.
    pub fn spikes_of(state: &[f32], n_local: usize) -> &[f32] {
        &state[2 * n_local..3 * n_local]
    }

    /// Retain the (step-invariant) weight matrix in the runtime once.
    ///
    /// Perf: the weight matrix is by far the largest input
    /// (n_local×n_global f32) and never changes between steps, so callers
    /// hand it over once and use [`Self::step_with`] afterwards — on the
    /// PJRT backend this kept the buffer device-side and removed ~99% of
    /// the per-step host→device traffic.
    pub fn upload_weights(&self, w: &[f32]) -> Result<WeightBuffer> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        anyhow::ensure!(w.len() == n_local * n_global, "weights length");
        Ok(WeightBuffer { w: w.to_vec() })
    }

    /// Execute one timestep against pre-uploaded weights.
    pub fn step_with(
        &self,
        state: &[f32],
        spikes_in: &[f32],
        w_buf: &WeightBuffer,
    ) -> Result<Vec<f32>> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        anyhow::ensure!(state.len() == 3 * n_local, "state length");
        anyhow::ensure!(spikes_in.len() == n_global, "spikes length");
        anyhow::ensure!(w_buf.w.len() == n_local * n_global, "weights length");
        Ok(self.execute(state, spikes_in, &w_buf.w))
    }

    /// The reference LIF shard step (semantics of `shard_step_ref`).
    fn execute(&self, state: &[f32], spikes_in: &[f32], w: &[f32]) -> Vec<f32> {
        let n_local = self.manifest.n_local;
        let n_global = self.manifest.n_global;
        let decay = self.manifest.decay as f32;
        let v_th = self.manifest.v_th as f32;
        let v_reset = self.manifest.v_reset as f32;
        let refrac_steps = self.manifest.refrac_steps as f32;
        let i_ext = self.manifest.i_ext as f32;

        // Spike vectors are sparse: gather active indices once so the
        // synaptic accumulation is O(n_local × n_active).
        let active_in: Vec<usize> = spikes_in
            .iter()
            .enumerate()
            .filter(|(_, &s)| s != 0.0)
            .map(|(j, _)| j)
            .collect();

        let mut out = vec![0.0f32; 3 * n_local];
        for i in 0..n_local {
            let row = &w[i * n_global..(i + 1) * n_global];
            let mut i_syn = 0.0f32;
            for &j in &active_in {
                i_syn += row[j] * spikes_in[j];
            }
            let i_total = i_syn + i_ext;
            let v = state[i];
            let r = state[n_local + i];
            let active = r <= 0.0;
            let v_new = if active {
                v * decay + i_total * (1.0 - decay)
            } else {
                v
            };
            let spike = active && v_new >= v_th;
            out[i] = if spike { v_reset } else { v_new };
            out[n_local + i] = if spike {
                refrac_steps
            } else {
                (r - 1.0).max(0.0)
            };
            out[2 * n_local + i] = if spike { 1.0 } else { 0.0 };
        }
        out
    }
}

/// Locate the artifacts directory: `$BSS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("BSS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when the artifact suite has been built.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("shard_256x1024.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        // tests run from the crate root
        artifacts_dir()
    }

    fn skip() -> bool {
        if !artifacts_available() {
            eprintln!("skipping runtime test: artifacts not built (make artifacts)");
            return true;
        }
        false
    }

    #[test]
    fn manifest_parses() {
        if skip() {
            return;
        }
        let m = Manifest::load(&dir().join("shard_256x1024.json")).unwrap();
        assert_eq!(m.n_local, 256);
        assert_eq!(m.n_global, 1024);
        assert_eq!(m.dtype, "f32");
        assert!(m.decay > 0.9 && m.decay < 1.0);
        assert!(!m.hlo_sha256.is_empty());
    }

    #[test]
    fn load_and_step_shard() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        // all neurons start at rest with zero input: one step charges the
        // membrane by i_ext*(1-decay) — far below threshold, no spikes
        let state = vec![0.0f32; 3 * n_local];
        let spikes = vec![0.0f32; n_global];
        let w = vec![0.0f32; n_local * n_global];
        let out = model.step(&state, &spikes, &w).unwrap();
        assert_eq!(out.len(), 3 * n_local);
        let m = &model.manifest;
        let expect_v = (m.i_ext * (1.0 - m.decay)) as f32;
        for i in 0..n_local {
            assert!((out[i] - expect_v).abs() < 1e-5, "v[{i}] = {}", out[i]);
            assert_eq!(out[2 * n_local + i], 0.0, "unexpected spike at {i}");
        }
    }

    #[test]
    fn spikes_propagate_through_weights() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        // one incoming spike at global index 7 with a huge weight to
        // local neuron 3: neuron 3 must fire this step
        let state = vec![0.0f32; 3 * n_local];
        let mut spikes = vec![0.0f32; n_global];
        spikes[7] = 1.0;
        let mut w = vec![0.0f32; n_local * n_global];
        w[3 * n_global + 7] = 500.0;
        let out = model.step(&state, &spikes, &w).unwrap();
        let s = ShardModel::spikes_of(&out, n_local);
        assert_eq!(s[3], 1.0, "neuron 3 should spike");
        assert_eq!(s.iter().filter(|&&x| x > 0.0).count(), 1);
        // and be reset + refractory
        assert_eq!(out[3], model.manifest.v_reset as f32);
        assert_eq!(out[n_local + 3], model.manifest.refrac_steps as f32);
    }

    #[test]
    fn repeated_steps_are_deterministic() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        let state = vec![0.1f32; 3 * n_local];
        let spikes = vec![0.0f32; n_global];
        let w = vec![0.01f32; n_local * n_global];
        let a = model.step(&state, &spikes, &w).unwrap();
        let b = model.step(&state, &spikes, &w).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn step_with_matches_step() {
        if skip() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_shard_model(&dir(), "shard_256x1024").unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        let state = vec![0.5f32; 3 * n_local];
        let mut spikes = vec![0.0f32; n_global];
        spikes[1] = 1.0;
        spikes[900] = 2.0;
        let w = vec![0.03f32; n_local * n_global];
        let w_buf = model.upload_weights(&w).unwrap();
        let a = model.step(&state, &spikes, &w).unwrap();
        let b = model.step_with(&state, &spikes, &w_buf).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn missing_artifact_is_friendly_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_shard_model(&dir(), "no_such_artifact") {
            Ok(_) => panic!("expected an error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "got: {err}");
    }
}
