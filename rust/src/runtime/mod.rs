//! PJRT execution substrate: loads the AOT artifacts produced by
//! `python/compile/aot.py` and runs them from the rust request path.

pub mod client;

pub use client::{artifacts_available, artifacts_dir, Manifest, Runtime, ShardModel};
