//! Execution substrate: loads the AOT artifacts produced by
//! `python/compile/aot.py` and runs them from the rust request path —
//! Python never on the request path. This offline build interprets the
//! artifacts natively (see [`client`] for the backend contract).

pub mod client;

pub use client::{
    artifacts_available, artifacts_dir, Manifest, Runtime, ShardModel, WeightBuffer,
};
