//! TX and RX lookup tables (paper §3).
//!
//! TX side: a spike from a HICANN "does not inherently define a destination
//! in the overall network, a lookup table is indexed to retrieve the
//! respective network destination-address and a generic Global Unique
//! Identifier (GUID) that will be transmitted over the network together
//! with the event itself."
//!
//! RX side: "At the destination, another lookup table is indexed with the
//! received GUID, yielding a multicast mask to distribute the event among
//! the HICANN chips connected to that FPGA."

use crate::extoll::torus::NodeAddr;

use super::event::SpikeEvent;

/// A network destination endpoint: one of the FPGAs behind a torus node's
/// concentrator (6 in the paper's Fig. 1 topology; the topology-sweep
/// benchmark also explores other fan-ins). This is the granularity at
/// which aggregation buckets are keyed ("accumulating events for the same
/// destination", §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EndpointAddr {
    /// Extoll torus node (the concentrator's Tourmalet).
    pub node: NodeAddr,
    /// FPGA index behind that concentrator (0..64).
    pub fpga: u8,
}

impl EndpointAddr {
    pub fn new(node: NodeAddr, fpga: u8) -> Self {
        debug_assert!(fpga < 64);
        EndpointAddr { node, fpga }
    }

    /// Pack into the 16-bit network destination id the paper's map table
    /// is sized for (2^16 possible destinations): 10 bits node, 6 bits FPGA
    /// (covers a 1024-node torus with up to 64 FPGAs per concentrator).
    pub fn as_u16(&self) -> u16 {
        assert!(self.node.0 < (1 << 10), "node address exceeds 10 bits");
        (self.node.0 << 6) | self.fpga as u16
    }

    pub fn from_u16(v: u16) -> Self {
        EndpointAddr {
            node: NodeAddr(v >> 6),
            fpga: (v & 0x3F) as u8,
        }
    }
}

/// One TX lookup-table entry: where a source pulse address routes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TxEntry {
    pub dest: EndpointAddr,
    /// 15-bit GUID transmitted with the event.
    pub guid: u16,
}

/// The TX lookup table: `(hicann, pulse_addr) → [TxEntry]`.
///
/// Indexed by the 3-bit HICANN id and the 12-bit pulse address, i.e. a
/// 32768-entry SRAM in the real FPGA. Entries may be absent (unrouted
/// neurons: events are counted and dropped, mirroring hardware behaviour).
///
/// A source may fan out to **multiple destination FPGAs** — the 2-page
/// abstract specifies a single (destination, GUID) pair per lookup, but a
/// neuron projecting to several wafers necessarily ships one event per
/// destination FPGA (network-level multicast exists only at the RX side,
/// across the 8 HICANNs of one FPGA). The fan-out list models the repeated
/// lookup the hardware would perform; see DESIGN.md.
#[derive(Clone, Debug)]
pub struct TxLookup {
    entries: Vec<Vec<TxEntry>>,
    programmed: usize,
}

impl Default for TxLookup {
    fn default() -> Self {
        Self::new()
    }
}

impl TxLookup {
    pub fn new() -> Self {
        TxLookup {
            entries: vec![Vec::new(); 8 << 12],
            programmed: 0,
        }
    }

    #[inline]
    fn index(hicann: u8, pulse_addr: u16) -> usize {
        debug_assert!(hicann < 8);
        debug_assert!(pulse_addr < (1 << 12));
        ((hicann as usize) << 12) | pulse_addr as usize
    }

    /// Program one entry: replaces the fan-out list with a single target.
    pub fn set(&mut self, hicann: u8, pulse_addr: u16, entry: TxEntry) {
        let e = &mut self.entries[Self::index(hicann, pulse_addr)];
        if e.is_empty() {
            self.programmed += 1;
        }
        e.clear();
        e.push(entry);
    }

    /// Add a fan-out target to a source.
    pub fn add(&mut self, hicann: u8, pulse_addr: u16, entry: TxEntry) {
        let e = &mut self.entries[Self::index(hicann, pulse_addr)];
        if e.is_empty() {
            self.programmed += 1;
        }
        e.push(entry);
    }

    /// Look up the fan-out list for an event (empty slice = unrouted).
    #[inline]
    pub fn lookup(&self, ev: &SpikeEvent) -> &[TxEntry] {
        &self.entries[Self::index(ev.hicann, ev.pulse_addr)]
    }

    /// Number of programmed sources.
    pub fn len(&self) -> usize {
        self.programmed
    }

    pub fn is_empty(&self) -> bool {
        self.programmed == 0
    }
}

/// One RX lookup-table entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RxEntry {
    /// Multicast mask over the 8 HICANN chips of this FPGA (bit i set ⇒
    /// the event is delivered to HICANN i).
    pub hicann_mask: u8,
    /// Translated pulse address to present on the HICANN links.
    pub pulse_addr: u16,
}

/// The RX lookup table: `GUID → RxEntry` (32768-entry SRAM).
#[derive(Clone, Debug)]
pub struct RxLookup {
    entries: Vec<Option<RxEntry>>,
}

impl Default for RxLookup {
    fn default() -> Self {
        Self::new()
    }
}

impl RxLookup {
    pub fn new() -> Self {
        RxLookup {
            entries: vec![None; 1 << 15],
        }
    }

    pub fn set(&mut self, guid: u16, entry: RxEntry) {
        debug_assert!(guid < (1 << 15));
        self.entries[guid as usize] = Some(entry);
    }

    #[inline]
    pub fn lookup(&self, guid: u16) -> Option<RxEntry> {
        self.entries[(guid & 0x7FFF) as usize]
    }

    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|e| e.is_none())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_pack_roundtrip() {
        for node in [0u16, 1, 100, 1023] {
            for fpga in [0u8, 1, 5, 47, 63] {
                let e = EndpointAddr::new(NodeAddr(node), fpga);
                assert_eq!(EndpointAddr::from_u16(e.as_u16()), e);
            }
        }
    }

    #[test]
    #[should_panic(expected = "10 bits")]
    fn endpoint_overflow_panics() {
        let _ = EndpointAddr::new(NodeAddr(1 << 10), 0).as_u16();
    }

    #[test]
    fn tx_lookup_roundtrip() {
        let mut lut = TxLookup::new();
        let entry = TxEntry {
            dest: EndpointAddr::new(NodeAddr(7), 3),
            guid: 1234,
        };
        lut.set(2, 0x5A5, entry);
        let ev = SpikeEvent::new(2, 0x5A5, 100);
        assert_eq!(lut.lookup(&ev), &[entry]);
        // unprogrammed entries miss
        let miss = SpikeEvent::new(3, 0x5A5, 100);
        assert!(lut.lookup(&miss).is_empty());
        assert_eq!(lut.len(), 1);
    }

    #[test]
    fn tx_lookup_fanout() {
        let mut lut = TxLookup::new();
        for i in 0..3u16 {
            lut.add(
                1,
                7,
                TxEntry {
                    dest: EndpointAddr::new(NodeAddr(i), 0),
                    guid: 100 + i,
                },
            );
        }
        let ev = SpikeEvent::new(1, 7, 0);
        let targets = lut.lookup(&ev);
        assert_eq!(targets.len(), 3);
        assert_eq!(targets[2].guid, 102);
        assert_eq!(lut.len(), 1, "one source, three targets");
    }

    #[test]
    fn rx_lookup_roundtrip() {
        let mut lut = RxLookup::new();
        let entry = RxEntry {
            hicann_mask: 0b1010_0001,
            pulse_addr: 0x0FF,
        };
        lut.set(77, entry);
        assert_eq!(lut.lookup(77), Some(entry));
        assert_eq!(lut.lookup(78), None);
        assert_eq!(lut.len(), 1);
    }

    #[test]
    fn tx_index_disambiguates_hicanns() {
        let mut lut = TxLookup::new();
        for h in 0..8u8 {
            lut.set(
                h,
                42,
                TxEntry {
                    dest: EndpointAddr::new(NodeAddr(h as u16), 0),
                    guid: h as u16,
                },
            );
        }
        for h in 0..8u8 {
            let ev = SpikeEvent::new(h, 42, 0);
            assert_eq!(lut.lookup(&ev)[0].guid, h as u16);
        }
        assert_eq!(lut.len(), 8);
    }
}
