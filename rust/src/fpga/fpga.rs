//! The communication-FPGA actor (paper §3): the complete TX pipeline
//! (HICANN ingest → TX lookup → aggregation buckets → egress serializer →
//! Extoll injection) and RX pipeline (packet delivery → GUID lookup →
//! multicast to HICANN playback).
//!
//! Timing model at the 210 MHz FPGA clock:
//! - ingest accepts at most one event per clock (paper §3.1); pacing is
//!   enforced by the HICANN link model on the generator side,
//! - the egress serializer shifts one 64-bit word per clock, so a packet
//!   occupies it for [`Packet::egress_cycles`] — this is what makes single
//!   30-bit events cost "one event every two clocks" and what aggregation
//!   amortizes,
//! - bucket deadline scans are event-driven: the actor schedules a timer
//!   for the earliest deadline-margin expiry instead of polling each clock.

use std::collections::VecDeque;

use crate::extoll::packet::Packet;
use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};
use crate::util::stats::Histogram;

use super::bucket::{FlushBatch, FlushReason};
use super::event::{systime_of, ts_before_eq, RoutedEvent, SpikeEvent};
use super::hicann::PlaybackStats;
use super::lookup::{EndpointAddr, RxLookup, TxLookup};
use super::manager::{BucketManager, ManagerConfig};

/// Timer tags of the FPGA actor.
pub const TIMER_DEADLINE_SCAN: u32 = 1;
pub const TIMER_EGRESS_DONE: u32 = 2;
pub const TIMER_FLUSH_ALL: u32 = 3;

/// Configuration of one communication FPGA.
#[derive(Clone, Copy, Debug)]
pub struct FpgaConfig {
    /// This FPGA's network endpoint (torus node + index at concentrator).
    pub endpoint: EndpointAddr,
    /// Bucket-manager parameters (pool size, capacity, deadline margin,
    /// eviction policy, concurrency ablation).
    pub manager: ManagerConfig,
    /// FPGA→concentrator Extoll link rate in Gbit/s (Kintex-7 transceivers;
    /// 4 lanes × 8.4 by default).
    pub egress_gbps: f64,
    /// Injection credits towards the concentrator (packets in flight).
    pub inject_credits: u32,
    /// TX/RX lookup pipeline latency in FPGA cycles.
    pub lookup_cycles: u64,
    /// Capacity of the ingest stall FIFO (events waiting for a bucket side
    /// to free up); beyond this, events are dropped and counted.
    pub stall_fifo: usize,
}

impl Default for FpgaConfig {
    fn default() -> Self {
        FpgaConfig {
            endpoint: EndpointAddr::new(crate::extoll::torus::NodeAddr(0), 0),
            manager: ManagerConfig::default(),
            egress_gbps: 4.0 * 8.4,
            inject_credits: 4,
            lookup_cycles: 2,
            stall_fifo: 64,
        }
    }
}

/// FPGA statistics.
#[derive(Clone, Debug, Default)]
pub struct FpgaStats {
    /// TX side.
    pub events_in: u64,
    pub tx_unrouted: u64,
    pub events_out: u64,
    pub packets_out: u64,
    /// Wire bytes (header + cell-padded payload) of transmitted packets —
    /// the per-neuron communication cost metric of the rack scenario.
    pub tx_wire_bytes: u64,
    pub stalled_events: u64,
    pub dropped_events: u64,
    /// Events per transmitted packet (aggregation efficiency).
    pub batch_size: Histogram,
    /// Event wait time in the bucket (ingress → flush trigger), ps.
    pub bucket_wait_ps: Histogram,
    /// Egress serializer busy time.
    pub egress_busy: Time,
    /// RX side.
    pub rx_packets: u64,
    pub rx_events: u64,
    pub playback: PlaybackStats,
}

impl FpgaStats {
    /// Mean events per packet on the TX side.
    pub fn mean_batch(&self) -> f64 {
        if self.packets_out == 0 {
            f64::NAN
        } else {
            self.events_out as f64 / self.packets_out as f64
        }
    }
}

/// The FPGA actor.
pub struct Fpga {
    pub cfg: FpgaConfig,
    pub tx_lut: TxLookup,
    pub rx_lut: RxLookup,
    pub mgr: BucketManager,
    /// The concentrator (or NIC) that receives our injected packets.
    uplink: Option<ActorId>,
    /// Batches cut from buckets, waiting for the egress serializer.
    egress_q: VecDeque<FlushBatch>,
    egress_busy: bool,
    inject_credits: u32,
    /// Events rejected by the manager (both bucket sides busy), waiting to
    /// be replayed — models the ingest stall FIFO.
    stalled: VecDeque<(EndpointAddr, RoutedEvent)>,
    /// Bucket indices whose batches are in the egress serializer, in
    /// serialization order (drain_complete fires when the packet leaves).
    draining: VecDeque<usize>,
    /// Earliest scheduled deadline-scan time (dedup of timer events).
    scan_at: Option<Time>,
    /// Packet sequence counter (seeded from the endpoint for global
    /// uniqueness across FPGAs).
    seq: u64,
    /// Delivered events buffer for the coordinator / neuron layer: the
    /// experiment drains this each timestep.
    pub rx_buffer: Vec<(Time, u16, RoutedEvent)>, // (arrival, hicann mask expanded later, event)
    pub stats: FpgaStats,
}

impl Fpga {
    pub fn new(cfg: FpgaConfig) -> Self {
        Fpga {
            cfg,
            tx_lut: TxLookup::new(),
            rx_lut: RxLookup::new(),
            mgr: BucketManager::new(cfg.manager),
            uplink: None,
            egress_q: VecDeque::new(),
            egress_busy: false,
            inject_credits: cfg.inject_credits,
            stalled: VecDeque::new(),
            draining: VecDeque::new(),
            scan_at: None,
            seq: (cfg.endpoint.as_u16() as u64) << 40,
            rx_buffer: Vec::new(),
            stats: FpgaStats::default(),
        }
    }

    /// Attach the uplink (concentrator mux or NIC local port).
    pub fn attach_uplink(&mut self, id: ActorId) {
        self.uplink = Some(id);
    }

    /// Egress serialization time for a packet: the slower of the 64-bit
    /// datapath at 210 MHz and the serial link at `egress_gbps`.
    fn egress_time(&self, p: &Packet) -> Time {
        let datapath = Time::from_fpga_cycles(p.egress_cycles());
        let serial = crate::sim::ps_for_bits(p.wire_bytes() as u64 * 8, self.cfg.egress_gbps);
        datapath.max(serial)
    }

    fn enqueue_batches(&mut self, batches: Vec<FlushBatch>, ctx: &mut Ctx<'_, Msg>) {
        for b in batches {
            debug_assert!(!b.events.is_empty());
            self.egress_q.push_back(b);
        }
        self.try_egress(ctx);
    }

    fn try_egress(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.egress_busy || self.inject_credits == 0 {
            return;
        }
        let Some(batch) = self.egress_q.pop_front() else {
            return;
        };
        let now = ctx.now();
        for ev in &batch.events {
            self.stats
                .bucket_wait_ps
                .record(now.saturating_sub(ev.ingress).ps());
        }
        self.stats.events_out += batch.events.len() as u64;
        self.stats.packets_out += 1;
        self.stats.batch_size.record(batch.events.len() as u64);
        self.seq += 1;
        let mut packet = Packet::spike_batch(
            self.cfg.endpoint.node,
            batch.dest,
            batch.events,
            batch.oldest_ingress,
            self.seq,
        );
        // mark ourselves as the ingress so the concentrator (or uplink
        // stub) can return the injection credit when it takes the packet
        packet.ingress = Some((ctx.self_id(), crate::extoll::torus::LOCAL_PORT, 0));
        self.stats.tx_wire_bytes += packet.wire_bytes() as u64;
        let ser = self.egress_time(&packet);
        self.stats.egress_busy += ser;
        self.egress_busy = true;
        self.inject_credits -= 1;
        let uplink = self.uplink.expect("fpga has no uplink attached");
        // the packet leaves us fully serialized after `ser`
        ctx.send(uplink, ser, Msg::Inject(packet));
        // remember which bucket to release: encode bucket_idx in the timer
        // by keeping a parallel queue
        self.draining.push_back(batch.bucket_idx);
        ctx.send_self(ser, Msg::Timer(TIMER_EGRESS_DONE));
    }

    /// Replay stalled events after a drain completed.
    fn replay_stalled(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let mut still_stalled = VecDeque::new();
        while let Some((dest, ev)) = self.stalled.pop_front() {
            let r = self.mgr.insert(dest, ev);
            if !r.accepted {
                still_stalled.push_back((dest, ev));
            }
            if !r.batches.is_empty() {
                self.enqueue_batches(r.batches, ctx);
            }
            if !still_stalled.is_empty() {
                // keep order; stop retrying once one is refused
                while let Some(x) = self.stalled.pop_front() {
                    still_stalled.push_back(x);
                }
                break;
            }
        }
        self.stalled = still_stalled;
        self.schedule_scan(ctx);
    }

    /// (Re)schedule the deadline-scan timer for the earliest bucket expiry
    /// (full scan over all buckets — used after timer fires / replays).
    fn schedule_scan(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(fire_sys) = self.mgr.next_deadline_fire() else {
            return;
        };
        self.schedule_scan_at(fire_sys, ctx);
    }

    /// Schedule a scan for one known fire time if it is earlier than the
    /// currently scheduled one. O(1) — the per-event path uses this with
    /// the affected bucket's fire time instead of scanning all buckets
    /// (PERF.md §Methodology).
    fn schedule_scan_at(&mut self, fire_sys: u16, ctx: &mut Ctx<'_, Msg>) {
        let now = ctx.now();
        let now_sys = systime_of(now);
        let delta = super::event::ts_delta(now_sys, fire_sys);
        // if the fire time is in the past half-window, scan immediately
        let delay = if delta > super::event::TS_MASK / 2 {
            Time::ZERO
        } else {
            super::event::systime_unit() * delta as u64
        };
        let at = now + delay;
        if let Some(cur) = self.scan_at {
            if cur <= at && cur >= now {
                return; // an earlier or equal scan is already scheduled
            }
        }
        self.scan_at = Some(at);
        ctx.send_self(delay, Msg::Timer(TIMER_DEADLINE_SCAN));
    }

    /// RX path: distribute a delivered spike batch to the HICANN chips.
    /// The spent payload buffer goes back to the packet pool
    /// (`extoll::packet::pool`) for the next bucket flush.
    fn receive_batch(&mut self, events: Vec<RoutedEvent>, ctx: &mut Ctx<'_, Msg>) {
        let now = ctx.now();
        // model the RX lookup pipeline latency once per packet
        let _ = self.cfg.lookup_cycles;
        self.stats.rx_packets += 1;
        for ev in events.iter().copied() {
            self.stats.rx_events += 1;
            match self.rx_lut.lookup(ev.guid) {
                None => {
                    self.stats.playback.unrouted += 1;
                }
                Some(entry) => {
                    let n_targets = entry.hicann_mask.count_ones() as u64;
                    for h in 0..super::hicann::HICANNS_PER_FPGA {
                        if entry.hicann_mask & (1 << h) != 0 {
                            self.stats.playback.per_hicann[h] += 1;
                        }
                    }
                    let _ = n_targets;
                    self.stats
                        .playback
                        .latency_ps
                        .record(now.saturating_sub(ev.ingress).ps());
                    // deadline check: has the arrival deadline passed?
                    let now_sys = systime_of(now);
                    if !ts_before_eq(now_sys, ev.timestamp) {
                        self.stats.playback.deadline_misses += 1;
                    }
                    self.rx_buffer.push((now, entry.pulse_addr, ev));
                }
            }
        }
        crate::extoll::packet::pool::recycle(events);
    }

    /// Total events currently inside the FPGA (buckets + stall FIFO +
    /// egress queue) — used by tests for conservation checks.
    pub fn inflight_events(&self) -> usize {
        self.mgr.buffered_events()
            + self.stalled.len()
            + self.egress_q.iter().map(|b| b.events.len()).sum::<usize>()
    }
}

// The draining-bucket FIFO lives outside the struct definition above for
// readability; declare it here.
impl Fpga {
    fn drain_front(&mut self) {
        if let Some(idx) = self.draining.pop_front() {
            self.mgr.drain_complete(idx);
        }
    }
}

impl Actor<Msg> for Fpga {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            // ---- TX: event from a HICANN link --------------------------
            Msg::HicannEvent(ev) => {
                self.stats.events_in += 1;
                // index-based iteration avoids allocating the fan-out list
                // on the ingest hot path (TxEntry is Copy; the repeated
                // lookup is a direct SRAM index)
                let n_targets = self.tx_lut.lookup(&ev).len();
                if n_targets == 0 {
                    self.stats.tx_unrouted += 1;
                    return;
                }
                for ti in 0..n_targets {
                    let entry = self.tx_lut.lookup(&ev)[ti];
                    let routed = RoutedEvent::new(entry.guid, ev.timestamp, ctx.now());
                    let r = self.mgr.insert(entry.dest, routed);
                    if !r.accepted {
                        self.stats.stalled_events += 1;
                        if self.stalled.len() >= self.cfg.stall_fifo {
                            self.stats.dropped_events += 1;
                        } else {
                            self.stalled.push_back((entry.dest, routed));
                        }
                    }
                    if !r.batches.is_empty() {
                        self.enqueue_batches(r.batches, ctx);
                    }
                    // O(1) targeted scan scheduling: only this event's
                    // bucket can have introduced an earlier deadline
                    if let Some(idx) = self.mgr.index_of(entry.dest) {
                        if let Some(fire) = self.mgr.bucket(idx).deadline_fire_at() {
                            self.schedule_scan_at(fire, ctx);
                        }
                    }
                }
            }
            // ---- RX: packet delivered from the fabric ------------------
            Msg::Deliver(p) => {
                match p.kind {
                    crate::extoll::packet::PacketKind::SpikeBatch { dst_fpga, events } => {
                        debug_assert_eq!(dst_fpga, self.cfg.endpoint.fpga);
                        self.receive_batch(events, ctx);
                    }
                    other => panic!("fpga: unexpected packet kind {other:?}"),
                }
            }
            // ---- timers -------------------------------------------------
            Msg::Timer(TIMER_DEADLINE_SCAN) => {
                self.scan_at = None;
                let now_sys = systime_of(ctx.now());
                let batches = self.mgr.poll_deadlines(now_sys);
                if !batches.is_empty() {
                    self.enqueue_batches(batches, ctx);
                }
                self.schedule_scan(ctx);
            }
            Msg::Timer(TIMER_EGRESS_DONE) => {
                self.egress_busy = false;
                self.drain_front();
                self.replay_stalled(ctx);
                self.try_egress(ctx);
            }
            Msg::Timer(TIMER_FLUSH_ALL) => {
                let batches = self.mgr.flush_all();
                if !batches.is_empty() {
                    self.enqueue_batches(batches, ctx);
                }
            }
            // ---- credit from the uplink ---------------------------------
            Msg::Credit { .. } => {
                self.inject_credits += 1;
                self.try_egress(ctx);
            }
            other => panic!("fpga {:?}: unexpected message {other:?}", self.cfg.endpoint),
        }
    }

    fn name(&self) -> String {
        format!("fpga-{}-{}", self.cfg.endpoint.node, self.cfg.endpoint.fpga)
    }

    /// Lives on its concentrator's torus node: FPGA↔concentrator traffic
    /// is sub-lookahead, so the whole wafer-side stack of a node shares
    /// one PDES domain.
    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::Site(self.cfg.endpoint.node.0 as u32)
    }

    /// Reconstruct from config, keeping the uplink wiring. `Fpga::new` is
    /// a pure function of `cfg` (including the endpoint-seeded packet
    /// sequence counter), and route tables are re-programmed per execute
    /// by `apply_plan`, so this is byte-identical to a cold build.
    fn reset(&mut self) -> bool {
        let uplink = self.uplink;
        *self = Fpga::new(self.cfg);
        self.uplink = uplink;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::NodeAddr;
    use crate::fpga::bucket::BucketConfig;
    use crate::fpga::lookup::{RxEntry, TxEntry};
    use crate::fpga::manager::EvictionPolicy;
    use crate::sim::Sim;

    /// Uplink stub: counts injected packets, returns credits immediately.
    struct UplinkStub {
        fpga: ActorId,
        packets: Vec<(Time, Packet)>,
    }

    impl Actor<Msg> for UplinkStub {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::Inject(p) = msg {
                self.packets.push((ctx.now(), p));
                ctx.send(self.fpga, Time::ZERO, Msg::Credit { port: 6, vc: 0 });
            }
        }
    }

    fn cfg(node: u16, fpga: u8) -> FpgaConfig {
        FpgaConfig {
            endpoint: EndpointAddr::new(NodeAddr(node), fpga),
            manager: ManagerConfig {
                n_buckets: 8,
                bucket: BucketConfig {
                    capacity: 124,
                    deadline_margin: 100,
                    concurrent: true,
                },
                eviction: EvictionPolicy::MostUrgent,
            },
            ..FpgaConfig::default()
        }
    }

    fn setup(c: FpgaConfig) -> (Sim<Msg>, ActorId, ActorId) {
        let mut sim = Sim::new();
        let fpga = sim.add(Fpga::new(c));
        let uplink = sim.add(UplinkStub {
            fpga,
            packets: vec![],
        });
        sim.get_mut::<Fpga>(fpga).attach_uplink(uplink);
        (sim, fpga, uplink)
    }

    fn program_route(sim: &mut Sim<Msg>, fpga: ActorId, pulse: u16, dest: EndpointAddr, guid: u16) {
        sim.get_mut::<Fpga>(fpga).tx_lut.set(
            0,
            pulse,
            TxEntry { dest, guid },
        );
    }

    #[test]
    fn unrouted_events_are_counted_and_dropped() {
        let (mut sim, fpga, _) = setup(cfg(0, 0));
        sim.schedule(Time::ZERO, fpga, Msg::HicannEvent(SpikeEvent::new(0, 7, 100)));
        sim.run_to_completion();
        let f: &Fpga = sim.get(fpga);
        assert_eq!(f.stats.events_in, 1);
        assert_eq!(f.stats.tx_unrouted, 1);
        assert_eq!(f.stats.packets_out, 0);
    }

    #[test]
    fn deadline_flush_emits_packet() {
        let (mut sim, fpga, uplink) = setup(cfg(0, 0));
        let dest = EndpointAddr::new(NodeAddr(5), 2);
        program_route(&mut sim, fpga, 7, dest, 99);
        // event with deadline 1000 cycles out; margin 100 → flush at ~900
        // cycles ≈ 4.29 µs
        let ev = SpikeEvent::new(0, 7, 1000);
        sim.schedule(Time::ZERO, fpga, Msg::HicannEvent(ev));
        sim.run_until(Time::from_ms(1));
        let u: &UplinkStub = sim.get(uplink);
        assert_eq!(u.packets.len(), 1);
        let p = &u.packets[0].1;
        assert_eq!(p.dst, NodeAddr(5));
        assert_eq!(p.n_events(), 1);
        // flush fired before the deadline, after (deadline - margin)
        let fire = u.packets[0].0;
        let cycles = fire.fpga_cycles();
        assert!(cycles >= 890 && cycles <= 1001, "fired at cycle {cycles}");
        let f: &Fpga = sim.get(fpga);
        assert_eq!(f.mgr.stats.flush_deadline, 1);
    }

    #[test]
    fn full_bucket_emits_immediately() {
        let (mut sim, fpga, uplink) = setup(cfg(0, 0));
        let dest = EndpointAddr::new(NodeAddr(3), 1);
        program_route(&mut sim, fpga, 7, dest, 42);
        // 124 events back-to-back; deadline 0x3000 cycles (~58 µs) is far
        // enough in the future (within the unambiguous half-window) that no
        // deadline flush fires inside the observation window
        for i in 0..124u64 {
            sim.schedule(
                Time::from_ns(i * 10),
                fpga,
                Msg::HicannEvent(SpikeEvent::new(0, 7, 0x3000)),
            );
        }
        sim.run_until(Time::from_us(50));
        let u: &UplinkStub = sim.get(uplink);
        assert_eq!(u.packets.len(), 1);
        assert_eq!(u.packets[0].1.n_events(), 124);
        let f: &Fpga = sim.get(fpga);
        assert_eq!(f.mgr.stats.flush_full, 1);
        assert_eq!(f.stats.mean_batch(), 124.0);
    }

    #[test]
    fn rx_path_multicasts_and_buffers() {
        let (mut sim, fpga, _) = setup(cfg(5, 2));
        sim.get_mut::<Fpga>(fpga).rx_lut.set(
            42,
            RxEntry {
                hicann_mask: 0b0000_0101, // HICANN 0 and 2
                pulse_addr: 0x123,
            },
        );
        let events = vec![RoutedEvent::new(42, 5000, Time::ZERO)];
        let p = Packet::spike_batch(
            NodeAddr(0),
            EndpointAddr::new(NodeAddr(5), 2),
            events,
            Time::ZERO,
            1,
        );
        sim.schedule(Time::from_us(1), fpga, Msg::Deliver(p));
        sim.run_to_completion();
        let f: &Fpga = sim.get(fpga);
        assert_eq!(f.stats.rx_events, 1);
        assert_eq!(f.stats.playback.per_hicann[0], 1);
        assert_eq!(f.stats.playback.per_hicann[2], 1);
        assert_eq!(f.stats.playback.per_hicann[1], 0);
        assert_eq!(f.rx_buffer.len(), 1);
        assert_eq!(f.rx_buffer[0].1, 0x123);
        // deadline 5000 cycles ≈ 23.8us > 1us arrival: no miss
        assert_eq!(f.stats.playback.deadline_misses, 0);
    }

    #[test]
    fn rx_deadline_miss_detected() {
        let (mut sim, fpga, _) = setup(cfg(5, 2));
        sim.get_mut::<Fpga>(fpga).rx_lut.set(
            1,
            RxEntry {
                hicann_mask: 1,
                pulse_addr: 0,
            },
        );
        // deadline = systime 10 (≈47.6 ns), delivered at 50 µs → missed
        // (within the unambiguous half of the 15-bit systime window)
        let events = vec![RoutedEvent::new(1, 10, Time::ZERO)];
        let p = Packet::spike_batch(
            NodeAddr(0),
            EndpointAddr::new(NodeAddr(5), 2),
            events,
            Time::ZERO,
            1,
        );
        sim.schedule(Time::from_us(50), fpga, Msg::Deliver(p));
        sim.run_to_completion();
        let f: &Fpga = sim.get(fpga);
        assert_eq!(f.stats.playback.deadline_misses, 1);
    }

    #[test]
    fn event_conservation_under_load() {
        let (mut sim, fpga, uplink) = setup(cfg(0, 0));
        // route 16 pulse addresses to 16 different destinations (> buckets)
        for pa in 0..16u16 {
            program_route(
                &mut sim,
                fpga,
                pa,
                EndpointAddr::new(NodeAddr(pa + 1), (pa % 6) as u8),
                pa + 100,
            );
        }
        let mut rng = crate::util::rng::Rng::new(7);
        let n = 5000u64;
        for i in 0..n {
            let pa = rng.below(16) as u16;
            let deadline = ((i / 4 + 500) & 0x7FFF) as u16;
            sim.schedule(
                Time::from_ns(i * 40),
                fpga,
                Msg::HicannEvent(SpikeEvent::new(0, pa, deadline)),
            );
        }
        sim.run_until(Time::from_ms(10));
        // final external flush
        sim.schedule(sim.now, fpga, Msg::Timer(TIMER_FLUSH_ALL));
        sim.run_to_completion();
        let f: &Fpga = sim.get(fpga);
        let u: &UplinkStub = sim.get(uplink);
        let sent: usize = u.packets.iter().map(|(_, p)| p.n_events()).sum();
        assert_eq!(f.stats.events_in, n);
        assert_eq!(
            sent as u64 + f.stats.dropped_events + f.inflight_events() as u64,
            n,
            "event conservation violated"
        );
        // with 40ns spacing and a 124-event cap nothing should drop
        assert_eq!(f.stats.dropped_events, 0);
        assert_eq!(f.inflight_events(), 0, "flush-all left events behind");
        assert_eq!(sent as u64, n);
    }

    #[test]
    fn aggregation_efficiency_grows_with_rate() {
        // at high rate into one destination, mean batch size should be large
        let (mut sim, fpga, _) = setup(cfg(0, 0));
        let dest = EndpointAddr::new(NodeAddr(2), 0);
        program_route(&mut sim, fpga, 7, dest, 9);
        for i in 0..10_000u64 {
            // deadline tracks arrival (~1.05 cycles per 5 ns) plus 2000
            // cycles of slack, so deadline flushes never preempt Full ones
            sim.schedule(
                Time::from_ns(i * 5), // 200 Mev/s
                fpga,
                Msg::HicannEvent(SpikeEvent::new(0, 7, ((i + 2000) & 0x7FFF) as u16)),
            );
        }
        sim.run_until(Time::from_ms(2));
        let f: &Fpga = sim.get(fpga);
        assert!(
            f.stats.mean_batch() > 60.0,
            "mean batch {} too small at saturation",
            f.stats.mean_batch()
        );
    }
}
