//! HICANN link model (paper §1, §3.1).
//!
//! Each BrainScaleS reticle carries 8 HICANN chips connected to the
//! communication FPGA through 8 serial links of 1 Gbit/s. Events arrive at
//! the FPGA "with rates of up to approximately one event per 210 MHz FPGA
//! clock" in aggregate. This module models the per-link pacing (framing
//! bits per event at the line rate) and the playback direction (FPGA →
//! HICANN after the RX multicast lookup).

use crate::sim::{ps_for_bits, Time};
use crate::util::stats::Histogram;

/// Number of HICANN chips per communication FPGA (one reticle).
pub const HICANNS_PER_FPGA: usize = 8;

/// Physical parameters of one HICANN↔FPGA serial link.
#[derive(Clone, Copy, Debug)]
pub struct HicannLinkConfig {
    /// Line rate in Gbit/s (paper: "8 1 Gbit/s serial links").
    pub gbps: f64,
    /// Bits per event frame on the serial link (event + framing). 38 bits
    /// makes 8 links sum to ≈210 Mevent/s — the paper's "approximately one
    /// event per 210 MHz FPGA clock".
    pub bits_per_event: u32,
}

impl Default for HicannLinkConfig {
    fn default() -> Self {
        HicannLinkConfig {
            gbps: 1.0,
            bits_per_event: 38,
        }
    }
}

impl HicannLinkConfig {
    /// Minimum spacing between two events on one link.
    pub fn event_spacing(&self) -> Time {
        ps_for_bits(self.bits_per_event as u64, self.gbps)
    }

    /// Maximum event rate of one link (events/s).
    pub fn max_rate(&self) -> f64 {
        self.gbps * 1e9 / self.bits_per_event as f64
    }

    /// Aggregate maximum rate over the 8 links of an FPGA (events/s).
    pub fn max_aggregate_rate(&self) -> f64 {
        self.max_rate() * HICANNS_PER_FPGA as f64
    }
}

/// Playback sink: statistics of events delivered from the FPGA back to its
/// HICANN chips (the end of the RX multicast path).
#[derive(Clone, Debug, Default)]
pub struct PlaybackStats {
    /// Events delivered per HICANN chip.
    pub per_hicann: [u64; HICANNS_PER_FPGA],
    /// End-to-end event latency: source-FPGA ingress → HICANN delivery (ps).
    pub latency_ps: Histogram,
    /// Events that arrived after their deadline.
    pub deadline_misses: u64,
    /// Events whose GUID missed in the RX lookup table.
    pub unrouted: u64,
}

impl PlaybackStats {
    pub fn total_delivered(&self) -> u64 {
        self.per_hicann.iter().sum()
    }

    /// Deadline miss rate over delivered events.
    pub fn miss_rate(&self) -> f64 {
        let n = self.latency_ps.count();
        if n == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_rates_match_paper() {
        let cfg = HicannLinkConfig::default();
        // one event per 38 ns per link
        assert_eq!(cfg.event_spacing(), Time::from_ps(38_000));
        // 8 links ≈ 210.5 Mevent/s — the paper's "one event per 210 MHz clock"
        let agg = cfg.max_aggregate_rate();
        assert!(
            (agg - 210.5e6).abs() < 1e6,
            "aggregate rate {agg} not ≈ 210 Mev/s"
        );
    }

    #[test]
    fn spacing_scales_with_rate() {
        let cfg = HicannLinkConfig {
            gbps: 2.0,
            bits_per_event: 38,
        };
        assert_eq!(cfg.event_spacing(), Time::from_ps(19_000));
    }

    #[test]
    fn playback_stats_accounting() {
        let mut s = PlaybackStats::default();
        s.per_hicann[0] += 3;
        s.per_hicann[7] += 2;
        s.latency_ps.record(1000);
        s.latency_ps.record(2000);
        s.deadline_misses = 1;
        assert_eq!(s.total_delivered(), 5);
        assert!((s.miss_rate() - 0.5).abs() < 1e-12);
    }
}
