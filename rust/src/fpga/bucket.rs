//! The event-accumulation buffer — "bucket" (paper §3.1, Fig. 2b).
//!
//! A bucket aggregates events heading to the same network destination until
//! a flushing condition is met:
//!
//! 1. the most urgent timestamp deadline is about to be exceeded,
//! 2. the buffer is full (124 events — one max-size Extoll packet), or
//! 3. external logic (the bucket manager / arbiter) triggers a flush.
//!
//! "To avoid large latencies, concurrent flushing and aggregation is
//! implemented. Two counters track the filling level of a bucket. One
//! increments for incoming events while the other one decrements for
//! flushed events. The counters are swapped when a flush is triggered."
//!
//! The model mirrors that structure: an *accumulation side* (fill counter)
//! and a *drain side* (flush counter). Triggering a flush swaps the sides —
//! the accumulated events become the drain set (handed to the egress
//! serializer) while new events keep accumulating into the (now empty)
//! fill side. A second flush cannot be triggered while the drain side is
//! still being shifted out; callers model the egress time and call
//! [`Bucket::drain_complete`].

use crate::sim::Time;

use super::event::{ts_before_eq, ts_delta, RoutedEvent, TS_MASK};
use super::lookup::EndpointAddr;

/// Why a flush fired (the three conditions of §3.1 + eviction renaming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FlushReason {
    /// The most urgent deadline in the bucket was about to expire.
    Deadline,
    /// The bucket reached capacity (a full Extoll packet).
    Full,
    /// External logic requested the flush (end of experiment, barrier).
    External,
    /// The bucket was reclaimed for a new destination (no free bucket).
    Eviction,
}

/// A batch of events handed to the egress path when a flush triggers.
#[derive(Clone, Debug)]
pub struct FlushBatch {
    pub dest: EndpointAddr,
    pub events: Vec<RoutedEvent>,
    pub reason: FlushReason,
    /// When the oldest event in the batch entered the bucket.
    pub oldest_ingress: Time,
    /// Physical bucket index (filled in by the manager; callers hand it
    /// back via [`super::manager::BucketManager::drain_complete`]).
    pub bucket_idx: usize,
}

/// Configuration of a single bucket.
#[derive(Clone, Copy, Debug)]
pub struct BucketConfig {
    /// Maximum events accumulated before a Full flush (≤ 124).
    pub capacity: usize,
    /// Deadline safety margin in systime units: flush when
    /// `deadline - now ≤ margin` for the most urgent event. This is the
    /// time budget left for egress serialization + network transit.
    pub deadline_margin: u16,
    /// Concurrent flushing & aggregation (the paper's dual-counter scheme).
    /// `false` is the ablation: the bucket cannot accept events while its
    /// drain side is busy.
    pub concurrent: bool,
}

impl Default for BucketConfig {
    fn default() -> Self {
        BucketConfig {
            capacity: crate::extoll::packet::MAX_EVENTS_PER_PACKET,
            // ~2 µs of 210 MHz cycles: enough for egress + a few torus hops
            deadline_margin: 420,
            concurrent: true,
        }
    }
}

/// One event-accumulation bucket (Fig. 2b).
#[derive(Clone, Debug)]
pub struct Bucket {
    cfg: BucketConfig,
    /// Destination currently bound to this bucket (None = on the free list).
    dest: Option<EndpointAddr>,
    /// Accumulation side ("fill counter" side of the paper's dual-counter
    /// scheme): events gathered since the last flush trigger.
    accum: Vec<RoutedEvent>,
    /// Drain side ("flush counter" side): events currently being shifted
    /// out by the egress serializer; None when idle.
    draining: bool,
    /// Most urgent (earliest) deadline among accumulated events.
    min_deadline: u16,
    /// Simulation time the oldest accumulated event entered the bucket.
    oldest_ingress: Time,
    // -- statistics ------------------------------------------------------
    pub total_events: u64,
    pub total_flushes: u64,
}

/// Outcome of inserting an event.
#[derive(Clone, Debug, PartialEq)]
pub enum InsertOutcome {
    /// Event stored; no flush necessary.
    Stored,
    /// Event stored and the bucket hit capacity → caller must flush now.
    NowFull,
}

impl Bucket {
    pub fn new(cfg: BucketConfig) -> Self {
        assert!(cfg.capacity >= 1 && cfg.capacity <= crate::extoll::packet::MAX_EVENTS_PER_PACKET);
        Bucket {
            cfg,
            dest: None,
            accum: Vec::with_capacity(cfg.capacity),
            draining: false,
            min_deadline: 0,
            oldest_ingress: Time::ZERO,
            total_events: 0,
            total_flushes: 0,
        }
    }

    /// The destination this bucket is renamed to (None = free).
    pub fn dest(&self) -> Option<EndpointAddr> {
        self.dest
    }

    /// Bind a free bucket to a destination (bucket renaming, Fig. 2c).
    pub fn bind(&mut self, dest: EndpointAddr) {
        debug_assert!(self.dest.is_none(), "binding a bound bucket");
        debug_assert!(self.accum.is_empty());
        self.dest = Some(dest);
    }

    /// Release the destination binding (after final drain).
    pub fn unbind(&mut self) {
        debug_assert!(self.accum.is_empty(), "unbinding a non-empty bucket");
        self.dest = None;
    }

    /// Events on the accumulation side.
    pub fn fill_level(&self) -> usize {
        self.accum.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accum.is_empty()
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Most urgent deadline (only meaningful when non-empty).
    pub fn min_deadline(&self) -> u16 {
        self.min_deadline
    }

    /// When the oldest accumulated event arrived (latency accounting).
    pub fn oldest_ingress(&self) -> Time {
        self.oldest_ingress
    }

    /// Insert an event (≤ one per FPGA clock in the hardware; rate is
    /// enforced by the caller's timing model, not here).
    pub fn insert(&mut self, ev: RoutedEvent) -> InsertOutcome {
        debug_assert!(self.dest.is_some(), "insert into unbound bucket");
        debug_assert!(
            self.accum.len() < self.cfg.capacity,
            "insert into full bucket — caller must flush first"
        );
        if self.accum.is_empty() {
            self.min_deadline = ev.timestamp;
            self.oldest_ingress = ev.ingress;
        } else if ts_before_eq(ev.timestamp, self.min_deadline) {
            self.min_deadline = ev.timestamp;
        }
        self.accum.push(ev);
        self.total_events += 1;
        if self.accum.len() >= self.cfg.capacity {
            InsertOutcome::NowFull
        } else {
            InsertOutcome::Stored
        }
    }

    /// Would the deadline condition fire at systime `now`?
    ///
    /// True when the remaining slack of the most urgent event is within the
    /// configured margin (or already past — the wrapped comparison treats
    /// "past" as slack 0 within half the 15-bit window).
    pub fn deadline_due(&self, now_systime: u16) -> bool {
        if self.accum.is_empty() {
            return false;
        }
        let slack = ts_delta(now_systime, self.min_deadline);
        // slack is in [0, 2^15); values in the upper half mean the deadline
        // already passed (now is ahead of the deadline) → definitely due.
        slack <= self.cfg.deadline_margin as u16 || slack > TS_MASK / 2
    }

    /// Absolute systime at which the deadline condition will fire, given
    /// the current contents (for event-driven scan scheduling).
    pub fn deadline_fire_at(&self) -> Option<u16> {
        if self.accum.is_empty() {
            None
        } else {
            Some(
                self.min_deadline
                    .wrapping_sub(self.cfg.deadline_margin)
                    & TS_MASK,
            )
        }
    }

    /// Trigger a flush: swap the dual counters — the accumulation side
    /// becomes the drain set, accumulation restarts empty. Returns `None`
    /// if there is nothing to flush or a drain is still in progress
    /// (concurrent flush covers exactly one outstanding drain, as in the
    /// two-counter hardware scheme).
    pub fn trigger_flush(&mut self, reason: FlushReason) -> Option<FlushBatch> {
        if self.accum.is_empty() || self.draining {
            return None;
        }
        let dest = self.dest.expect("flush of unbound bucket");
        // swap in a pooled replacement buffer instead of an empty Vec:
        // the flushed payload travels in the packet and is recycled by
        // the RX path (`extoll::packet::pool`), so steady-state flushing
        // allocates nothing and never regrows the accumulation side
        let events = std::mem::replace(
            &mut self.accum,
            crate::extoll::packet::pool::take(self.cfg.capacity),
        );
        let oldest = self.oldest_ingress;
        self.draining = true;
        self.total_flushes += 1;
        self.min_deadline = 0;
        self.oldest_ingress = Time::ZERO;
        Some(FlushBatch {
            dest,
            events,
            reason,
            oldest_ingress: oldest,
            bucket_idx: usize::MAX,
        })
    }

    /// The egress serializer finished shifting out the drain set.
    pub fn drain_complete(&mut self) {
        debug_assert!(self.draining, "drain_complete without drain");
        self.draining = false;
    }

    /// Mean events per flush so far (aggregation efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.total_flushes == 0 {
            f64::NAN
        } else {
            // events still accumulating are not yet flushed
            (self.total_events - self.accum.len() as u64) as f64 / self.total_flushes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::NodeAddr;

    fn dest() -> EndpointAddr {
        EndpointAddr::new(NodeAddr(3), 1)
    }

    fn bucket(capacity: usize, margin: u16) -> Bucket {
        let mut b = Bucket::new(BucketConfig {
            capacity,
            deadline_margin: margin,
            concurrent: true,
        });
        b.bind(dest());
        b
    }

    fn ev(ts: u16) -> RoutedEvent {
        RoutedEvent::new(1, ts, Time::from_ns(10))
    }

    #[test]
    fn fills_to_capacity_then_reports_full() {
        let mut b = bucket(4, 100);
        assert_eq!(b.insert(ev(50)), InsertOutcome::Stored);
        assert_eq!(b.insert(ev(60)), InsertOutcome::Stored);
        assert_eq!(b.insert(ev(40)), InsertOutcome::Stored);
        assert_eq!(b.insert(ev(70)), InsertOutcome::NowFull);
        assert_eq!(b.fill_level(), 4);
        assert_eq!(b.min_deadline(), 40);
    }

    #[test]
    fn flush_swaps_sides_and_allows_concurrent_accumulation() {
        let mut b = bucket(124, 100);
        b.insert(ev(10));
        b.insert(ev(20));
        let batch = b.trigger_flush(FlushReason::Full).unwrap();
        assert_eq!(batch.events.len(), 2);
        assert_eq!(batch.dest, dest());
        // drain in progress, accumulation continues
        assert!(b.is_draining());
        assert!(b.is_empty());
        b.insert(ev(30));
        assert_eq!(b.fill_level(), 1);
        // cannot trigger a second flush while draining
        assert!(b.trigger_flush(FlushReason::External).is_none());
        b.drain_complete();
        let batch2 = b.trigger_flush(FlushReason::External).unwrap();
        assert_eq!(batch2.events.len(), 1);
        assert_eq!(batch2.events[0].timestamp, 30);
    }

    #[test]
    fn empty_flush_is_none() {
        let mut b = bucket(8, 100);
        assert!(b.trigger_flush(FlushReason::External).is_none());
    }

    #[test]
    fn deadline_due_within_margin() {
        let mut b = bucket(124, 100);
        b.insert(ev(1000));
        assert!(!b.deadline_due(500)); // slack 500 > 100
        assert!(b.deadline_due(900)); // slack 100 <= 100
        assert!(b.deadline_due(950)); // slack 50
        assert!(b.deadline_due(1001)); // already past (wrapped slack huge)
    }

    #[test]
    fn deadline_due_wraps() {
        let mut b = bucket(124, 100);
        // deadline just past the wrap point
        b.insert(ev(5));
        // now near the top of the window: slack = 5 - 0x7FF0 wrapped = 21
        assert!(b.deadline_due(0x7FF0));
        // a deadline that already passed is immediately due
        assert!(b.deadline_due(1000));
        // plenty of slack: not due
        let mut b = bucket(124, 100);
        b.insert(ev(5000));
        assert!(!b.deadline_due(1000));
    }

    #[test]
    fn min_deadline_tracks_most_urgent_with_wrap() {
        let mut b = bucket(124, 100);
        b.insert(ev(0x7FFa));
        b.insert(ev(3)); // later than 0x7FFa in wrapped order
        assert_eq!(b.min_deadline(), 0x7FFa);
        let mut b = bucket(124, 100);
        b.insert(ev(3));
        b.insert(ev(0x7FFa)); // earlier in wrapped order
        assert_eq!(b.min_deadline(), 0x7FFa);
    }

    #[test]
    fn deadline_fire_at_is_margin_before() {
        let mut b = bucket(124, 100);
        b.insert(ev(500));
        assert_eq!(b.deadline_fire_at(), Some(400));
        let mut b = bucket(124, 50);
        b.insert(ev(10));
        assert_eq!(b.deadline_fire_at(), Some((10u16.wrapping_sub(50)) & TS_MASK));
    }

    #[test]
    fn stats_track_batches() {
        let mut b = bucket(124, 100);
        for i in 0..10 {
            b.insert(ev(i));
        }
        b.trigger_flush(FlushReason::Deadline).unwrap();
        b.drain_complete();
        for i in 0..20 {
            b.insert(ev(i));
        }
        b.trigger_flush(FlushReason::Full).unwrap();
        assert_eq!(b.total_flushes, 2);
        assert!((b.mean_batch_size() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn rebinding_after_unbind() {
        let mut b = bucket(8, 100);
        b.insert(ev(5));
        b.trigger_flush(FlushReason::Eviction).unwrap();
        b.drain_complete();
        b.unbind();
        assert_eq!(b.dest(), None);
        b.bind(EndpointAddr::new(NodeAddr(9), 2));
        b.insert(ev(7));
        assert_eq!(b.dest(), Some(EndpointAddr::new(NodeAddr(9), 2)));
        assert_eq!(b.fill_level(), 1);
    }

    #[test]
    fn oldest_ingress_resets_per_epoch() {
        let mut b = bucket(124, 100);
        b.insert(RoutedEvent::new(1, 10, Time::from_ns(100)));
        b.insert(RoutedEvent::new(1, 11, Time::from_ns(200)));
        let batch = b.trigger_flush(FlushReason::External).unwrap();
        assert_eq!(batch.oldest_ingress, Time::from_ns(100));
        b.drain_complete();
        b.insert(RoutedEvent::new(1, 12, Time::from_ns(300)));
        let batch = b.trigger_flush(FlushReason::External).unwrap();
        assert_eq!(batch.oldest_ingress, Time::from_ns(300));
    }
}
