//! Bucket management: map table, free-bucket list, urgency arbiter
//! (paper §3.1, Fig. 2c).
//!
//! "As there are up to 2^16 possible network destinations, the accumulation
//! buffers need to implement a bucket renaming principle, in analogy to the
//! well-known register renaming. To always select the right buffer for an
//! event with given destination, the buckets are managed by a map table and
//! a list of free buckets. When the lookup table indicates an address to be
//! new to the set of buckets, the address is assigned to the next free
//! bucket. If no bucket is free the next appropriate one is flushed."
//!
//! "The Arbiter selects the most urgent bucket for flushing."
//!
//! The eviction choice ("next appropriate") is a design parameter the paper
//! leaves open; [`EvictionPolicy`] exposes the candidates for the ablation
//! benchmark (`bench_bucket_mgmt`).

use crate::sim::Time;

use super::bucket::{Bucket, BucketConfig, FlushBatch, FlushReason, InsertOutcome};
use super::event::{ts_before_eq, RoutedEvent};
use super::lookup::EndpointAddr;

/// Which bucket to reclaim when a new destination arrives and none is free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// The arbiter's choice: most urgent deadline (paper default).
    MostUrgent,
    /// The fullest bucket (maximizes packet efficiency).
    Fullest,
    /// The bucket whose oldest event has waited longest.
    Oldest,
    /// Round-robin over bucket indices (cheapest hardware).
    RoundRobin,
}

/// Configuration of the bucket manager.
#[derive(Clone, Copy, Debug)]
pub struct ManagerConfig {
    /// Number of physical buckets (the renaming pool).
    pub n_buckets: usize,
    /// Per-bucket configuration.
    pub bucket: BucketConfig,
    /// Eviction policy when no bucket is free.
    pub eviction: EvictionPolicy,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        ManagerConfig {
            n_buckets: 32,
            bucket: BucketConfig::default(),
            eviction: EvictionPolicy::MostUrgent,
        }
    }
}

/// Counters of the manager's behaviour (per flush reason, renames...).
#[derive(Clone, Debug, Default)]
pub struct ManagerStats {
    pub events_in: u64,
    pub flush_deadline: u64,
    pub flush_full: u64,
    pub flush_external: u64,
    pub flush_eviction: u64,
    /// Destination was already mapped (map-table hit).
    pub map_hits: u64,
    /// New destination bound to a free bucket.
    pub renames: u64,
    /// New destination required evicting a live bucket.
    pub evictions: u64,
    /// Events refused because both bucket sides were occupied
    /// (ingest-pipeline stall cycles in hardware).
    pub rejected: u64,
}

impl ManagerStats {
    pub fn total_flushes(&self) -> u64 {
        self.flush_deadline + self.flush_full + self.flush_external + self.flush_eviction
    }
}

/// Result of [`BucketManager::insert`].
#[derive(Clone, Debug)]
pub struct InsertResult {
    /// Flush batches provoked by this insert (eviction and/or Full).
    pub batches: Vec<FlushBatch>,
    /// Whether the event was accepted. `false` models hardware
    /// backpressure: both the accumulation and drain side of the target
    /// bucket are occupied (or no bucket could be reclaimed) — the ingest
    /// pipeline must stall and retry after a drain completes.
    pub accepted: bool,
}

/// Sentinel for "destination not mapped".
const UNMAPPED: u32 = u32::MAX;

/// The bucket manager (Fig. 2c): map table + free list + arbiter.
#[derive(Clone, Debug)]
pub struct BucketManager {
    cfg: ManagerConfig,
    buckets: Vec<Bucket>,
    /// Map table: 16-bit destination id → physical bucket index. A
    /// direct-indexed 2^16-entry table — the software analog of the
    /// hardware CAM, and ~4× faster on the ingest hot path than a hash
    /// map (see PERF.md §Methodology).
    map: Vec<u32>,
    /// Number of live destinations (mapped entries).
    live: usize,
    /// Free-bucket list (LIFO keeps hot buckets hot).
    free: Vec<usize>,
    /// Round-robin cursor for [`EvictionPolicy::RoundRobin`].
    rr_cursor: usize,
    pub stats: ManagerStats,
}

impl BucketManager {
    pub fn new(cfg: ManagerConfig) -> Self {
        assert!(cfg.n_buckets >= 1, "need at least one bucket");
        BucketManager {
            cfg,
            buckets: (0..cfg.n_buckets).map(|_| Bucket::new(cfg.bucket)).collect(),
            map: vec![UNMAPPED; 1 << 16],
            live: 0,
            free: (0..cfg.n_buckets).rev().collect(),
            rr_cursor: 0,
            stats: ManagerStats::default(),
        }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn free_buckets(&self) -> usize {
        self.free.len()
    }

    pub fn live_destinations(&self) -> usize {
        self.live
    }

    /// Total events currently accumulated across all buckets.
    pub fn buffered_events(&self) -> usize {
        self.buckets.iter().map(|b| b.fill_level()).sum()
    }

    pub fn bucket(&self, idx: usize) -> &Bucket {
        &self.buckets[idx]
    }

    /// Insert one routed event for `dest`. The result carries the flushes
    /// this insert provoked — at most one eviction batch (renaming
    /// pressure) and at most one Full batch (bucket reached capacity), in
    /// that order — plus whether the event was accepted at all (hardware
    /// backpressure when both bucket sides are occupied).
    pub fn insert(&mut self, dest: EndpointAddr, ev: RoutedEvent) -> InsertResult {
        let mut out = Vec::new();
        let key = dest.as_u16() as usize;
        let idx = match self.map[key] {
            idx if idx != UNMAPPED => {
                self.stats.map_hits += 1;
                idx as usize
            }
            _ => {
                // destination is new to the set of buckets
                let idx = if let Some(idx) = self.free.pop() {
                    self.stats.renames += 1;
                    idx
                } else {
                    // no free bucket: flush the "next appropriate one"
                    let Some(victim) = self.choose_victim() else {
                        // every bound bucket is draining with a non-empty
                        // accumulation side — nothing can be reclaimed
                        self.stats.rejected += 1;
                        return InsertResult {
                            batches: out,
                            accepted: false,
                        };
                    };
                    self.stats.evictions += 1;
                    if let Some(batch) = self.flush_index(victim, FlushReason::Eviction) {
                        out.push(batch);
                    }
                    // the victim's accumulation side is now empty (it was
                    // either flushed just now or already empty); release
                    // the old binding — a still-running drain keeps its
                    // own copy of the batch and finishes independently.
                    let old = self.buckets[victim]
                        .dest()
                        .expect("victim bucket had no destination");
                    self.map[old.as_u16() as usize] = UNMAPPED;
                    self.live -= 1;
                    self.buckets[victim].unbind();
                    idx_assert_free(&self.buckets[victim]);
                    victim
                };
                self.buckets[idx].bind(dest);
                self.map[key] = idx as u32;
                self.live += 1;
                idx
            }
        };
        // Non-concurrent ablation: a draining bucket cannot aggregate.
        if !self.cfg.bucket.concurrent && self.buckets[idx].is_draining() {
            self.stats.rejected += 1;
            return InsertResult {
                batches: out,
                accepted: false,
            };
        }
        // The bucket may be at capacity while its drain side is still busy
        // (burst into one destination): try to flush the accumulation side;
        // if the drain side is occupied too, the ingest pipeline stalls.
        if self.buckets[idx].fill_level() >= self.cfg.bucket.capacity {
            match self.flush_index(idx, FlushReason::Full) {
                Some(batch) => out.push(batch),
                None => {
                    self.stats.rejected += 1;
                    return InsertResult {
                        batches: out,
                        accepted: false,
                    };
                }
            }
        }
        self.stats.events_in += 1;
        match self.buckets[idx].insert(ev) {
            InsertOutcome::Stored => {}
            InsertOutcome::NowFull => {
                // cut the batch immediately if the drain side is free; if
                // not, the Full condition re-fires on the next insert
                if let Some(batch) = self.flush_index(idx, FlushReason::Full) {
                    out.push(batch);
                }
            }
        }
        InsertResult {
            batches: out,
            accepted: true,
        }
    }

    /// Scan for deadline-due buckets at systime `now` (the arbiter's
    /// periodic urgency check). Returns all due batches, most urgent first.
    pub fn poll_deadlines(&mut self, now_systime: u16) -> Vec<FlushBatch> {
        let mut due: Vec<usize> = (0..self.buckets.len())
            .filter(|&i| !self.buckets[i].is_draining() && self.buckets[i].deadline_due(now_systime))
            .collect();
        due.sort_by(|&a, &b| {
            let da = self.buckets[a].min_deadline();
            let db = self.buckets[b].min_deadline();
            if da == db {
                std::cmp::Ordering::Equal
            } else if ts_before_eq(da, db) {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Greater
            }
        });
        due.into_iter()
            .filter_map(|i| self.flush_index(i, FlushReason::Deadline))
            .collect()
    }

    /// Earliest systime at which any bucket's deadline condition fires
    /// (for event-driven scheduling of the next scan).
    pub fn next_deadline_fire(&self) -> Option<u16> {
        let mut best: Option<u16> = None;
        for b in &self.buckets {
            if b.is_draining() {
                continue;
            }
            if let Some(t) = b.deadline_fire_at() {
                best = Some(match best {
                    None => t,
                    Some(cur) if ts_before_eq(t, cur) => t,
                    Some(cur) => cur,
                });
            }
        }
        best
    }

    /// Flush every non-empty bucket (experiment barrier / shutdown).
    pub fn flush_all(&mut self) -> Vec<FlushBatch> {
        (0..self.buckets.len())
            .filter_map(|i| self.flush_index(i, FlushReason::External))
            .collect()
    }

    /// The egress serializer finished one batch for `dest`'s bucket (or the
    /// bucket that *was* bound to dest when the batch was cut — identified
    /// by index for robustness against rebinding).
    pub fn drain_complete(&mut self, idx: usize) {
        self.buckets[idx].drain_complete();
    }

    /// Index of the bucket currently mapped to `dest`.
    pub fn index_of(&self, dest: EndpointAddr) -> Option<usize> {
        match self.map[dest.as_u16() as usize] {
            UNMAPPED => None,
            idx => Some(idx as usize),
        }
    }

    fn flush_index(&mut self, idx: usize, reason: FlushReason) -> Option<FlushBatch> {
        let mut batch = self.buckets[idx].trigger_flush(reason)?;
        batch.bucket_idx = idx;
        match reason {
            FlushReason::Deadline => self.stats.flush_deadline += 1,
            FlushReason::Full => self.stats.flush_full += 1,
            FlushReason::External => self.stats.flush_external += 1,
            FlushReason::Eviction => self.stats.flush_eviction += 1,
        }
        Some(batch)
    }

    /// Pick the eviction victim among bound buckets ("the next appropriate
    /// one", §3.1). A bucket qualifies if its accumulation side can be
    /// cleared right away: either it is empty, or the drain side is free so
    /// a flush can be cut. Returns `None` when nothing can be reclaimed
    /// (all buckets mid-drain with pending accumulation) — backpressure.
    fn choose_victim(&mut self) -> Option<usize> {
        // allocation-free single pass (this sits on the ingest hot path
        // whenever renaming pressure is high — see PERF.md §Methodology)
        fn eligible(b: &Bucket) -> bool {
            b.dest().is_some() && (b.is_empty() || !b.is_draining())
        }
        let candidates = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| eligible(b));
        match self.cfg.eviction {
            EvictionPolicy::MostUrgent => candidates
                .min_by(|(_, ba), (_, bb)| match (ba.is_empty(), bb.is_empty()) {
                    // empty buckets are ideal victims (nothing to flush)
                    (true, false) => std::cmp::Ordering::Less,
                    (false, true) => std::cmp::Ordering::Greater,
                    (true, true) => std::cmp::Ordering::Equal,
                    (false, false) => {
                        if ba.min_deadline() == bb.min_deadline() {
                            std::cmp::Ordering::Equal
                        } else if ts_before_eq(ba.min_deadline(), bb.min_deadline()) {
                            std::cmp::Ordering::Less
                        } else {
                            std::cmp::Ordering::Greater
                        }
                    }
                })
                .map(|(i, _)| i),
            EvictionPolicy::Fullest => candidates
                .max_by_key(|(_, b)| b.fill_level())
                .map(|(i, _)| i),
            EvictionPolicy::Oldest => candidates
                .min_by_key(|(_, b)| {
                    if b.is_empty() {
                        Time::ZERO
                    } else {
                        b.oldest_ingress()
                    }
                })
                .map(|(i, _)| i),
            EvictionPolicy::RoundRobin => {
                self.rr_cursor = (self.rr_cursor + 1) % self.buckets.len();
                let cursor = self.rr_cursor;
                let mut first = None;
                let mut from_cursor = None;
                for (i, b) in self.buckets.iter().enumerate() {
                    if !eligible(b) {
                        continue;
                    }
                    if first.is_none() {
                        first = Some(i);
                    }
                    if i >= cursor {
                        from_cursor = Some(i);
                        break;
                    }
                }
                from_cursor.or(first)
            }
        }
    }
}

fn idx_assert_free(b: &Bucket) {
    debug_assert!(b.dest().is_none());
    debug_assert!(b.is_empty());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::NodeAddr;

    fn mgr(n_buckets: usize, capacity: usize, margin: u16) -> BucketManager {
        BucketManager::new(ManagerConfig {
            n_buckets,
            bucket: BucketConfig {
                capacity,
                deadline_margin: margin,
                concurrent: true,
            },
            eviction: EvictionPolicy::MostUrgent,
        })
    }

    fn d(n: u16) -> EndpointAddr {
        EndpointAddr::new(NodeAddr(n), 0)
    }

    fn ev(ts: u16) -> RoutedEvent {
        RoutedEvent::new(7, ts, Time::from_ns(5))
    }

    #[test]
    fn map_table_routes_same_destination_to_same_bucket() {
        let mut m = mgr(4, 124, 100);
        assert!(m.insert(d(1), ev(10)).batches.is_empty());
        assert!(m.insert(d(1), ev(11)).batches.is_empty());
        assert!(m.insert(d(2), ev(12)).batches.is_empty());
        assert_eq!(m.live_destinations(), 2);
        assert_eq!(m.free_buckets(), 2);
        assert_eq!(m.stats.map_hits, 1);
        assert_eq!(m.stats.renames, 2);
        let idx1 = m.index_of(d(1)).unwrap();
        assert_eq!(m.bucket(idx1).fill_level(), 2);
    }

    #[test]
    fn full_bucket_flushes() {
        let mut m = mgr(2, 3, 100);
        assert!(m.insert(d(5), ev(1)).batches.is_empty());
        assert!(m.insert(d(5), ev(2)).batches.is_empty());
        let batches = m.insert(d(5), ev(3)).batches;
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].reason, FlushReason::Full);
        assert_eq!(batches[0].events.len(), 3);
        assert_eq!(m.stats.flush_full, 1);
    }

    #[test]
    fn eviction_when_no_free_bucket() {
        let mut m = mgr(2, 124, 100);
        m.insert(d(1), ev(500)); // bucket 0 (less urgent)
        m.insert(d(2), ev(100)); // bucket 1 (most urgent)
        let batches = m.insert(d(3), ev(50)).batches;
        // most-urgent policy evicts d(2)'s bucket
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].reason, FlushReason::Eviction);
        assert_eq!(batches[0].dest, d(2));
        assert_eq!(m.stats.evictions, 1);
        assert!(m.index_of(d(2)).is_none());
        assert!(m.index_of(d(3)).is_some());
        assert!(m.index_of(d(1)).is_some());
        // d(3)'s event landed
        let idx = m.index_of(d(3)).unwrap();
        assert_eq!(m.bucket(idx).fill_level(), 1);
    }

    #[test]
    fn no_event_lost_under_heavy_renaming() {
        // more destinations than buckets: every event must end up in
        // exactly one flush batch
        let mut m = mgr(4, 16, 100);
        let mut collected = 0usize;
        let n_events = 1000;
        let mut accepted = 0usize;
        for i in 0..n_events {
            let dst = d((i % 37) as u16);
            let r = m.insert(dst, ev((i % 0x7FFF) as u16));
            if r.accepted {
                accepted += 1;
            }
            for b in r.batches {
                collected += b.events.len();
                // drain completes immediately in this timing-free test
                m.drain_complete(b.bucket_idx);
            }
        }
        for b in m.flush_all() {
            collected += b.events.len();
        }
        assert_eq!(accepted, n_events, "no rejection expected: drains complete instantly");
        assert_eq!(collected, n_events);
        assert_eq!(m.stats.events_in as usize, n_events);
    }

    #[test]
    fn deadline_poll_flushes_due_buckets_in_urgency_order() {
        let mut m = mgr(8, 124, 100);
        m.insert(d(1), ev(1000));
        m.insert(d(2), ev(500));
        m.insert(d(3), ev(5000));
        let batches = m.poll_deadlines(950);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].dest, d(2)); // 500 before 1000
        assert_eq!(batches[1].dest, d(1));
        assert_eq!(m.stats.flush_deadline, 2);
        // d(3) still buffered
        assert_eq!(m.buffered_events(), 1);
    }

    #[test]
    fn next_deadline_fire_is_earliest() {
        let mut m = mgr(8, 124, 100);
        assert_eq!(m.next_deadline_fire(), None);
        m.insert(d(1), ev(1000));
        m.insert(d(2), ev(700));
        assert_eq!(m.next_deadline_fire(), Some(600));
    }

    #[test]
    fn flush_all_drains_everything() {
        let mut m = mgr(8, 124, 100);
        for i in 0..5 {
            m.insert(d(i), ev(i * 10));
        }
        let batches = m.flush_all();
        assert_eq!(batches.len(), 5);
        assert_eq!(m.buffered_events(), 0);
        assert_eq!(m.stats.flush_external, 5);
    }

    #[test]
    fn eviction_policies_pick_expected_victims() {
        // Fullest
        let mut m = BucketManager::new(ManagerConfig {
            n_buckets: 2,
            bucket: BucketConfig {
                capacity: 124,
                deadline_margin: 10,
                concurrent: true,
            },
            eviction: EvictionPolicy::Fullest,
        });
        m.insert(d(1), ev(100));
        m.insert(d(2), ev(50));
        m.insert(d(2), ev(51));
        let b = m.insert(d(3), ev(1)).batches;
        assert_eq!(b[0].dest, d(2), "fullest policy evicts the 2-event bucket");

        // Oldest
        let mut m = BucketManager::new(ManagerConfig {
            n_buckets: 2,
            bucket: BucketConfig {
                capacity: 124,
                deadline_margin: 10,
                concurrent: true,
            },
            eviction: EvictionPolicy::Oldest,
        });
        m.insert(d(1), RoutedEvent::new(1, 100, Time::from_ns(10)));
        m.insert(d(2), RoutedEvent::new(1, 50, Time::from_ns(999)));
        let b = m.insert(d(3), ev(1)).batches;
        assert_eq!(b[0].dest, d(1), "oldest policy evicts the earliest-ingress bucket");
    }

    #[test]
    fn empty_bound_bucket_is_preferred_victim() {
        let mut m = mgr(2, 4, 100);
        // fill both buckets, then flush one fully so it is bound but empty
        m.insert(d(1), ev(5000));
        m.insert(d(2), ev(6000));
        let idx2 = m.index_of(d(2)).unwrap();
        let batch = {
            let batches = m.poll_deadlines(0); // nothing due (slack ≫ margin)
            assert!(batches.is_empty());
            // force-flush d(2) externally
            let idx = idx2;
            let b = m.buckets[idx].trigger_flush(FlushReason::External).unwrap();
            m.buckets[idx].drain_complete();
            b
        };
        assert_eq!(batch.dest, d(2));
        // new destination should evict the empty d(2) bucket, producing no
        // eviction batch
        let batches = m.insert(d(3), ev(30)).batches;
        assert!(batches.is_empty(), "evicting an empty bucket flushes nothing");
        assert!(m.index_of(d(2)).is_none());
        assert_eq!(m.bucket(m.index_of(d(3)).unwrap()).fill_level(), 1);
        // d(1) untouched
        assert_eq!(m.bucket(m.index_of(d(1)).unwrap()).fill_level(), 1);
    }

    #[test]
    fn burst_into_one_destination_backpressures_while_draining() {
        // capacity 4, drain never completes: the first Full flush occupies
        // the drain side; once the accumulation side fills again, further
        // inserts are rejected (ingest stall) — and nothing is lost.
        let mut m = mgr(2, 4, 100);
        let mut batches = Vec::new();
        let mut rejected = 0;
        for i in 0..12 {
            let r = m.insert(d(9), ev(i));
            if !r.accepted {
                rejected += 1;
            }
            batches.extend(r.batches);
        }
        assert!(rejected > 0, "expected ingest backpressure");
        assert_eq!(m.stats.rejected, rejected as u64);
        let flushed: usize = batches.iter().map(|b| b.events.len()).sum();
        let buffered = m.buffered_events();
        assert_eq!(
            flushed + buffered + rejected,
            12,
            "events lost in burst"
        );
        // after the drain completes, inserts flow again
        m.drain_complete(batches[0].bucket_idx);
        assert!(m.insert(d(9), ev(99)).accepted);
    }

    #[test]
    fn rejected_events_resume_after_drain_complete() {
        let mut m = mgr(1, 2, 100);
        assert!(m.insert(d(1), ev(1)).accepted);
        let r = m.insert(d(1), ev(2));
        assert!(r.accepted);
        assert_eq!(r.batches.len(), 1); // Full flush, drain busy now
        assert!(m.insert(d(1), ev(3)).accepted); // accum has room
        assert!(m.insert(d(1), ev(4)).accepted); // accum full again...
        let r = m.insert(d(1), ev(5));
        assert!(!r.accepted, "both sides occupied: reject");
        // also: new destination with a single draining+full bucket rejects
        let r2 = m.insert(d(2), ev(6));
        assert!(!r2.accepted, "no reclaimable bucket: reject");
        m.drain_complete(0);
        let r = m.insert(d(1), ev(5));
        assert!(r.accepted);
        assert_eq!(r.batches.len(), 1, "pending Full condition fires on resume");
    }
}
