//! The BrainScaleS communication-FPGA model (paper §3): spike events from
//! 8 HICANN chips, TX/RX lookup tables, event-aggregation buckets with
//! dual counters and concurrent flush, the bucket manager (map table +
//! free-bucket list + urgency arbiter), and the complete FPGA actor —
//! the paper's core contribution.

pub mod bucket;
pub mod event;
#[allow(clippy::module_inception)]
pub mod fpga;
pub mod hicann;
pub mod lookup;
pub mod manager;

pub use bucket::{Bucket, BucketConfig, FlushBatch, FlushReason};
pub use event::{RoutedEvent, SpikeEvent};
pub use fpga::{Fpga, FpgaConfig, FpgaStats};
pub use hicann::{HicannLinkConfig, PlaybackStats, HICANNS_PER_FPGA};
pub use lookup::{EndpointAddr, RxEntry, RxLookup, TxEntry, TxLookup};
pub use manager::{BucketManager, EvictionPolicy, InsertResult, ManagerConfig, ManagerStats};
