//! Spike event representation (paper §3, Fig. 2b).
//!
//! Events arriving from HICANN chips carry a **12-bit source neuron pulse
//! address** and a **15-bit timestamp** stating an *arrival deadline* in
//! systemtime units. On the Extoll wire the FPGA transmits 30-bit events —
//! here modeled as a 15-bit GUID (the network-global source identifier
//! produced by the TX lookup table) plus the 15-bit deadline — packed in
//! groups of four into 16-byte network cells, so a maximum-size 496-byte
//! packet carries 124 events, exactly as in the paper.

use crate::sim::Time;

/// Bits of a raw HICANN pulse address.
pub const PULSE_ADDR_BITS: u32 = 12;
/// Bits of the arrival-deadline timestamp.
pub const TIMESTAMP_BITS: u32 = 15;
/// Mask for 15-bit timestamp arithmetic.
pub const TS_MASK: u16 = (1 << TIMESTAMP_BITS) - 1;
/// Half of the timestamp window, for wrap-around comparisons.
pub const TS_HALF: u16 = 1 << (TIMESTAMP_BITS - 1);
/// Bits of one event on the Extoll wire (paper: "30 bit events").
pub const WIRE_EVENT_BITS: u32 = 30;
/// Events per 16-byte network cell ("deserialised to groups of four").
pub const EVENTS_PER_CELL: usize = 4;
/// Bytes of one network cell (4 × 30 bit events + 8 pad bits).
pub const CELL_BYTES: u32 = 16;

/// One systemtime unit, chosen as one 210 MHz FPGA clock cycle.
///
/// The HICANN system time and the FPGA communication clock are mesochronous
/// in the real system; the paper states deadlines in "systemtime units"
/// without fixing the unit, so we take the FPGA clock as the reference —
/// the 15-bit window then spans ≈156 µs, comfortably above realistic
/// inter-wafer transit times.
pub fn systime_unit() -> Time {
    Time::from_fpga_cycles(1)
}

/// Convert an absolute simulation time to a (wrapping) 15-bit systime stamp.
/// Rounds to the nearest cycle so `from_fpga_cycles` round-trips exactly.
pub fn systime_of(t: Time) -> u16 {
    let cycles = ((t.ps() as u128 * 21 + 50_000) / 100_000) as u64;
    (cycles & TS_MASK as u64) as u16
}

/// `true` if deadline `a` is earlier than or equal to `b` in the wrapped
/// 15-bit systime window (sequence-number comparison).
#[inline]
pub fn ts_before_eq(a: u16, b: u16) -> bool {
    ((b.wrapping_sub(a)) & TS_MASK) < TS_HALF
}

/// Wrapped distance from `a` to `b` (how far b lies ahead of a).
#[inline]
pub fn ts_delta(a: u16, b: u16) -> u16 {
    b.wrapping_sub(a) & TS_MASK
}

/// A spike event as emitted by a HICANN chip towards the FPGA.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpikeEvent {
    /// 12-bit source neuron pulse address (HICANN-local).
    pub pulse_addr: u16,
    /// 15-bit arrival deadline, systemtime units, wraps.
    pub timestamp: u16,
    /// Which of the 8 HICANN links the event arrived on (0..8).
    pub hicann: u8,
}

impl SpikeEvent {
    pub fn new(hicann: u8, pulse_addr: u16, timestamp: u16) -> Self {
        debug_assert!(hicann < 8);
        debug_assert!(pulse_addr < (1 << PULSE_ADDR_BITS));
        debug_assert!(timestamp <= TS_MASK);
        SpikeEvent {
            pulse_addr: pulse_addr & 0x0FFF,
            timestamp: timestamp & TS_MASK,
            hicann,
        }
    }

    /// Pack into the 27 meaningful bits (for codec tests / wire modeling).
    pub fn pack(&self) -> u32 {
        ((self.pulse_addr as u32) << TIMESTAMP_BITS) | self.timestamp as u32
    }

    pub fn unpack(hicann: u8, bits: u32) -> Self {
        SpikeEvent {
            pulse_addr: ((bits >> TIMESTAMP_BITS) & 0x0FFF) as u16,
            timestamp: (bits & TS_MASK as u32) as u16,
            hicann,
        }
    }
}

/// A routed event as carried on the Extoll wire: the TX lookup table has
/// replaced the HICANN-local context by a network-global GUID.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RoutedEvent {
    /// 15-bit Global Unique Identifier of the source context; the RX
    /// lookup table maps it to a multicast mask + local pulse address.
    pub guid: u16,
    /// 15-bit arrival deadline (propagated unchanged).
    pub timestamp: u16,
    /// Simulation time at which the event entered the source FPGA
    /// (metadata for latency accounting, not on the wire).
    pub ingress: Time,
}

impl RoutedEvent {
    pub fn new(guid: u16, timestamp: u16, ingress: Time) -> Self {
        debug_assert!(guid < (1 << 15));
        RoutedEvent {
            guid: guid & 0x7FFF,
            timestamp: timestamp & TS_MASK,
            ingress,
        }
    }

    /// 30-bit wire image (15-bit GUID + 15-bit deadline).
    pub fn wire_bits(&self) -> u32 {
        ((self.guid as u32) << 15) | self.timestamp as u32
    }
}

/// Payload bytes consumed by `n` events, in whole 16-byte cells.
pub fn payload_bytes_for_events(n: usize) -> u32 {
    (n.div_ceil(EVENTS_PER_CELL) as u32) * CELL_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        for (h, a, t) in [(0u8, 0u16, 0u16), (3, 0xFFF, 0x7FFF), (7, 0x123, 0x4567 & TS_MASK)] {
            let e = SpikeEvent::new(h, a, t);
            let e2 = SpikeEvent::unpack(h, e.pack());
            assert_eq!(e, e2);
        }
    }

    #[test]
    fn wire_bits_fit_30() {
        let r = RoutedEvent::new(0x7FFF, 0x7FFF, Time::ZERO);
        assert!(r.wire_bits() < (1 << WIRE_EVENT_BITS));
    }

    #[test]
    fn ts_wraparound_compare() {
        assert!(ts_before_eq(5, 10));
        assert!(!ts_before_eq(10, 5));
        assert!(ts_before_eq(7, 7));
        // wrap: 0x7FF0 is before 0x0010
        assert!(ts_before_eq(0x7FF0, 0x0010));
        assert!(!ts_before_eq(0x0010, 0x7FF0));
    }

    #[test]
    fn ts_delta_wraps() {
        assert_eq!(ts_delta(0x7FFE, 0x0002), 4);
        assert_eq!(ts_delta(10, 15), 5);
        assert_eq!(ts_delta(15, 15), 0);
    }

    #[test]
    fn cell_math_matches_paper() {
        // 124 events -> 31 cells -> 496 bytes: the paper's maximum.
        assert_eq!(payload_bytes_for_events(124), 496);
        assert_eq!(payload_bytes_for_events(1), 16);
        assert_eq!(payload_bytes_for_events(4), 16);
        assert_eq!(payload_bytes_for_events(5), 32);
        assert_eq!(payload_bytes_for_events(0), 0);
    }

    #[test]
    fn systime_of_wraps() {
        let t = Time::from_fpga_cycles(0x8000 + 5); // one full window + 5
        assert_eq!(systime_of(t), 5);
    }

    #[test]
    fn systime_window_exceeds_100us() {
        let window = systime_unit() * (1 << TIMESTAMP_BITS);
        assert!(window > Time::from_us(100), "window = {window}");
    }
}
