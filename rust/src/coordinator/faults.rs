//! Degraded-fabric scenarios: the fault-injection counterparts of the
//! `traffic` scenario (`docs/ARCHITECTURE.md`, "Fault model & adaptive
//! routing").
//!
//! - [`FaultSweepScenario`] (`fault_sweep`) — the traffic workload over a
//!   fabric with injected faults, reporting deliverability (delivered /
//!   injected spike events) and re-route hop inflation (mean hops over
//!   mean fault-free shortest-path hops). Swept over `fault=` specs it
//!   produces the degraded-fabric curves: deliverability is exactly 1.0
//!   at zero faults and monotone non-increasing in the failed-link
//!   fraction (gated by `scripts/validate_bench.py`).
//! - [`ReliabilitySweepScenario`] (`reliability_sweep`) — the same
//!   degraded fabric with the link-level retransmission layer in play
//!   (`--set reliability=link`), reporting the recovery economics:
//!   CRC-detected losses, retransmissions, NACKs, timeouts, recovered
//!   events, residual loss past the retry budget, and the
//!   recovery-latency histogram. Swept over `reliability=off,link` it
//!   shows deliverability returning to 1.0 under loss at a measured
//!   latency/bandwidth cost (`docs/ARCHITECTURE.md` §6).
//! - [`LatencyDistScenario`] (`latency_dist`) — the same workload
//!   reporting full latency *distributions* as
//!   [`MetricKind::Histogram`](crate::util::report::MetricKind) metrics
//!   (bucketed counts + p50/p95/p99) instead of two scalar percentiles:
//!   end-to-end event latency and fabric transit latency.
//!
//! All three reuse [`TrafficScenario`]'s plan and cache family: the fault
//! model is an execute-time resource built from the experiment seed
//! (`run_fabric_experiment_with`), so a fault sweep shares one cached
//! plan across every point — and the plan RNG draw sequence is untouched,
//! keeping fault-free reports byte-identical to `traffic`.

use std::sync::Arc;

use anyhow::Result;

use crate::msg::Msg;
use crate::sim::Sim;
use crate::util::report::{MetricDecl, Report};
use crate::util::rng::Rng;
use crate::wafer::system::System;
use crate::workload::generators::GeneratorKind;

use super::config::ExperimentConfig;
use super::scenario::{downcast_prepared, CacheKey, Prepared, Scenario};
use super::traffic::{
    execute_fabric_plan, fabric_schema, plan_fabric, zipf_plan_key, FabricPlan, FabricScenario,
    TrafficScenario,
};

/// Declared metric schema of [`FaultSweepScenario`].
pub const FAULT_SWEEP_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::count("failed_cables", "cables"),
    MetricDecl::count("injected_events", "events"),
    MetricDecl::count("lost_packets", "packets"),
    MetricDecl::count("lost_events", "events"),
    MetricDecl::count("undeliverable_packets", "packets"),
    MetricDecl::count("undeliverable_events", "events"),
    MetricDecl::count("detour_hops", "hops"),
    MetricDecl::real("deliverability", "1"),
    MetricDecl::real("mean_hops", "hops"),
    MetricDecl::real("hop_inflation", "1"),
];

/// Declared metric schema of [`ReliabilitySweepScenario`].
pub const RELIABILITY_SWEEP_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::count("failed_cables", "cables"),
    MetricDecl::count("injected_events", "events"),
    MetricDecl::count("crc_failures", "packets"),
    MetricDecl::count("retransmissions", "packets"),
    MetricDecl::count("nacks", "frames"),
    MetricDecl::count("timeouts", "timeouts"),
    MetricDecl::count("recovered_packets", "packets"),
    MetricDecl::count("recovered_events", "events"),
    MetricDecl::count("duplicate_packets", "packets"),
    MetricDecl::count("undeliverable_events", "events"),
    MetricDecl::count("residual_loss_packets", "packets"),
    MetricDecl::count("residual_loss_events", "events"),
    MetricDecl::real("deliverability", "1"),
    MetricDecl::histogram("recovery_hist", "ps"),
];

/// Declared metric schema of [`LatencyDistScenario`].
pub const LATENCY_DIST_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::real("latency_p95", "ns"),
    MetricDecl::histogram("latency_hist", "ps"),
    MetricDecl::histogram("transit_hist", "ps"),
];

// ---- fault_sweep ---------------------------------------------------------

/// The `traffic` workload over a degraded fabric: deliverability and
/// re-route hop inflation versus the configured fault set.
pub struct FaultSweepScenario;

impl FabricScenario for FaultSweepScenario {
    fn plan(&self, sys: &System, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<FabricPlan> {
        TrafficScenario.plan(sys, cfg, rng)
    }

    fn generator(&self, cfg: &ExperimentConfig) -> GeneratorKind {
        cfg.workload.generator
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let t = sys.fault_totals(sim);
        let failed = sys.fault.as_ref().map_or(0, |m| m.failed_cables());
        report.push_unit("failed_cables", failed as u64, "cables");
        report.push_unit("injected_events", t.injected_events, "events");
        report.push_unit("lost_packets", t.lost_packets, "packets");
        report.push_unit("lost_events", t.lost_events, "events");
        report.push_unit("undeliverable_packets", t.undeliverable_packets, "packets");
        report.push_unit("undeliverable_events", t.undeliverable_events, "events");
        report.push_unit("detour_hops", t.detour_hops, "hops");
        report.push_unit("deliverability", t.deliverability(), "1");
        let mean_hops = if t.hops.is_empty() { 0.0 } else { t.hops.mean() };
        report.push_unit("mean_hops", mean_hops, "hops");
        report.push_unit("hop_inflation", t.hop_inflation(), "1");
    }
}

impl Scenario for FaultSweepScenario {
    fn name(&self) -> &'static str {
        "fault_sweep"
    }

    fn about(&self) -> &'static str {
        "traffic workload on a degraded fabric: deliverability + hop inflation vs faults"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        FAULT_SWEEP_METRICS
    }

    /// Shares the traffic plan family: the fault model is built at
    /// execute time from the seed, so sweeping `fault=` reuses one plan.
    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), FAULT_SWEEP_METRICS, plan, cfg)
    }
}

// ---- reliability_sweep ---------------------------------------------------

/// The `traffic` workload over a degraded fabric with the link-level
/// reliability protocol under test: what did recovery cost, and what
/// slipped past the retry budget?
pub struct ReliabilitySweepScenario;

impl FabricScenario for ReliabilitySweepScenario {
    fn plan(&self, sys: &System, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<FabricPlan> {
        TrafficScenario.plan(sys, cfg, rng)
    }

    fn generator(&self, cfg: &ExperimentConfig) -> GeneratorKind {
        cfg.workload.generator
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let t = sys.fault_totals(sim);
        let failed = sys.fault.as_ref().map_or(0, |m| m.failed_cables());
        report.push_unit("failed_cables", failed as u64, "cables");
        report.push_unit("injected_events", t.injected_events, "events");
        // with reliability=link a CRC failure is a *detected* loss — it is
        // counted here whether or not a retransmission later recovers it;
        // with reliability=off it is simply a dropped packet
        report.push_unit("crc_failures", t.lost_packets, "packets");
        report.push_unit("retransmissions", t.retransmissions, "packets");
        report.push_unit("nacks", t.nacks, "frames");
        report.push_unit("timeouts", t.timeouts, "timeouts");
        report.push_unit("recovered_packets", t.recovered_packets, "packets");
        report.push_unit("recovered_events", t.recovered_events, "events");
        report.push_unit("duplicate_packets", t.duplicate_packets, "packets");
        report.push_unit("undeliverable_events", t.undeliverable_events, "events");
        report.push_unit("residual_loss_packets", t.residual_loss_packets, "packets");
        report.push_unit("residual_loss_events", t.residual_loss_events, "events");
        report.push_unit("deliverability", t.deliverability(), "1");
        report.push_unit("recovery_hist", &t.recovery_ps, "ps");
    }
}

impl Scenario for ReliabilitySweepScenario {
    fn name(&self) -> &'static str {
        "reliability_sweep"
    }

    fn about(&self) -> &'static str {
        "degraded fabric with link-level retransmission: recovery cost vs residual loss"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        RELIABILITY_SWEEP_METRICS
    }

    /// Shares the traffic plan family: both the fault model and the
    /// reliability layer are execute-time state, so sweeping
    /// `reliability=off,link` (or `fault=`) reuses one cached plan.
    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), RELIABILITY_SWEEP_METRICS, plan, cfg)
    }
}

// ---- latency_dist --------------------------------------------------------

/// The `traffic` workload reporting latency *distributions*: bucketed
/// histograms with p50/p95/p99 summaries, for the tail analysis two
/// scalar percentiles cannot support (and the natural companion to
/// `fault_sweep` — jitter and detours move the tail first).
pub struct LatencyDistScenario;

impl FabricScenario for LatencyDistScenario {
    fn plan(&self, sys: &System, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<FabricPlan> {
        TrafficScenario.plan(sys, cfg, rng)
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let latency = sys.latency_histogram(sim);
        let transit = sys.fabric.transit_histogram(sim);
        report.push_unit("latency_p95", latency.quantile(0.95) as f64 / 1e3, "ns");
        report.push_unit("latency_hist", &latency, "ps");
        report.push_unit("transit_hist", &transit, "ps");
    }
}

impl Scenario for LatencyDistScenario {
    fn name(&self) -> &'static str {
        "latency_dist"
    }

    fn about(&self) -> &'static str {
        "traffic workload with full latency histograms (p50/p95/p99 + buckets)"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        LATENCY_DIST_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), LATENCY_DIST_METRICS, plan, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::fault::FaultConfig;
    use crate::sim::Time;
    use crate::util::report::{MetricKind, Value};
    use crate::wafer::system::SystemConfig;

    fn small(fault: FaultConfig) -> ExperimentConfig {
        let mut cfg = ExperimentConfig {
            system: SystemConfig {
                n_wafers: 2,
                torus: TorusSpec::new(2, 2, 1),
                fpgas_per_wafer: 4,
                concentrators_per_wafer: 2,
                ..SystemConfig::default()
            },
            fault,
            ..ExperimentConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(500);
        cfg
    }

    #[test]
    fn fault_sweep_is_perfect_on_a_healthy_fabric() {
        let cfg = small(FaultConfig::default());
        let r = FaultSweepScenario.run(&cfg).unwrap();
        assert_eq!(r.get_f64("deliverability"), Some(1.0));
        assert_eq!(r.get_f64("hop_inflation"), Some(1.0));
        assert_eq!(r.get_count("failed_cables"), Some(0));
        assert_eq!(r.get_count("lost_packets"), Some(0));
        assert_eq!(r.get_count("undeliverable_packets"), Some(0));
        assert_eq!(r.get_count("detour_hops"), Some(0));
    }

    #[test]
    fn fault_sweep_loses_events_under_loss() {
        let cfg = small(FaultConfig {
            loss: 0.05,
            ..FaultConfig::default()
        });
        let r = FaultSweepScenario.run(&cfg).unwrap();
        let deliv = r.get_f64("deliverability").unwrap();
        assert!(deliv < 1.0, "5% loss must lose something, got {deliv}");
        assert!(r.get_count("lost_packets").unwrap() > 0);
    }

    #[test]
    fn reliability_link_restores_deliverability_under_loss() {
        use crate::extoll::link::Reliability;
        let mut cfg = small(FaultConfig {
            loss: 0.05,
            ..FaultConfig::default()
        });
        cfg.system.nic.reliability = Reliability::Link;
        let r = ReliabilitySweepScenario.run(&cfg).unwrap();
        // every CRC-dropped packet is recovered within the retry budget:
        // deliverability returns to exactly 1.0 with zero residual loss
        assert_eq!(r.get_f64("deliverability"), Some(1.0));
        assert_eq!(r.get_count("residual_loss_packets"), Some(0));
        assert_eq!(r.get_count("residual_loss_events"), Some(0));
        assert_eq!(r.get_count("undeliverable_events"), Some(0));
        // ... and the recovery machinery demonstrably did the work
        let crc = r.get_count("crc_failures").unwrap();
        assert!(crc > 0, "5% loss must trip CRC failures");
        assert!(r.get_count("retransmissions").unwrap() >= crc);
        assert!(r.get_count("nacks").unwrap() > 0);
        assert!(r.get_count("recovered_packets").unwrap() > 0);
        assert!(r.get_count("recovered_events").unwrap() > 0);
        match r.get("recovery_hist") {
            Some(Value::Hist(h)) => assert!(h.n > 0, "no recovery samples"),
            other => panic!("recovery_hist is not a histogram: {other:?}"),
        }
    }

    #[test]
    fn reliability_off_matches_fault_sweep_exactly() {
        // with the layer off, reliability_sweep is fault_sweep with a
        // different schema: the shared physics metrics agree exactly and
        // every recovery counter is zero
        let cfg = small(FaultConfig {
            loss: 0.05,
            ..FaultConfig::default()
        });
        let r = ReliabilitySweepScenario.run(&cfg).unwrap();
        let f = FaultSweepScenario.run(&cfg).unwrap();
        assert_eq!(r.get_f64("deliverability"), f.get_f64("deliverability"));
        assert!(r.get_f64("deliverability").unwrap() < 1.0);
        assert_eq!(r.get_count("crc_failures"), f.get_count("lost_packets"));
        assert_eq!(r.get_count("injected_events"), f.get_count("injected_events"));
        for zero in ["retransmissions", "nacks", "timeouts", "recovered_packets",
                     "duplicate_packets", "residual_loss_packets"] {
            assert_eq!(r.get_count(zero), Some(0), "{zero} without the layer");
        }
    }

    #[test]
    fn reliability_link_is_clean_on_a_healthy_fabric() {
        use crate::extoll::link::Reliability;
        let mut cfg = small(FaultConfig::default());
        cfg.system.nic.reliability = Reliability::Link;
        let r = ReliabilitySweepScenario.run(&cfg).unwrap();
        assert_eq!(r.get_f64("deliverability"), Some(1.0));
        assert_eq!(r.get_count("crc_failures"), Some(0));
        assert_eq!(r.get_count("retransmissions"), Some(0));
        assert_eq!(r.get_count("timeouts"), Some(0));
        assert_eq!(r.get_count("residual_loss_events"), Some(0));
    }

    #[test]
    fn latency_dist_reports_histograms() {
        let cfg = small(FaultConfig::default());
        let r = LatencyDistScenario.run(&cfg).unwrap();
        match r.get("latency_hist") {
            Some(Value::Hist(h)) => assert!(h.n > 0, "no latency samples"),
            other => panic!("latency_hist is not a histogram: {other:?}"),
        }
        assert!(r.get_f64("latency_p95").unwrap() > 0.0);
        let p50 = r.get_f64("latency_p50").unwrap();
        let p95 = r.get_f64("latency_p95").unwrap();
        let p99 = r.get_f64("latency_p99").unwrap();
        assert!(p50 <= p95 && p95 <= p99, "percentiles out of order");
    }

    #[test]
    fn schemas_declare_the_new_kinds() {
        assert!(FAULT_SWEEP_METRICS.iter().any(|d| d.name == "deliverability"));
        assert!(LATENCY_DIST_METRICS
            .iter()
            .any(|d| d.name == "latency_hist" && d.kind == MetricKind::Histogram));
        assert!(RELIABILITY_SWEEP_METRICS
            .iter()
            .any(|d| d.name == "deliverability"));
        assert!(RELIABILITY_SWEEP_METRICS
            .iter()
            .any(|d| d.name == "residual_loss_events"));
        assert!(RELIABILITY_SWEEP_METRICS
            .iter()
            .any(|d| d.name == "recovery_hist" && d.kind == MetricKind::Histogram));
    }
}
