//! Degraded-fabric scenarios: the fault-injection counterparts of the
//! `traffic` scenario (`docs/ARCHITECTURE.md`, "Fault model & adaptive
//! routing").
//!
//! - [`FaultSweepScenario`] (`fault_sweep`) — the traffic workload over a
//!   fabric with injected faults, reporting deliverability (delivered /
//!   injected spike events) and re-route hop inflation (mean hops over
//!   mean fault-free shortest-path hops). Swept over `fault=` specs it
//!   produces the degraded-fabric curves: deliverability is exactly 1.0
//!   at zero faults and monotone non-increasing in the failed-link
//!   fraction (gated by `scripts/validate_bench.py`).
//! - [`LatencyDistScenario`] (`latency_dist`) — the same workload
//!   reporting full latency *distributions* as
//!   [`MetricKind::Histogram`](crate::util::report::MetricKind) metrics
//!   (bucketed counts + p50/p95/p99) instead of two scalar percentiles:
//!   end-to-end event latency and fabric transit latency.
//!
//! Both reuse [`TrafficScenario`]'s plan and cache family: the fault
//! model is an execute-time resource built from the experiment seed
//! (`run_fabric_experiment_with`), so a fault sweep shares one cached
//! plan across every point — and the plan RNG draw sequence is untouched,
//! keeping fault-free reports byte-identical to `traffic`.

use std::sync::Arc;

use anyhow::Result;

use crate::msg::Msg;
use crate::sim::Sim;
use crate::util::report::{MetricDecl, Report};
use crate::util::rng::Rng;
use crate::wafer::system::System;
use crate::workload::generators::GeneratorKind;

use super::config::ExperimentConfig;
use super::scenario::{downcast_prepared, CacheKey, Prepared, Scenario};
use super::traffic::{
    execute_fabric_plan, fabric_schema, plan_fabric, zipf_plan_key, FabricPlan, FabricScenario,
    TrafficScenario,
};

/// Declared metric schema of [`FaultSweepScenario`].
pub const FAULT_SWEEP_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::count("failed_cables", "cables"),
    MetricDecl::count("injected_events", "events"),
    MetricDecl::count("lost_packets", "packets"),
    MetricDecl::count("lost_events", "events"),
    MetricDecl::count("undeliverable_packets", "packets"),
    MetricDecl::count("undeliverable_events", "events"),
    MetricDecl::count("detour_hops", "hops"),
    MetricDecl::real("deliverability", "1"),
    MetricDecl::real("mean_hops", "hops"),
    MetricDecl::real("hop_inflation", "1"),
];

/// Declared metric schema of [`LatencyDistScenario`].
pub const LATENCY_DIST_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::real("latency_p95", "ns"),
    MetricDecl::histogram("latency_hist", "ps"),
    MetricDecl::histogram("transit_hist", "ps"),
];

// ---- fault_sweep ---------------------------------------------------------

/// The `traffic` workload over a degraded fabric: deliverability and
/// re-route hop inflation versus the configured fault set.
pub struct FaultSweepScenario;

impl FabricScenario for FaultSweepScenario {
    fn plan(&self, sys: &System, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<FabricPlan> {
        TrafficScenario.plan(sys, cfg, rng)
    }

    fn generator(&self, cfg: &ExperimentConfig) -> GeneratorKind {
        cfg.workload.generator
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let t = sys.fault_totals(sim);
        let failed = sys.fault.as_ref().map_or(0, |m| m.failed_cables());
        report.push_unit("failed_cables", failed as u64, "cables");
        report.push_unit("injected_events", t.injected_events, "events");
        report.push_unit("lost_packets", t.lost_packets, "packets");
        report.push_unit("lost_events", t.lost_events, "events");
        report.push_unit("undeliverable_packets", t.undeliverable_packets, "packets");
        report.push_unit("undeliverable_events", t.undeliverable_events, "events");
        report.push_unit("detour_hops", t.detour_hops, "hops");
        report.push_unit("deliverability", t.deliverability(), "1");
        let mean_hops = if t.hops.is_empty() { 0.0 } else { t.hops.mean() };
        report.push_unit("mean_hops", mean_hops, "hops");
        report.push_unit("hop_inflation", t.hop_inflation(), "1");
    }
}

impl Scenario for FaultSweepScenario {
    fn name(&self) -> &'static str {
        "fault_sweep"
    }

    fn about(&self) -> &'static str {
        "traffic workload on a degraded fabric: deliverability + hop inflation vs faults"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        FAULT_SWEEP_METRICS
    }

    /// Shares the traffic plan family: the fault model is built at
    /// execute time from the seed, so sweeping `fault=` reuses one plan.
    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), FAULT_SWEEP_METRICS, plan, cfg)
    }
}

// ---- latency_dist --------------------------------------------------------

/// The `traffic` workload reporting latency *distributions*: bucketed
/// histograms with p50/p95/p99 summaries, for the tail analysis two
/// scalar percentiles cannot support (and the natural companion to
/// `fault_sweep` — jitter and detours move the tail first).
pub struct LatencyDistScenario;

impl FabricScenario for LatencyDistScenario {
    fn plan(&self, sys: &System, cfg: &ExperimentConfig, rng: &mut Rng) -> Result<FabricPlan> {
        TrafficScenario.plan(sys, cfg, rng)
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let latency = sys.latency_histogram(sim);
        let transit = sys.fabric.transit_histogram(sim);
        report.push_unit("latency_p95", latency.quantile(0.95) as f64 / 1e3, "ns");
        report.push_unit("latency_hist", &latency, "ps");
        report.push_unit("transit_hist", &transit, "ps");
    }
}

impl Scenario for LatencyDistScenario {
    fn name(&self) -> &'static str {
        "latency_dist"
    }

    fn about(&self) -> &'static str {
        "traffic workload with full latency histograms (p50/p95/p99 + buckets)"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        LATENCY_DIST_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), LATENCY_DIST_METRICS, plan, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::fault::FaultConfig;
    use crate::sim::Time;
    use crate::util::report::{MetricKind, Value};
    use crate::wafer::system::SystemConfig;

    fn small(fault: FaultConfig) -> ExperimentConfig {
        let mut cfg = ExperimentConfig {
            system: SystemConfig {
                n_wafers: 2,
                torus: TorusSpec::new(2, 2, 1),
                fpgas_per_wafer: 4,
                concentrators_per_wafer: 2,
                ..SystemConfig::default()
            },
            fault,
            ..ExperimentConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(500);
        cfg
    }

    #[test]
    fn fault_sweep_is_perfect_on_a_healthy_fabric() {
        let cfg = small(FaultConfig::default());
        let r = FaultSweepScenario.run(&cfg).unwrap();
        assert_eq!(r.get_f64("deliverability"), Some(1.0));
        assert_eq!(r.get_f64("hop_inflation"), Some(1.0));
        assert_eq!(r.get_count("failed_cables"), Some(0));
        assert_eq!(r.get_count("lost_packets"), Some(0));
        assert_eq!(r.get_count("undeliverable_packets"), Some(0));
        assert_eq!(r.get_count("detour_hops"), Some(0));
    }

    #[test]
    fn fault_sweep_loses_events_under_loss() {
        let cfg = small(FaultConfig {
            loss: 0.05,
            ..FaultConfig::default()
        });
        let r = FaultSweepScenario.run(&cfg).unwrap();
        let deliv = r.get_f64("deliverability").unwrap();
        assert!(deliv < 1.0, "5% loss must lose something, got {deliv}");
        assert!(r.get_count("lost_packets").unwrap() > 0);
    }

    #[test]
    fn latency_dist_reports_histograms() {
        let cfg = small(FaultConfig::default());
        let r = LatencyDistScenario.run(&cfg).unwrap();
        match r.get("latency_hist") {
            Some(Value::Hist(h)) => assert!(h.n > 0, "no latency samples"),
            other => panic!("latency_hist is not a histogram: {other:?}"),
        }
        assert!(r.get_f64("latency_p95").unwrap() > 0.0);
        let p50 = r.get_f64("latency_p50").unwrap();
        let p95 = r.get_f64("latency_p95").unwrap();
        let p99 = r.get_f64("latency_p99").unwrap();
        assert!(p50 <= p95 && p95 <= p99, "percentiles out of order");
    }

    #[test]
    fn schemas_declare_the_new_kinds() {
        assert!(FAULT_SWEEP_METRICS.iter().any(|d| d.name == "deliverability"));
        assert!(LATENCY_DIST_METRICS
            .iter()
            .any(|d| d.name == "latency_hist" && d.kind == MetricKind::Histogram));
    }
}
