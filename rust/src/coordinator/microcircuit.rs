//! End-to-end multi-wafer cortical-microcircuit experiment (paper §4):
//! LIF neuron dynamics run in AOT-compiled JAX/Pallas artifacts through
//! PJRT, and every inter-shard spike crosses the simulated BrainScaleS
//! Extoll fabric — FPGA aggregation buckets, concentrators, torus routing —
//! with full accounting.
//!
//! The scenario follows the two-phase [`Scenario`] lifecycle:
//!
//! - **prepare** loads the shard artifact **once** (manifest parse +
//!   shape checks) and builds every shard's synaptic weight matrix — the
//!   dominant setup cost (O(n_local × n_global) RNG draws per shard).
//!   The result depends only on `(artifact, seed, w_exc, w_inh,
//!   k_scale)`, which is exactly its cache key, so a sweep over e.g.
//!   `steps` or `dt_s` loads the artifact a single time.
//! - **execute** instantiates per-run [`ShardArena`] state over the
//!   shared weight arena (zero-copy borrow, not regeneration), builds the
//!   fabric, programs routes and runs the co-simulation loop.
//!
//! Co-simulation scheme (one neural timestep = `dt` of hardware time):
//!
//! 1. every shard executes its compiled step with the spike-count vector
//!    assembled from events the fabric delivered during the previous step,
//! 2. the resulting spikes are injected as `HicannEvent`s into the source
//!    FPGA actor, paced within the step window, deadline = end of the
//!    *next* window,
//! 3. the discrete-event simulation advances to the next step boundary,
//! 4. delivered events are drained from each FPGA's RX buffer (GUID =
//!    global source-neuron id) into the next spike-count vectors;
//!    intra-shard spikes short-circuit locally (on-wafer routing).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::extoll::torus::TorusSpec;
use crate::fpga::event::{systime_of, SpikeEvent, TS_MASK};
use crate::fpga::fpga::Fpga;
use crate::fpga::lookup::{RxEntry, TxEntry};
use crate::msg::Msg;
use crate::neuro::shard::{pulse_of_neuron, ShardArena};
use crate::neuro::weights::{fill_weights, weights_shape};
use crate::runtime::{Runtime, ShardModel};
use crate::sim::{EventQueue, F32Arena, F32Handle, Sim, Time};
use crate::util::json::Json;
use crate::util::report::{MetricDecl, Report};
use crate::util::rng::Rng;
use crate::util::stats::Histogram;
use crate::wafer::system::{System, SystemConfig};
use crate::workload::microcircuit::{Microcircuit, FULL_SCALE_NEURONS};

use super::config::ExperimentConfig;
use super::scenario::{downcast_prepared, CacheKey, Prepared, Scenario};

/// Declared metric schema of [`MicrocircuitScenario`]
/// (`pjrt_seconds`/`des_seconds` are wall-clock and therefore excluded
/// from byte-identity gates — see `rust/tests/determinism_queue.rs`).
pub const MICROCIRCUIT_METRICS: &[MetricDecl] = &[
    MetricDecl::count("steps", "steps"),
    MetricDecl::count("n_neurons", "neurons"),
    MetricDecl::count("n_shards", "shards"),
    MetricDecl::count("spikes_total", "spikes"),
    MetricDecl::count("fabric_events", "events"),
    MetricDecl::count("delivered_events", "events"),
    MetricDecl::real("mean_rate", "spikes/neuron/step"),
    MetricDecl::real("mean_batch", "events/packet"),
    MetricDecl::count("deadline_misses", "events"),
    MetricDecl::real("latency_p50", "ns"),
    MetricDecl::real("latency_p99", "ns"),
    MetricDecl::real("pjrt_seconds", "s"),
    MetricDecl::real("des_seconds", "s"),
];

/// Result of a microcircuit co-simulation.
#[derive(Clone, Debug)]
pub struct NeuroReport {
    pub steps: usize,
    pub n_neurons: usize,
    pub n_shards: usize,
    /// Total spikes emitted by the neuron models.
    pub spikes_total: u64,
    /// Spike events shipped over the fabric in packets (= spikes × remote
    /// fan-out: the TX lookup replicates each spike per destination FPGA).
    pub fabric_events: u64,
    /// Events delivered to destination FPGAs.
    pub delivered_events: u64,
    /// Mean firing rate (spikes/neuron/step).
    pub mean_rate: f64,
    /// Per-step spike counts (the "loss curve" analogue for this system).
    pub spikes_per_step: Vec<u32>,
    /// Aggregation efficiency observed during the run.
    pub mean_batch: f64,
    /// Deadline misses at RX.
    pub deadline_misses: u64,
    /// End-to-end fabric latency histogram (ps).
    pub latency: Histogram,
    /// Wall-clock seconds spent in PJRT execute calls.
    pub pjrt_seconds: f64,
    /// Wall-clock seconds spent in the DES.
    pub des_seconds: f64,
}

impl NeuroReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("steps", self.steps)
            .set("n_neurons", self.n_neurons)
            .set("n_shards", self.n_shards)
            .set("spikes_total", self.spikes_total)
            .set("fabric_events", self.fabric_events)
            .set("delivered_events", self.delivered_events)
            .set("mean_rate", self.mean_rate)
            .set("mean_batch", self.mean_batch)
            .set("deadline_misses", self.deadline_misses)
            .set("latency_p50_ns", self.latency.p50() as f64 / 1e3)
            .set("latency_p99_ns", self.latency.p99() as f64 / 1e3)
            .set("pjrt_seconds", self.pjrt_seconds)
            .set("des_seconds", self.des_seconds)
            .set(
                "spikes_per_step",
                self.spikes_per_step
                    .iter()
                    .map(|&x| x as u64)
                    .collect::<Vec<_>>(),
            )
    }

    /// Convert into the unified metric-keyed [`Report`], validated
    /// against `schema` (the per-step spike curve stays on the struct /
    /// full JSON form).
    pub fn to_report(&self, scenario: &str, schema: &'static [MetricDecl]) -> Report {
        let mut r = Report::with_schema(scenario, schema);
        r.push_unit("steps", self.steps, "steps");
        r.push_unit("n_neurons", self.n_neurons, "neurons");
        r.push_unit("n_shards", self.n_shards, "shards");
        r.push_unit("spikes_total", self.spikes_total, "spikes");
        r.push_unit("fabric_events", self.fabric_events, "events");
        r.push_unit("delivered_events", self.delivered_events, "events");
        r.push_unit("mean_rate", self.mean_rate, "spikes/neuron/step");
        r.push_unit("mean_batch", self.mean_batch, "events/packet");
        r.push_unit("deadline_misses", self.deadline_misses, "events");
        r.push_unit("latency_p50", self.latency.p50() as f64 / 1e3, "ns");
        r.push_unit("latency_p99", self.latency.p99() as f64 / 1e3, "ns");
        r.push_unit("pjrt_seconds", self.pjrt_seconds, "s");
        r.push_unit("des_seconds", self.des_seconds, "s");
        r
    }
}

/// Prepared resources of the microcircuit scenarios: the loaded shard
/// artifact and every shard's synaptic weight matrix, packed into one
/// flat [`F32Arena`] (row per shard). Immutable and shared across sweep
/// points; executes read their weight rows straight out of the shared
/// arena — no per-execute copy, which is what lets a 20-wafer rack's
/// ~10⁸-synapse weight set exist exactly once per cache entry.
pub struct MicrocircuitPrepared {
    pub(crate) model: ShardModel,
    /// All shards' row-major `[n_local, n_global]` weights, contiguous.
    pub(crate) weights: Arc<F32Arena>,
    /// Per-shard rows inside `weights`.
    pub(crate) weight_rows: Vec<F32Handle>,
    pub(crate) n_shards: usize,
    pub(crate) n_local: usize,
    pub(crate) n_global: usize,
}

impl Prepared for MicrocircuitPrepared {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        // the weight arena dominates; the loaded artifact is a small
        // constant next to it
        (std::mem::size_of::<MicrocircuitPrepared>() + self.weights.resident_bytes()) as u64
    }
}

/// End-to-end multi-wafer cortical-microcircuit co-simulation (paper §4).
/// Requires `make artifacts`.
pub struct MicrocircuitScenario;

impl Scenario for MicrocircuitScenario {
    fn name(&self) -> &'static str {
        "microcircuit"
    }

    fn about(&self) -> &'static str {
        "cortical-microcircuit co-simulation: LIF shards × Extoll fabric"
    }

    /// Default machine sized for the 4-shard artifacts (the full-size
    /// default system would demand 96 shards).
    fn default_config(&self) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 2,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        MICROCIRCUIT_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        CacheKey::new("microcircuit_shards")
            .field("artifact", &cfg.neuro.artifact)
            .field("seed", cfg.seed)
            .field("w_exc", cfg.neuro.w_exc)
            .field("w_inh", cfg.neuro.w_inh)
            .field("k_scale", cfg.neuro.k_scale)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(mc_prepare(cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let prep: &MicrocircuitPrepared = downcast_prepared(prepared, self.name())?;
        Ok(mc_execute(prep, cfg)?.to_report(self.name(), self.metrics()))
    }
}

/// Split the microcircuit into `n_shards` equal shards of exactly
/// `n_local` neurons (population-major layout inside each shard).
pub fn shard_slices(n_shards: usize, n_local: u32) -> Vec<[u32; 8]> {
    let total = n_shards as u32 * n_local;
    let scale = total as f64 / FULL_SCALE_NEURONS as f64;
    let mc = Microcircuit::new(scale.min(1.0));
    // per-shard quota per population, then fix rounding on the largest pop
    let mut slices = vec![[0u32; 8]; n_shards];
    for (f, slice) in slices.iter_mut().enumerate() {
        let _ = f;
        for p in 0..8 {
            slice[p] = mc.sizes[p] / n_shards as u32;
        }
        let sum: u32 = slice.iter().sum();
        // pad/trim the largest population (L4E) to hit n_local exactly
        let l4e = 2usize;
        slice[l4e] = (slice[l4e] as i64 + (n_local as i64 - sum as i64))
            .try_into()
            .expect("shard slice underflow");
    }
    for s in &slices {
        debug_assert_eq!(s.iter().sum::<u32>(), n_local);
    }
    slices
}

/// Run the experiment. Requires `make artifacts`.
#[deprecated(
    since = "0.2.0",
    note = "use the Scenario registry: coordinator::scenario::find(\"microcircuit\")"
)]
pub fn run_microcircuit(cfg: &ExperimentConfig) -> Result<NeuroReport> {
    microcircuit_experiment(cfg)
}

/// One-shot prepare + execute (the old monolithic driver's shape).
pub(crate) fn microcircuit_experiment(cfg: &ExperimentConfig) -> Result<NeuroReport> {
    let prep = mc_prepare(cfg)?;
    mc_execute(&prep, cfg)
}

/// Phase 1: load the artifact once and build every shard's weights.
fn mc_prepare(cfg: &ExperimentConfig) -> Result<MicrocircuitPrepared> {
    let rt = Runtime::cpu()?;
    let dir = crate::runtime::artifacts_dir();
    let model = rt
        .load_shard_model(&dir, &cfg.neuro.artifact)
        .context("loading shard artifact")?;
    let n_local = model.n_local();
    let n_global = model.n_global();
    anyhow::ensure!(n_global % n_local == 0, "artifact global/local mismatch");
    let n_shards = n_global / n_local;

    let slices = shard_slices(n_shards, n_local as u32);
    let mc = Microcircuit::new(
        (n_shards as u32 * n_local as u32) as f64 / FULL_SCALE_NEURONS as f64,
    );
    // each shard's weights come from an independent, seed-derived RNG
    // stream (see fill_weights), so the matrices are position-independent
    // of whatever the run RNG does at execute time; all shards share one
    // contiguous arena (bit-identical to the former per-shard Vecs)
    let mut arena = F32Arena::with_capacity(n_shards * n_local * n_global);
    let weight_rows = (0..n_shards)
        .map(|f| {
            let (nl, ng) = weights_shape(&slices, f);
            arena.alloc_with(nl * ng, |w| {
                fill_weights(
                    &mc,
                    &slices,
                    f,
                    cfg.neuro.w_exc,
                    cfg.neuro.w_inh,
                    cfg.neuro.k_scale,
                    cfg.seed,
                    w,
                );
            })
        })
        .collect();
    Ok(MicrocircuitPrepared {
        model,
        weights: Arc::new(arena),
        weight_rows,
        n_shards,
        n_local,
        n_global,
    })
}

/// Phase 2: the co-simulation driver behind [`MicrocircuitScenario`].
fn mc_execute(prep: &MicrocircuitPrepared, cfg: &ExperimentConfig) -> Result<NeuroReport> {
    let (n_shards, n_local, n_global) = (prep.n_shards, prep.n_local, prep.n_global);

    // the system must expose exactly n_shards FPGAs
    let sys_cfg = cfg.system;
    anyhow::ensure!(
        sys_cfg.n_wafers * sys_cfg.fpgas_per_wafer == n_shards,
        "system has {} FPGAs but artifact needs {n_shards}",
        sys_cfg.n_wafers * sys_cfg.fpgas_per_wafer
    );
    // every neuron can have at most a handful of in-flight events per
    // step; 4× the global population is a comfortable slab pre-size
    let mut sim: Sim<Msg> =
        Sim::with_queue(EventQueue::with_capacity(cfg.queue, 4 * n_global));
    let sys = System::build(&mut sim, sys_cfg);
    let fpgas: Vec<_> = sys.fpgas().collect();

    // --- neural substrate: per-run SoA state over the shared weights ------
    // membrane/trace state lives in one flat shard-major buffer; weights
    // are borrowed from the prepared arena, never copied per execute
    let mut rng = Rng::new(cfg.seed);
    let mut shards = ShardArena::new(
        prep.model.clone(),
        Arc::clone(&prep.weights),
        prep.weight_rows.clone(),
    );
    shards.randomize_v(&mut rng, cfg.neuro.v_init.0, cfg.neuro.v_init.1);

    // --- route programming --------------------------------------------------
    // every neuron may project anywhere: program full fan-out from every
    // source neuron to every *other* FPGA; GUID = global neuron id (needs
    // n_global ≤ 2^15)
    anyhow::ensure!(n_global <= 1 << 15, "GUID space exceeded");
    for (f, &(_, _, actor, _)) in fpgas.iter().enumerate() {
        for local in 0..n_local as u32 {
            let (hicann, pulse) = pulse_of_neuron(local);
            let guid = (f * n_local) as u16 + local as u16;
            for (g, &(_, _, _dactor, dep)) in fpgas.iter().enumerate() {
                if g == f {
                    continue;
                }
                sim.get_mut::<Fpga>(actor).tx_lut.add(
                    hicann,
                    pulse,
                    TxEntry { dest: dep, guid },
                );
            }
        }
        // RX: accept every remote neuron's GUID (mask: all HICANNs — the
        // weight matrix decides who actually listens)
        for (g, _) in fpgas.iter().enumerate() {
            if g == f {
                continue;
            }
            for local in 0..n_local as u32 {
                let guid = (g * n_local) as u16 + local as u16;
                sim.get_mut::<Fpga>(actor).rx_lut.set(
                    guid,
                    RxEntry {
                        hicann_mask: 0xFF,
                        pulse_addr: pulse_of_neuron(local).1,
                    },
                );
            }
        }
    }

    // --- co-simulation loop -------------------------------------------------
    let dt = cfg.neuro.dt;
    let dt_cycles = (dt.ps() as u128 * 21 / 100_000) as u32; // systime units per step
    let mut spikes_in: Vec<Vec<f32>> = vec![vec![0.0; n_global]; n_shards];
    let mut report = NeuroReport {
        steps: cfg.neuro.steps,
        n_neurons: n_shards * n_local,
        n_shards,
        spikes_total: 0,
        fabric_events: 0,
        delivered_events: 0,
        mean_rate: 0.0,
        spikes_per_step: Vec::with_capacity(cfg.neuro.steps),
        mean_batch: f64::NAN,
        deadline_misses: 0,
        latency: Histogram::new(),
        pjrt_seconds: 0.0,
        des_seconds: 0.0,
    };

    for k in 0..cfg.neuro.steps {
        let t0 = dt * k as u64;
        let t1 = dt * (k as u64 + 1);
        // 1. neuron dynamics
        let pjrt_t = std::time::Instant::now();
        let mut step_spikes = 0u32;
        for f in 0..n_shards {
            let spiked = shards.step_shard(f, &spikes_in[f])?;
            step_spikes += spiked.len() as u32;
        }
        report.pjrt_seconds += pjrt_t.elapsed().as_secs_f64();
        report.spikes_total += step_spikes as u64;
        report.spikes_per_step.push(step_spikes);

        // reset input accumulators for the next step
        for v in spikes_in.iter_mut() {
            for x in v.iter_mut() {
                *x = 0.0;
            }
        }

        // 2. inject spikes: local short-circuit + fabric events
        let des_t = std::time::Instant::now();
        // deadline: end of next window (in systime units), plus margin
        let deadline = ((systime_of(t0) as u32 + 2 * dt_cycles) & TS_MASK as u32) as u16;
        for f in 0..n_shards {
            // pace injections within the first 60% of the window across
            // the 8 HICANN links
            let spikes = shards.last_spikes(f);
            let window = dt * 3 / 5;
            let n_spikes = spikes.len().max(1) as u64;
            for (si, &local) in spikes.iter().enumerate() {
                let g_idx = f * n_local + local as usize;
                // intra-shard delivery (on-wafer routing, no fabric)
                spikes_in[f][g_idx] += 1.0;
                let (hicann, pulse) = pulse_of_neuron(local);
                let at = t0 + window * si as u64 / n_spikes;
                sim.schedule(
                    at.max(sim.now),
                    fpgas[f].2,
                    Msg::HicannEvent(SpikeEvent::new(hicann, pulse, deadline)),
                );
            }
        }

        // 3. advance the fabric to the step boundary
        sim.run_until(t1);
        // service-mode quota/cancellation checkpoint (no-op in batch
        // runs); once per neural step is the natural granularity here
        crate::serve::quota::checkpoint(sim.processed())?;

        // 4. drain deliveries into next-step inputs
        for (f, &(_, _, actor, _)) in fpgas.iter().enumerate() {
            let fpga = sim.get_mut::<Fpga>(actor);
            for (_at, _pulse, ev) in fpga.rx_buffer.drain(..) {
                let g_idx = ev.guid as usize;
                debug_assert!(g_idx < n_global);
                spikes_in[f][g_idx] += 1.0;
                report.delivered_events += 1;
            }
        }
        report.des_seconds += des_t.elapsed().as_secs_f64();
    }

    // tail: flush and account remaining in-flight events
    sys.flush_all(&mut sim);
    sim.run_until(dt * (cfg.neuro.steps as u64 + 4));
    for &(_, _, actor, _) in &fpgas {
        let fpga = sim.get_mut::<Fpga>(actor);
        report.delivered_events += fpga.rx_buffer.len() as u64;
        fpga.rx_buffer.clear();
    }

    report.fabric_events = sys.total_events_out(&sim);
    report.mean_batch = sys.mean_batch_size(&sim);
    report.deadline_misses = sys.total_deadline_misses(&sim);
    report.latency = sys.latency_histogram(&sim);
    report.mean_rate =
        report.spikes_total as f64 / (cfg.neuro.steps as f64 * report.n_neurons as f64);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::wafer::system::SystemConfig;

    #[test]
    fn shard_slices_exact() {
        for (n_shards, n_local) in [(4usize, 256u32), (4, 1024), (2, 512)] {
            let slices = shard_slices(n_shards, n_local);
            assert_eq!(slices.len(), n_shards);
            for s in &slices {
                assert_eq!(s.iter().sum::<u32>(), n_local);
            }
        }
    }

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 2,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.neuro.artifact = "shard_256x1024".to_string();
        cfg.neuro.steps = 30;
        cfg
    }

    #[test]
    fn microcircuit_e2e_small() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let cfg = small_cfg();
        let r = microcircuit_experiment(&cfg).unwrap();
        assert_eq!(r.n_neurons, 1024);
        assert_eq!(r.n_shards, 4);
        assert!(r.spikes_total > 0, "network silent — tune v_init/w");
        // every remote spike fans out to 3 other FPGAs
        assert_eq!(r.fabric_events, 3 * r.spikes_total, "fan-out accounting");
        // nothing may be lost in the fabric
        assert_eq!(r.delivered_events, r.fabric_events, "event loss");
        assert_eq!(r.spikes_per_step.len(), 30);
    }

    #[test]
    fn prepared_shards_are_reusable_across_executes() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut cfg = small_cfg();
        cfg.neuro.steps = 10;
        let prep = mc_prepare(&cfg).unwrap();
        let a = mc_execute(&prep, &cfg).unwrap();
        let b = mc_execute(&prep, &cfg).unwrap();
        // same prepared weights, fresh per-run state: identical physics
        assert_eq!(a.spikes_per_step, b.spikes_per_step);
        assert_eq!(a.delivered_events, b.delivered_events);
        // and identical to a cold one-shot run
        let cold = microcircuit_experiment(&cfg).unwrap();
        assert_eq!(a.spikes_per_step, cold.spikes_per_step);
        assert_eq!(a.fabric_events, cold.fabric_events);
    }

    #[test]
    fn cache_key_tracks_weight_inputs_only() {
        let s = MicrocircuitScenario;
        let base = small_cfg();
        let mut steps = small_cfg();
        steps.neuro.steps = 99;
        steps.workload.rate_hz = 1.0; // irrelevant to the shards
        assert_eq!(s.cache_key(&base), s.cache_key(&steps));
        let mut w = small_cfg();
        w.neuro.w_exc += 1.0;
        assert_ne!(s.cache_key(&base), s.cache_key(&w));
        let mut seed = small_cfg();
        seed.seed ^= 1;
        assert_ne!(s.cache_key(&base), s.cache_key(&seed));
    }
}
