//! Fabric-driven spike-traffic scenarios: multi-wafer system under
//! synthetic load, measuring the paper's communication-path metrics —
//! aggregation efficiency, end-to-end latency, deadline misses, link
//! utilization, flush-reason breakdown.
//!
//! The shared driver implements the two-phase [`Scenario`] lifecycle for
//! every scenario that drives the packet-level simulator:
//!
//! - **prepare** ([`plan_fabric`]): the scenario's
//!   [`FabricScenario::plan`] computes an immutable [`FabricPlan`] —
//!   route tables (TX/RX entries), generator source lists and generator
//!   seeds — from the machine shape and the experiment seed. This is the
//!   config-subset-keyed resource the sweep cache shares across points.
//! - **execute** ([`execute_fabric_plan`]): builds the [`System`] inside
//!   a fresh `Sim`, applies the plan (programs routes, spawns
//!   generators), runs the workload window plus a drain tail (serial or
//!   partitioned PDES), collects the standard fabric metrics, and lets
//!   the scenario append extras via [`FabricScenario::collect`].
//!
//! The plan captures the RNG draws the old single-phase `build` made
//! (route fan-out picks, then one generator seed per FPGA, in FPGA
//! order), so executing a cached plan is byte-identical to the
//! pre-redesign monolithic run — gated in
//! `rust/tests/determinism_queue.rs`.
//!
//! Scenarios in this module:
//! - [`TrafficScenario`] — Poisson/Zipf fan-out load (port of the seed
//!   `run_traffic` driver; identical metrics for identical seed/config).
//! - [`BurstScenario`] — same routes, bursty generators (it shares the
//!   traffic plan's cache family on purpose).
//! - [`HotspotScenario`] — every FPGA fires at one hot FPGA.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::Result;

use crate::extoll::network::{pdes_channel_graph_with, pdes_lookahead_with};
use crate::extoll::torus::{DomainMap, NodeAddr};
use crate::fpga::fpga::{Fpga, TIMER_FLUSH_ALL};
use crate::fpga::lookup::{RxEntry, TxEntry};
use crate::msg::Msg;
use crate::sim::{EventQueue, Partition, Placement, Sim, SyncMode, Time};
use crate::util::json::Json;
use crate::util::report::{MetricDecl, Report};
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::Histogram;
use crate::wafer::system::System;
use crate::workload::generators::{
    spawn_generator, total_generated, BurstGen, GenConfig, GeneratorKind,
};

use super::config::{ExperimentConfig, ReuseMode};
use super::scenario::{
    downcast_prepared, machine_shape_fields, CacheKey, Prepared, Scenario,
};

/// The common fabric metric declarations (the order
/// [`System::fill_fabric_report`] pushes them) plus per-scenario extras.
macro_rules! fabric_schema {
    ($($extra:expr),* $(,)?) => {
        &[
            MetricDecl::real("duration", "s"),
            MetricDecl::count("events_in", "events"),
            MetricDecl::count("events_out", "events"),
            MetricDecl::count("packets_out", "packets"),
            MetricDecl::count("rx_events", "events"),
            MetricDecl::count("dropped", "events"),
            MetricDecl::count("unrouted", "events"),
            MetricDecl::real("mean_batch", "events/packet"),
            MetricDecl::count("flush_deadline", "flushes"),
            MetricDecl::count("flush_full", "flushes"),
            MetricDecl::count("flush_evict", "flushes"),
            MetricDecl::count("flush_external", "flushes"),
            MetricDecl::count("evictions", "evictions"),
            MetricDecl::count("deadline_misses", "events"),
            MetricDecl::real("latency_p50", "ns"),
            MetricDecl::real("latency_p99", "ns"),
            MetricDecl::real("max_link_util", "1"),
            MetricDecl::real("delivered_events_per_s", "events/s"),
            MetricDecl::count("events_generated", "events"),
            MetricDecl::count("des_events", "events"),
            $($extra,)*
        ]
    };
}
pub(crate) use fabric_schema;

/// Declared metric schema of [`TrafficScenario`].
pub const TRAFFIC_METRICS: &[MetricDecl] = fabric_schema![];
/// Declared metric schema of [`BurstScenario`].
pub const BURST_METRICS: &[MetricDecl] = fabric_schema![MetricDecl::count("bursts", "bursts")];
/// Declared metric schema of [`HotspotScenario`].
pub const HOTSPOT_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::count("hot_rx_events", "events"),
    MetricDecl::count("hot_rx_packets", "packets"),
];

/// Aggregated result of one fabric-driven run.
///
/// Kept for compatibility with the pre-`Scenario` API; new code should
/// use the metric-keyed [`Report`] obtained from [`Scenario::run`].
#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub duration: Time,
    pub events_generated: u64,
    pub events_in: u64,
    pub events_out: u64,
    pub packets_out: u64,
    pub rx_events: u64,
    pub dropped: u64,
    pub unrouted: u64,
    pub mean_batch: f64,
    pub flush_deadline: u64,
    pub flush_full: u64,
    pub flush_evict: u64,
    pub evictions: u64,
    pub deadline_misses: u64,
    /// End-to-end event latency (source FPGA ingress → playback), ps.
    pub latency: Histogram,
    /// Peak torus-link utilization (0..1) over the run.
    pub max_link_util: f64,
    /// Throughput in delivered events/s.
    pub delivered_events_per_s: f64,
}

impl TrafficReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("duration_s", self.duration.secs_f64())
            .set("events_generated", self.events_generated)
            .set("events_in", self.events_in)
            .set("events_out", self.events_out)
            .set("packets_out", self.packets_out)
            .set("rx_events", self.rx_events)
            .set("dropped", self.dropped)
            .set("unrouted", self.unrouted)
            .set("mean_batch", self.mean_batch)
            .set("flush_deadline", self.flush_deadline)
            .set("flush_full", self.flush_full)
            .set("flush_evict", self.flush_evict)
            .set("evictions", self.evictions)
            .set("deadline_misses", self.deadline_misses)
            .set("latency_p50_ns", self.latency.p50() as f64 / 1e3)
            .set("latency_p99_ns", self.latency.p99() as f64 / 1e3)
            .set("max_link_util", self.max_link_util)
            .set("delivered_events_per_s", self.delivered_events_per_s)
    }

}

/// One FPGA's slice of a [`FabricPlan`]: its generator sources + seed
/// and its TX lookup entries, in programming order.
#[derive(Clone, Debug)]
pub struct FpgaPlan {
    /// (hicann, pulse) sources fed to this FPGA's generator.
    pub sources: Vec<(u8, u16)>,
    /// Seed of this FPGA's generator; `None` = no generator (e.g. the
    /// hotspot scenario's hot FPGA only receives).
    pub gen_seed: Option<u64>,
    /// TX entries: (hicann, pulse, entry), in `TxLookup::add` order.
    pub tx: Vec<(u8, u16, TxEntry)>,
}

/// The immutable prepared resource of a fabric scenario: everything the
/// old monolithic `build` derived from the seed and the machine shape,
/// with the mutable `Sim` state factored out. Indexed by the
/// [`System::fpgas`] iteration order of the (deterministically rebuilt)
/// system.
#[derive(Clone, Debug)]
pub struct FabricPlan {
    pub per_fpga: Vec<FpgaPlan>,
    /// RX entries: (destination FPGA index, guid, entry).
    pub rx: Vec<(usize, u16, RxEntry)>,
}

impl Prepared for FabricPlan {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        let per_fpga: usize = self
            .per_fpga
            .iter()
            .map(|fp| {
                std::mem::size_of::<FpgaPlan>()
                    + fp.sources.len() * std::mem::size_of::<(u8, u16)>()
                    + fp.tx.len() * std::mem::size_of::<(u8, u16, TxEntry)>()
            })
            .sum();
        (std::mem::size_of::<FabricPlan>()
            + per_fpga
            + self.rx.len() * std::mem::size_of::<(usize, u16, RxEntry)>()) as u64
    }
}

/// The planning half of a fabric-driven scenario. Implementors compute
/// routes and generator seeds from the (throwaway) built system and the
/// experiment-seeded `rng`; the shared driver owns the simulation loop
/// and the common collect.
pub trait FabricScenario {
    /// Compute the immutable route + generator plan. `rng` is seeded
    /// with `cfg.seed`; draw all randomness from it so plans are
    /// reproducible (and cacheable by the config fields that feed it).
    fn plan(
        &self,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<FabricPlan>;

    /// Generator kind spawned at execute time (default: the config's).
    fn generator(&self, cfg: &ExperimentConfig) -> GeneratorKind {
        cfg.workload.generator
    }

    /// Append scenario-specific metrics after the common collect.
    fn collect(&self, _sim: &Sim<Msg>, _sys: &System, _report: &mut Report) {}
}

/// Expected steady-state event-queue occupancy for a fabric workload:
/// one pending wake-up per HICANN link per FPGA plus a per-source
/// envelope for in-flight fabric events. Used to pre-size the queue's
/// payload slab so warmup never grows it mid-simulation.
fn expected_pending_events(cfg: &ExperimentConfig) -> usize {
    let n_fpgas = cfg.system.n_wafers * cfg.system.fpgas_per_wafer;
    (n_fpgas * (8 + 4 * cfg.workload.sources_per_fpga)).min(1 << 20)
}

// ---- fabric reuse pool ---------------------------------------------------

/// One parked fabric: a finished execute's `Sim` + `System`, kept so the
/// next execute with identical build inputs can rewind it with
/// [`Sim::reset_to_epoch`] instead of re-allocating and re-wiring every
/// actor (at rack scale, thousands of boxed actors per point).
struct PooledFabric {
    key: String,
    sim: Sim<Msg>,
    sys: System,
}

thread_local! {
    /// One-entry fabric pool per thread (`reuse=fabric`, the default).
    /// Thread-local because sweep workers execute points concurrently;
    /// each worker recycles its own fabric with zero synchronization.
    static FABRIC_POOL: RefCell<Option<PooledFabric>> = const { RefCell::new(None) };
}

/// Everything that shapes the build: the machine, the fault config and
/// seed (the fault model is sampled from them), the queue backend and
/// the slab pre-size. Two configs with equal keys build byte-identical
/// fabrics, so a rewound fabric stands in for a cold one exactly.
fn fabric_pool_key(cfg: &ExperimentConfig) -> String {
    format!(
        "{:?}|{:?}|{}|{:?}|{}",
        cfg.system,
        cfg.fault,
        cfg.seed,
        cfg.queue,
        expected_pending_events(cfg)
    )
}

/// Take the parked fabric if its build inputs match and it rewinds
/// cleanly; `None` (pool empty, key mismatch, or a non-resettable actor)
/// sends the caller down the cold-build path. A failed reset discards
/// the parked fabric — it is never left half-rewound.
fn acquire_fabric(cfg: &ExperimentConfig) -> Option<(Sim<Msg>, System)> {
    if cfg.reuse != ReuseMode::Fabric {
        return None;
    }
    let mut parked = FABRIC_POOL.with(|p| p.borrow_mut().take())?;
    if parked.key != fabric_pool_key(cfg) {
        return None;
    }
    if parked.sim.reset_to_epoch(&parked.sys.epoch) {
        Some((parked.sim, parked.sys))
    } else {
        None
    }
}

/// Park a finished fabric for the next execute on this thread.
fn release_fabric(cfg: &ExperimentConfig, sim: Sim<Msg>, sys: System) {
    if cfg.reuse != ReuseMode::Fabric {
        return;
    }
    FABRIC_POOL.with(|p| {
        *p.borrow_mut() = Some(PooledFabric {
            key: fabric_pool_key(cfg),
            sim,
            sys,
        });
    });
}

/// Phase 1 for fabric scenarios: build a throwaway system (only its
/// endpoint layout is read) and let the scenario plan against it.
pub fn plan_fabric(scn: &dyn FabricScenario, cfg: &ExperimentConfig) -> Result<FabricPlan> {
    let mut sim: Sim<Msg> = Sim::new();
    let sys = System::build(&mut sim, cfg.system);
    let mut rng = Rng::new(cfg.seed);
    scn.plan(&sys, cfg, &mut rng)
}

/// Program a plan into a freshly built system: TX/RX lookup tables, then
/// the generators (spawned in FPGA order, exactly the actor-creation and
/// external-schedule order of the old monolithic build — the engine's
/// merge keys, and therefore the whole trajectory, match).
fn apply_plan(
    sim: &mut Sim<Msg>,
    sys: &System,
    plan: &FabricPlan,
    kind: GeneratorKind,
    cfg: &ExperimentConfig,
) -> Result<()> {
    let fpgas: Vec<_> = sys.fpgas().collect(); // (wafer, slot, actor, endpoint)
    anyhow::ensure!(
        plan.per_fpga.len() == fpgas.len(),
        "plan covers {} FPGAs but the system has {} — cache key must include \
         the machine shape",
        plan.per_fpga.len(),
        fpgas.len()
    );
    for (fi, fp) in plan.per_fpga.iter().enumerate() {
        let actor = fpgas[fi].2;
        for &(hicann, pulse, entry) in &fp.tx {
            sim.get_mut::<Fpga>(actor).tx_lut.add(hicann, pulse, entry);
        }
    }
    for &(fi, guid, entry) in &plan.rx {
        sim.get_mut::<Fpga>(fpgas[fi].2).rx_lut.set(guid, entry);
    }
    for (fi, fp) in plan.per_fpga.iter().enumerate() {
        let Some(seed) = fp.gen_seed else {
            continue;
        };
        let gen_id = spawn_generator(
            sim,
            kind,
            gen_config(cfg, fp.sources.clone()),
            fpgas[fi].2,
            seed,
        );
        sim.schedule(Time::ZERO, gen_id, Msg::Timer(0));
    }
    Ok(())
}

/// One-shot plan + run (the old single-phase experiment entry point,
/// used by the deprecated wrappers and unit tests).
pub(crate) fn run_fabric_experiment(
    scn: &dyn FabricScenario,
    cfg: &ExperimentConfig,
) -> Result<(Sim<Msg>, System, TrafficReport)> {
    let plan = plan_fabric(scn, cfg)?;
    run_fabric_experiment_with(scn, &plan, cfg)
}

/// Phase 2: build system → apply plan → run workload window + drain
/// tail → collect. Returns the simulation for post-hoc inspection.
///
/// With `cfg.domains > 1` the run loop executes as partitioned
/// conservative PDES ([`crate::sim::Partition`]): same build, same
/// external schedules, same collect — and, by the engine's merge-key
/// contract, byte-identical reports (gated in
/// `rust/tests/determinism_queue.rs`).
pub(crate) fn run_fabric_experiment_with(
    scn: &dyn FabricScenario,
    plan: &FabricPlan,
    cfg: &ExperimentConfig,
) -> Result<(Sim<Msg>, System, TrafficReport)> {
    // `reuse=fabric` (the default): rewind this thread's parked fabric
    // back to its post-build epoch when the build inputs match —
    // identical actor ids, wiring and queue shape, so the run that
    // follows is byte-identical to a cold build (gated below and by the
    // reset axis of `rust/tests/differential_sync.rs`).
    let (mut sim, sys) = match acquire_fabric(cfg) {
        Some(reused) => reused,
        None => {
            let mut sim: Sim<Msg> = Sim::with_queue(EventQueue::with_capacity(
                cfg.queue,
                expected_pending_events(cfg),
            ));
            // The fault model is an execute-time resource, built here
            // (never in prepare) from the experiment seed: plans stay
            // fault-agnostic, so a fault sweep shares one cached plan
            // across every point. The default (fault-free) config builds
            // no model at all — byte-identical to the pre-fault simulator.
            let fault = (!cfg.fault.is_default()).then(|| {
                Arc::new(crate::fault::FaultModel::build(&cfg.fault, cfg.system.torus, cfg.seed))
            });
            let sys = System::build_with(&mut sim, cfg.system, fault.as_ref());
            (sim, sys)
        }
    };
    let fault = sys.fault.clone();
    apply_plan(&mut sim, &sys, plan, scn.generator(cfg), cfg)?;

    let dm = DomainMap::new(cfg.system.torus, cfg.domains);
    let sim = if dm.n_domains() > 1 {
        run_loop_partitioned(sim, &sys, cfg, &dm, fault.as_deref())?
    } else {
        run_loop_serial(sim, &sys, cfg)?
    };

    let report = collect_traffic(&sim, &sys, cfg);
    Ok((sim, sys, report))
}

/// The classic single-threaded run loop: workload window + drain tail.
///
/// Under service mode (a [`crate::serve::quota`] job control installed
/// on this thread) the workload window is sliced into cooperative
/// checkpoint intervals; with no control installed the loop is the
/// original two `run_until` calls. Either way the DES event order is
/// untouched — `run_until(a); run_until(b)` processes exactly the
/// events of `run_until(b)` — so reports stay byte-identical.
fn run_loop_serial(
    mut sim: Sim<Msg>,
    sys: &System,
    cfg: &ExperimentConfig,
) -> Result<Sim<Msg>> {
    run_windowed(&mut sim, cfg.workload.duration)?;
    sys.flush_all(&mut sim);
    sim.run_until(cfg.workload.duration + Time::from_ms(1));
    crate::serve::quota::checkpoint(sim.processed())?;
    Ok(sim)
}

/// Advance `sim` to `end`, stopping at quota checkpoints when a
/// service-mode job control is active on this thread (no-op slicing
/// otherwise).
fn run_windowed(sim: &mut Sim<Msg>, end: Time) -> Result<()> {
    if !crate::serve::quota::is_active() {
        sim.run_until(end);
        return Ok(());
    }
    const SLICES: u64 = 64;
    for i in 1..=SLICES {
        let t = (end.ps() as u128 * i as u128 / SLICES as u128) as u64;
        sim.run_until(Time::from_ps(t));
        crate::serve::quota::checkpoint(sim.processed())?;
    }
    Ok(())
}

/// The same run loop over a torus-partitioned [`Partition`]: identical
/// phases, identical external-schedule order (so the merge keys match the
/// serial run), merged back into one `Sim` for collection.
/// `cfg.sync` picks the synchronization protocol: per-neighbor channel
/// clocks over the inter-domain edge graph (default), the barrier-free
/// variant of the same bounds (`free`), or the windowed global-minimum
/// reference — byte-identical reports in every mode.
fn run_loop_partitioned(
    sim: Sim<Msg>,
    sys: &System,
    cfg: &ExperimentConfig,
    dm: &DomainMap,
    fault: Option<&crate::fault::FaultModel>,
) -> Result<Sim<Msg>> {
    let owner = resolve_owners(&sim, dm)?;
    // one inter-domain edge enumeration either way: the channel graph's
    // cheapest channel IS the windowed lookahead (a closure sum is never
    // smaller than its cheapest edge). Links dead from t=0 never carry a
    // message, so the fault-aware folds exclude them from the channel
    // bounds (`pdes_lookahead_with`).
    let no_links = || anyhow::anyhow!("partition has no inter-domain links");
    let (lookahead, channels) = if cfg.sync.needs_channel_graph() {
        let graph = pdes_channel_graph_with(dm, &cfg.system.nic, fault);
        let la = graph.min_lookahead().ok_or_else(no_links)?;
        (la, Some(graph))
    } else {
        (
            pdes_lookahead_with(dm, &cfg.system.nic, fault).ok_or_else(no_links)?,
            None,
        )
    };
    let mut part = Partition::split(sim, owner, dm.n_domains(), lookahead);
    if let Some(graph) = channels {
        part = part.with_channels(graph);
    }
    if cfg.sync == SyncMode::Free {
        part = part.barrier_free();
    }
    part.run_until(cfg.workload.duration);
    // coarse quota checkpoints only: the partitioned window runs on its
    // own worker threads, so service mode checks between phases rather
    // than slicing inside them (cancellation latency = one window)
    crate::serve::quota::checkpoint(part.processed())?;
    // experiment barrier: same targets, same order as System::flush_all,
    // so the external-schedule merge keys match the serial run's
    for id in sys.flush_targets().collect::<Vec<_>>() {
        part.schedule(cfg.workload.duration, id, Msg::Timer(TIMER_FLUSH_ALL));
    }
    part.run_until(cfg.workload.duration + Time::from_ms(1));
    crate::serve::quota::checkpoint(part.processed())?;
    Ok(part.into_sim())
}

/// Map every actor to its PDES domain by resolving [`Placement`] chains
/// (generator → FPGA → torus node, concentrator → NIC → node, ...).
fn resolve_owners(sim: &Sim<Msg>, dm: &DomainMap) -> Result<Vec<u32>> {
    let n_nodes = dm.spec().n_nodes();
    let mut owner = Vec::with_capacity(sim.n_actors());
    for id in 0..sim.n_actors() {
        let mut cur = id;
        let mut site = None;
        for _ in 0..32 {
            match sim.placement_of(cur) {
                Some(Placement::Site(s)) => {
                    site = Some(s);
                    break;
                }
                Some(Placement::With(next)) => cur = next,
                Some(Placement::Free) => anyhow::bail!(
                    "actor {id} has no domain placement; partitioned runs \
                     (domains > 1) require every actor to resolve to a torus node"
                ),
                None => anyhow::bail!("placement chain of actor {id} hit missing actor {cur}"),
            }
        }
        let site =
            site.ok_or_else(|| anyhow::anyhow!("placement chain of actor {id} too deep"))?;
        anyhow::ensure!(
            (site as usize) < n_nodes,
            "actor {id} placed on site {site}, but the torus has {n_nodes} nodes"
        );
        owner.push(dm.domain_of(NodeAddr(site as u16)));
    }
    Ok(owner)
}

/// Drive `scn` against a prepared `plan` and return the unified,
/// schema-validated [`Report`]: the standard fabric metrics come from
/// [`System::fill_fabric_report`] (single source of truth), plus the
/// generator-side count and the scenario's extra metrics.
pub fn execute_fabric_plan(
    scn: &dyn FabricScenario,
    name: &str,
    schema: &'static [MetricDecl],
    plan: &FabricPlan,
    cfg: &ExperimentConfig,
) -> Result<Report> {
    let (sim, sys, _tr) = run_fabric_experiment_with(scn, plan, cfg)?;
    let mut report = Report::with_schema(name, schema);
    sys.fill_fabric_report(&sim, &mut report, cfg.workload.duration);
    report.push_unit("events_generated", total_generated(&sim), "events");
    // DES bookkeeping for the perf trajectory (benches/bench_events.rs):
    // total simulator events dispatched while producing this report.
    report.push_unit("des_events", sim.processed(), "events");
    scn.collect(&sim, &sys, &mut report);
    // collection done — park the fabric for the next execute instead of
    // dropping thousands of boxed actors just to re-allocate them
    release_fabric(cfg, sim, sys);
    Ok(report)
}

/// Common post-run collect for fabric scenarios (stat collection lives
/// behind [`System`]'s aggregation helpers).
fn collect_traffic(sim: &Sim<Msg>, sys: &System, cfg: &ExperimentConfig) -> TrafficReport {
    let totals = sys.manager_totals(sim);
    let rx_events = sys.total_rx_events(sim);
    TrafficReport {
        duration: cfg.workload.duration,
        events_generated: total_generated(sim),
        events_in: sys.total_events_in(sim),
        events_out: sys.total_events_out(sim),
        packets_out: sys.total_packets_out(sim),
        rx_events,
        dropped: totals.dropped,
        unrouted: totals.unrouted,
        mean_batch: sys.mean_batch_size(sim),
        flush_deadline: totals.flush_deadline,
        flush_full: totals.flush_full,
        flush_evict: totals.flush_evict,
        evictions: totals.evictions,
        deadline_misses: sys.total_deadline_misses(sim),
        latency: sys.latency_histogram(sim),
        max_link_util: sys
            .fabric
            .max_link_utilization(sim, cfg.workload.duration),
        delivered_events_per_s: rx_events as f64 / cfg.workload.duration.secs_f64(),
    }
}

/// Shared generator configuration for fabric scenarios.
fn gen_config(cfg: &ExperimentConfig, sources: Vec<(u8, u16)>) -> GenConfig {
    GenConfig {
        sources,
        rate_hz: cfg.workload.rate_hz,
        deadline_offset: cfg.workload.deadline_offset,
        until: Some(cfg.workload.duration),
        burst_len: cfg.workload.burst_len,
        ..GenConfig::default()
    }
}

/// Machine-shape + seed fields shared by every fabric plan key (the
/// shape rendering itself is the cross-scenario
/// [`machine_shape_fields`] helper).
pub(crate) fn fabric_key_base(family: &'static str, cfg: &ExperimentConfig) -> CacheKey {
    machine_shape_fields(CacheKey::new(family), cfg)
        .field("seed", cfg.seed)
        .field("sources_per_fpga", cfg.workload.sources_per_fpga)
}

/// Cache key of the Zipf fan-out plan — shared by `traffic` and `burst`
/// (their plans are identical; only the generator kind spawned at
/// execute time differs).
pub(crate) fn zipf_plan_key(cfg: &ExperimentConfig) -> CacheKey {
    fabric_key_base("fabric_zipf_plan", cfg)
        .field("fan_out", cfg.workload.fan_out)
        .field("zipf_s", cfg.workload.zipf_s)
}

// ---- traffic -------------------------------------------------------------

/// Poisson/Zipf fan-out load (port of the seed `run_traffic` driver).
///
/// Every FPGA gets `sources_per_fpga` sources spread over its 8 HICANN
/// links; each source fans out to `fan_out` destination FPGAs drawn
/// Zipf(`zipf_s`) over all *other* FPGAs. GUIDs encode (destination-local
/// route id); RX entries multicast to all 8 HICANNs.
pub struct TrafficScenario;

impl FabricScenario for TrafficScenario {
    fn plan(
        &self,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<FabricPlan> {
        let fpgas: Vec<_> = sys.fpgas().collect(); // (wafer, slot, actor, endpoint)
        let n = fpgas.len();
        anyhow::ensure!(n >= 2, "traffic scenario needs at least 2 FPGAs");
        let zipf = Zipf::new(n - 1, cfg.workload.zipf_s);

        // routes + generator seeds, in exactly the old build's draw order
        let mut guid_next = vec![0u16; n]; // per-destination GUID allocator
        let mut per_fpga = Vec::with_capacity(n);
        let mut rx = Vec::new();
        for fi in 0..n {
            let mut sources = Vec::new();
            let mut tx = Vec::new();
            for s in 0..cfg.workload.sources_per_fpga {
                let hicann = (s % 8) as u8;
                let pulse = (s / 8) as u16;
                sources.push((hicann, pulse));
                // fan-out destinations (distinct, excluding self)
                let mut picked = std::collections::BTreeSet::new();
                while picked.len() < cfg.workload.fan_out.min(n - 1) {
                    let mut d = zipf.sample(rng);
                    if d >= fi {
                        d += 1; // skip self
                    }
                    picked.insert(d);
                }
                for d in picked {
                    let dest = fpgas[d].3;
                    let guid = guid_next[d];
                    guid_next[d] = guid_next[d].wrapping_add(1) & 0x7FFF;
                    tx.push((hicann, pulse, TxEntry { dest, guid }));
                    rx.push((
                        d,
                        guid,
                        RxEntry {
                            hicann_mask: 0xFF,
                            pulse_addr: pulse,
                        },
                    ));
                }
            }
            per_fpga.push(FpgaPlan {
                sources,
                gen_seed: Some(rng.next_u64()),
                tx,
            });
        }
        Ok(FabricPlan { per_fpga, rx })
    }
}

impl Scenario for TrafficScenario {
    fn name(&self) -> &'static str {
        "traffic"
    }

    fn about(&self) -> &'static str {
        "multi-wafer Poisson spike traffic with Zipf fan-out destinations"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        TRAFFIC_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), TRAFFIC_METRICS, plan, cfg)
    }
}

// ---- burst ---------------------------------------------------------------

/// Same routes as [`TrafficScenario`], but the load arrives in
/// link-rate-paced bursts — the synchronized-population regime that
/// stresses bucket fill and renaming.
pub struct BurstScenario;

impl FabricScenario for BurstScenario {
    fn plan(
        &self,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<FabricPlan> {
        TrafficScenario.plan(sys, cfg, rng)
    }

    fn generator(&self, _cfg: &ExperimentConfig) -> GeneratorKind {
        GeneratorKind::Burst
    }

    fn collect(&self, sim: &Sim<Msg>, _sys: &System, report: &mut Report) {
        let mut bursts = 0u64;
        for id in 0..sim.n_actors() {
            if let Some(g) = sim.try_get::<BurstGen>(id) {
                bursts += g.bursts;
            }
        }
        report.push_unit("bursts", bursts, "bursts");
    }
}

impl Scenario for BurstScenario {
    fn name(&self) -> &'static str {
        "burst"
    }

    fn about(&self) -> &'static str {
        "traffic routes under bursty (synchronized-population) load"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        BURST_METRICS
    }

    /// Burst shares the traffic plan family: a sweep across
    /// `generator=poisson,burst` (or across both scenarios) reuses one
    /// cached plan.
    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        zipf_plan_key(cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), BURST_METRICS, plan, cfg)
    }
}

// ---- hotspot -------------------------------------------------------------

/// All traffic converges on one hot FPGA (wafer 0, slot 0): every other
/// FPGA's sources route there. Stresses the destination's concentrator
/// ingress and RX path — the worst case for the paper's topology claim.
pub struct HotspotScenario;

impl FabricScenario for HotspotScenario {
    fn plan(
        &self,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<FabricPlan> {
        let fpgas: Vec<_> = sys.fpgas().collect();
        let n = fpgas.len();
        anyhow::ensure!(n >= 2, "hotspot scenario needs at least 2 FPGAs");
        anyhow::ensure!(
            cfg.workload.sources_per_fpga * (n - 1) <= 1 << 15,
            "hotspot GUID space exceeded: {} sources × {} senders",
            cfg.workload.sources_per_fpga,
            n - 1
        );
        let hot = 0usize;
        let hot_ep = fpgas[hot].3;
        let mut guid_next: u16 = 0;
        let mut per_fpga = Vec::with_capacity(n);
        let mut rx = Vec::new();
        for fi in 0..n {
            if fi == hot {
                // the hot FPGA only receives
                per_fpga.push(FpgaPlan {
                    sources: Vec::new(),
                    gen_seed: None,
                    tx: Vec::new(),
                });
                continue;
            }
            let mut sources = Vec::new();
            let mut tx = Vec::new();
            for s in 0..cfg.workload.sources_per_fpga {
                let hicann = (s % 8) as u8;
                let pulse = (s / 8) as u16;
                sources.push((hicann, pulse));
                let guid = guid_next;
                guid_next = guid_next.wrapping_add(1) & 0x7FFF;
                tx.push((hicann, pulse, TxEntry { dest: hot_ep, guid }));
                rx.push((
                    hot,
                    guid,
                    RxEntry {
                        hicann_mask: 0xFF,
                        pulse_addr: pulse,
                    },
                ));
            }
            per_fpga.push(FpgaPlan {
                sources,
                gen_seed: Some(rng.next_u64()),
                tx,
            });
        }
        Ok(FabricPlan { per_fpga, rx })
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let hot_actor = sys.wafers[0].fpgas[0];
        let hot: &Fpga = sim.get(hot_actor);
        report.push_unit("hot_rx_events", hot.stats.rx_events, "events");
        report.push_unit("hot_rx_packets", hot.stats.rx_packets, "packets");
    }
}

impl Scenario for HotspotScenario {
    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn about(&self) -> &'static str {
        "all traffic converges on one hot FPGA (worst-case convergence)"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        HOTSPOT_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        fabric_key_base("hotspot_plan", cfg)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        execute_fabric_plan(self, Scenario::name(self), HOTSPOT_METRICS, plan, cfg)
    }
}

// ---- deprecated wrapper --------------------------------------------------

/// Program random routes and run Poisson traffic over the system.
#[deprecated(
    since = "0.2.0",
    note = "use the Scenario registry: coordinator::scenario::find(\"traffic\")"
)]
pub fn run_traffic(cfg: &ExperimentConfig) -> Result<TrafficReport> {
    let (_sim, _sys, report) = run_fabric_experiment(&TrafficScenario, cfg)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::sim::{QueueKind, Time};
    use crate::wafer::system::SystemConfig;

    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(500);
        cfg
    }

    fn run(cfg: &ExperimentConfig) -> TrafficReport {
        run_fabric_experiment(&TrafficScenario, cfg).unwrap().2
    }

    #[test]
    fn traffic_run_is_loss_free() {
        let cfg = small();
        let r = run(&cfg);
        assert!(r.events_generated > 0);
        assert_eq!(r.events_in, r.events_generated);
        assert_eq!(r.unrouted, 0);
        assert_eq!(r.dropped, 0);
        // every event generated is eventually delivered (fan_out 1)
        assert_eq!(r.rx_events, r.events_generated, "event loss in fabric");
        assert!(r.mean_batch >= 1.0);
        assert!(r.latency.count() > 0);
    }

    #[test]
    fn fan_out_multiplies_delivery() {
        let mut cfg = small();
        cfg.workload.fan_out = 3;
        let r = run(&cfg);
        assert_eq!(r.rx_events, 3 * r.events_generated, "fan-out mismatch");
    }

    #[test]
    fn higher_rate_improves_aggregation() {
        let mut lo = small();
        lo.workload.rate_hz = 0.5e6;
        let mut hi = small();
        hi.workload.rate_hz = 20e6;
        let r_lo = run(&lo);
        let r_hi = run(&hi);
        assert!(
            r_hi.mean_batch > r_lo.mean_batch,
            "aggregation should grow with rate: {} vs {}",
            r_hi.mean_batch,
            r_lo.mean_batch
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small();
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.events_generated, b.events_generated);
        assert_eq!(a.rx_events, b.rx_events);
        assert_eq!(a.packets_out, b.packets_out);
        assert_eq!(a.latency.p99(), b.latency.p99());
    }

    #[test]
    fn one_plan_many_executes_share_resources() {
        // a plan prepared once backs executes at different operating
        // points (rate is an execute-time knob, not a plan input)
        let base = small();
        let plan = plan_fabric(&TrafficScenario, &base).unwrap();
        let mut fast = base.clone();
        fast.workload.rate_hz = 8e6;
        let from_plan =
            run_fabric_experiment_with(&TrafficScenario, &plan, &fast).unwrap().2;
        let from_scratch = run(&fast);
        assert_eq!(from_plan.to_json().to_string(), from_scratch.to_json().to_string());
    }

    #[test]
    fn plan_rejects_mismatched_machine_shape() {
        let base = small();
        let plan = plan_fabric(&TrafficScenario, &base).unwrap();
        let mut other = small();
        other.system.fpgas_per_wafer = 8; // more FPGAs than the plan covers
        let err = match run_fabric_experiment_with(&TrafficScenario, &plan, &other) {
            Ok(_) => panic!("shape mismatch must be rejected"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("machine shape"), "{err:#}");
    }

    #[test]
    fn backend_choice_does_not_change_physics() {
        let mut heap_cfg = small();
        heap_cfg.queue = QueueKind::Heap;
        let mut wheel_cfg = small();
        wheel_cfg.queue = QueueKind::Wheel;
        let a = TrafficScenario.run(&heap_cfg).unwrap();
        let b = TrafficScenario.run(&wheel_cfg).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.get_count("des_events").unwrap() > 0);
    }

    #[test]
    fn domain_count_does_not_change_physics() {
        // the PR 3 invariant: partitioned conservative PDES is a perf
        // knob only — byte-identical reports at any domain count
        let mut base = small();
        base.workload.fan_out = 2;
        let serial = TrafficScenario.run(&base).unwrap();
        for d in [2usize, 4] {
            let mut cfg = base.clone();
            cfg.domains = d;
            let r = TrafficScenario.run(&cfg).unwrap();
            assert_eq!(
                serial.to_json().to_string(),
                r.to_json().to_string(),
                "report diverged at domains={d}"
            );
        }
    }

    #[test]
    fn sync_mode_does_not_change_physics() {
        // the PR 5/PR 8 invariant: the sync protocol is a perf knob
        // only — byte-identical reports at any domain count, in every
        // mode (including barrier-free)
        let mut base = small();
        base.workload.fan_out = 2;
        let serial = TrafficScenario.run(&base).unwrap();
        for sync in SyncMode::ALL {
            for d in [2usize, 4] {
                let mut cfg = base.clone();
                cfg.sync = sync;
                cfg.domains = d;
                let r = TrafficScenario.run(&cfg).unwrap();
                assert_eq!(
                    serial.to_json().to_string(),
                    r.to_json().to_string(),
                    "report diverged at sync={} domains={d}",
                    sync.as_str()
                );
            }
        }
    }

    #[test]
    fn deprecated_wrapper_matches_scenario() {
        let cfg = small();
        #[allow(deprecated)]
        let wrapper = run_traffic(&cfg).unwrap();
        let report = TrafficScenario.run(&cfg).unwrap();
        assert_eq!(
            report.get_count("events_generated"),
            Some(wrapper.events_generated)
        );
        assert_eq!(report.get_count("rx_events"), Some(wrapper.rx_events));
        assert_eq!(report.get_count("packets_out"), Some(wrapper.packets_out));
        assert_eq!(
            report.get_f64("latency_p99"),
            Some(wrapper.latency.p99() as f64 / 1e3)
        );
        assert_eq!(
            report.get_f64("mean_batch"),
            Some(wrapper.mean_batch)
        );
    }

    #[test]
    fn burst_scenario_smoke() {
        let cfg = small();
        let r = BurstScenario.run(&cfg).unwrap();
        assert_eq!(r.scenario(), "burst");
        assert!(r.get_count("events_generated").unwrap() > 0);
        assert!(r.get_count("rx_events").unwrap() > 0);
        assert!(r.get_count("bursts").unwrap() > 0, "no bursts recorded");
        assert_eq!(r.get_count("unrouted"), Some(0));
    }

    #[test]
    fn burst_shares_traffic_plan_cache_family() {
        let cfg = small();
        assert_eq!(
            Scenario::cache_key(&TrafficScenario, &cfg),
            Scenario::cache_key(&BurstScenario, &cfg)
        );
        // and the prepared plan really is interchangeable: execute burst
        // against a plan prepared by traffic
        let prepared = TrafficScenario.prepare(&cfg).unwrap();
        let via_traffic_plan = BurstScenario.execute(prepared.as_ref(), &cfg).unwrap();
        let direct = BurstScenario.run(&cfg).unwrap();
        assert_eq!(
            via_traffic_plan.to_json().to_string(),
            direct.to_json().to_string()
        );
    }

    fn exec(cfg: &ExperimentConfig, plan: &FabricPlan) -> String {
        execute_fabric_plan(&TrafficScenario, "traffic", TRAFFIC_METRICS, plan, cfg)
            .unwrap()
            .to_json()
            .to_string()
    }

    #[test]
    fn fabric_reuse_is_byte_identical_to_cold_rebuild() {
        // the tentpole gate: executes recycling a pooled fabric
        // (reuse=fabric, the default) must report byte-identically to
        // cold rebuilds (reuse=off)
        let cfg = small();
        assert_eq!(cfg.reuse, ReuseMode::Fabric, "reuse defaults on");
        let mut cold_cfg = small();
        cold_cfg.reuse = ReuseMode::Off;
        let plan = plan_fabric(&TrafficScenario, &cfg).unwrap();
        // back-to-back on one thread: the second execute takes the pool
        let first = exec(&cfg, &plan);
        let second = exec(&cfg, &plan);
        let cold = exec(&cold_cfg, &plan);
        assert_eq!(first, cold, "cold-pool execute diverged");
        assert_eq!(second, cold, "reused-fabric execute diverged");
    }

    #[test]
    fn fabric_reuse_covers_partitioned_runs() {
        // merged partitioned sims are resettable too (Partition::into_sim
        // clears the domain context), so warm PDES executes must match
        let mut cfg = small();
        cfg.workload.fan_out = 2;
        cfg.domains = 2;
        let mut cold_cfg = cfg.clone();
        cold_cfg.reuse = ReuseMode::Off;
        let plan = plan_fabric(&TrafficScenario, &cfg).unwrap();
        let first = exec(&cfg, &plan);
        let second = exec(&cfg, &plan);
        let cold = exec(&cold_cfg, &plan);
        assert_eq!(first, cold);
        assert_eq!(second, cold, "reused partitioned execute diverged");
    }

    #[test]
    fn pool_key_tracks_build_inputs() {
        // a parked fabric must never serve a config with different build
        // inputs: change the seed (fault sampling + plan RNG) and the
        // warm path has to cold-build — identical to reuse=off
        let cfg = small();
        let plan = plan_fabric(&TrafficScenario, &cfg).unwrap();
        let _ = exec(&cfg, &plan); // park a fabric for cfg's key
        let mut other = small();
        other.seed ^= 0xDEAD;
        let plan2 = plan_fabric(&TrafficScenario, &other).unwrap();
        let warm = exec(&other, &plan2);
        let mut other_cold = other.clone();
        other_cold.reuse = ReuseMode::Off;
        let cold = exec(&other_cold, &plan2);
        assert_eq!(warm, cold, "stale fabric leaked across pool keys");
        // and the fault axis is part of the key as well
        let mut faulty = small();
        faulty.fault.loss = 0.01;
        let plan3 = plan_fabric(&TrafficScenario, &faulty).unwrap();
        let warm = exec(&faulty, &plan3);
        let mut faulty_cold = faulty.clone();
        faulty_cold.reuse = ReuseMode::Off;
        let cold = exec(&faulty_cold, &plan3);
        assert_eq!(warm, cold, "fault config not part of the pool key");
    }

    #[test]
    fn hotspot_scenario_converges_on_hot_fpga() {
        let cfg = small();
        let r = HotspotScenario.run(&cfg).unwrap();
        assert_eq!(r.scenario(), "hotspot");
        let generated = r.get_count("events_generated").unwrap();
        let rx = r.get_count("rx_events").unwrap();
        let dropped = r.get_count("dropped").unwrap();
        assert!(generated > 0);
        assert_eq!(r.get_count("unrouted"), Some(0));
        // every accepted event is delivered, and all of it lands on the
        // hot FPGA
        assert_eq!(rx + dropped, generated, "event loss in fabric");
        assert_eq!(r.get_count("hot_rx_events"), Some(rx));
    }
}
