//! Spike-traffic experiment driver: multi-wafer system under synthetic
//! Poisson load, measuring the paper's communication-path metrics —
//! aggregation efficiency, end-to-end latency, deadline misses, link
//! utilization, flush-reason breakdown.

use anyhow::Result;

use crate::fpga::fpga::Fpga;
use crate::fpga::lookup::TxEntry;
use crate::fpga::lookup::{EndpointAddr, RxEntry};
use crate::msg::Msg;
use crate::sim::{Sim, Time};
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::Histogram;
use crate::wafer::system::System;
use crate::workload::generators::{GenConfig, PoissonGen};

use super::config::ExperimentConfig;

/// Aggregated result of one traffic run.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub duration: Time,
    pub events_generated: u64,
    pub events_in: u64,
    pub events_out: u64,
    pub packets_out: u64,
    pub rx_events: u64,
    pub dropped: u64,
    pub unrouted: u64,
    pub mean_batch: f64,
    pub flush_deadline: u64,
    pub flush_full: u64,
    pub flush_evict: u64,
    pub evictions: u64,
    pub deadline_misses: u64,
    /// End-to-end event latency (source FPGA ingress → playback), ps.
    pub latency: Histogram,
    /// Peak torus-link utilization (0..1) over the run.
    pub max_link_util: f64,
    /// Throughput in delivered events/s.
    pub delivered_events_per_s: f64,
}

impl TrafficReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("duration_s", self.duration.secs_f64())
            .set("events_generated", self.events_generated)
            .set("events_in", self.events_in)
            .set("events_out", self.events_out)
            .set("packets_out", self.packets_out)
            .set("rx_events", self.rx_events)
            .set("dropped", self.dropped)
            .set("unrouted", self.unrouted)
            .set("mean_batch", self.mean_batch)
            .set("flush_deadline", self.flush_deadline)
            .set("flush_full", self.flush_full)
            .set("flush_evict", self.flush_evict)
            .set("evictions", self.evictions)
            .set("deadline_misses", self.deadline_misses)
            .set("latency_p50_ns", self.latency.p50() as f64 / 1e3)
            .set("latency_p99_ns", self.latency.p99() as f64 / 1e3)
            .set("max_link_util", self.max_link_util)
            .set("delivered_events_per_s", self.delivered_events_per_s)
    }
}

/// Program random routes and run Poisson traffic over the system.
///
/// Every FPGA gets `sources_per_fpga` sources spread over its 8 HICANN
/// links; each source fans out to `fan_out` destination FPGAs drawn
/// Zipf(`zipf_s`) over all *other* FPGAs. GUIDs encode (destination-local
/// route id); RX entries multicast to all 8 HICANNs.
pub fn run_traffic(cfg: &ExperimentConfig) -> Result<TrafficReport> {
    let mut sim: Sim<Msg> = Sim::new();
    let sys = System::build(&mut sim, cfg.system);
    let mut rng = Rng::new(cfg.seed);

    // collect endpoints+actors
    let fpgas: Vec<_> = sys.fpgas().collect(); // (wafer, slot, actor, endpoint)
    let n = fpgas.len();
    let zipf = Zipf::new(n - 1, cfg.workload.zipf_s);

    // program routes + spawn generators
    let mut guid_next = vec![0u16; n]; // per-destination GUID allocator
    for (fi, &(_, _, actor, _ep)) in fpgas.iter().enumerate() {
        let mut sources = Vec::new();
        for s in 0..cfg.workload.sources_per_fpga {
            let hicann = (s % 8) as u8;
            let pulse = (s / 8) as u16;
            sources.push((hicann, pulse));
            // fan-out destinations (distinct, excluding self)
            let mut picked = std::collections::BTreeSet::new();
            while picked.len() < cfg.workload.fan_out.min(n - 1) {
                let mut d = zipf.sample(&mut rng);
                if d >= fi {
                    d += 1; // skip self
                }
                picked.insert(d);
            }
            for d in picked {
                let dest: EndpointAddr = fpgas[d].3;
                let guid = guid_next[d];
                guid_next[d] = guid_next[d].wrapping_add(1) & 0x7FFF;
                sim.get_mut::<Fpga>(actor)
                    .tx_lut
                    .add(hicann, pulse, TxEntry { dest, guid });
                sim.get_mut::<Fpga>(fpgas[d].2).rx_lut.set(
                    guid,
                    RxEntry {
                        hicann_mask: 0xFF,
                        pulse_addr: pulse,
                    },
                );
            }
        }
        let gen = PoissonGen::new(
            GenConfig {
                sources,
                rate_hz: cfg.workload.rate_hz,
                deadline_offset: cfg.workload.deadline_offset,
                until: Some(cfg.workload.duration),
                ..GenConfig::default()
            },
            actor,
            rng.next_u64(),
        );
        let gen_id = sim.add(gen);
        sim.schedule(Time::ZERO, gen_id, Msg::Timer(0));
    }

    // run: workload window + drain tail
    sim.run_until(cfg.workload.duration);
    sys.flush_all(&mut sim);
    sim.run_until(cfg.workload.duration + Time::from_ms(1));

    // collect
    let mut report = TrafficReport {
        duration: cfg.workload.duration,
        events_generated: 0,
        events_in: sys.total_events_in(&sim),
        events_out: sys.total_events_out(&sim),
        packets_out: sys.total_packets_out(&sim),
        rx_events: sys.total_rx_events(&sim),
        dropped: 0,
        unrouted: 0,
        mean_batch: sys.mean_batch_size(&sim),
        flush_deadline: 0,
        flush_full: 0,
        flush_evict: 0,
        evictions: 0,
        deadline_misses: sys.total_deadline_misses(&sim),
        latency: sys.latency_histogram(&sim),
        max_link_util: sys
            .fabric
            .max_link_utilization(&sim, cfg.workload.duration),
        delivered_events_per_s: 0.0,
    };
    for (_, _, actor, _) in &fpgas {
        let f: &Fpga = sim.get(*actor);
        report.dropped += f.stats.dropped_events;
        report.unrouted += f.stats.tx_unrouted;
        report.flush_deadline += f.mgr.stats.flush_deadline;
        report.flush_full += f.mgr.stats.flush_full;
        report.flush_evict += f.mgr.stats.flush_eviction;
        report.evictions += f.mgr.stats.evictions;
    }
    // generators were added after FPGAs; count generated events
    for id in 0..sim.n_actors() {
        if let Some(g) = sim.try_get::<PoissonGen>(id) {
            report.events_generated += g.stats.generated;
        }
    }
    report.delivered_events_per_s = report.rx_events as f64 / report.duration.secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::sim::Time;
    use crate::wafer::system::SystemConfig;

    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(500);
        cfg
    }

    #[test]
    fn traffic_run_is_loss_free() {
        let cfg = small();
        let r = run_traffic(&cfg).unwrap();
        assert!(r.events_generated > 0);
        assert_eq!(r.events_in, r.events_generated);
        assert_eq!(r.unrouted, 0);
        assert_eq!(r.dropped, 0);
        // every event generated is eventually delivered (fan_out 1)
        assert_eq!(r.rx_events, r.events_generated, "event loss in fabric");
        assert!(r.mean_batch >= 1.0);
        assert!(r.latency.count() > 0);
    }

    #[test]
    fn fan_out_multiplies_delivery() {
        let mut cfg = small();
        cfg.workload.fan_out = 3;
        let r = run_traffic(&cfg).unwrap();
        assert_eq!(r.rx_events, 3 * r.events_generated, "fan-out mismatch");
    }

    #[test]
    fn higher_rate_improves_aggregation() {
        let mut lo = small();
        lo.workload.rate_hz = 0.5e6;
        let mut hi = small();
        hi.workload.rate_hz = 20e6;
        let r_lo = run_traffic(&lo).unwrap();
        let r_hi = run_traffic(&hi).unwrap();
        assert!(
            r_hi.mean_batch > r_lo.mean_batch,
            "aggregation should grow with rate: {} vs {}",
            r_hi.mean_batch,
            r_lo.mean_batch
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small();
        let a = run_traffic(&cfg).unwrap();
        let b = run_traffic(&cfg).unwrap();
        assert_eq!(a.events_generated, b.events_generated);
        assert_eq!(a.rx_events, b.rx_events);
        assert_eq!(a.packets_out, b.packets_out);
        assert_eq!(a.latency.p99(), b.latency.p99());
    }
}
