//! Fabric-driven spike-traffic scenarios: multi-wafer system under
//! synthetic load, measuring the paper's communication-path metrics —
//! aggregation efficiency, end-to-end latency, deadline misses, link
//! utilization, flush-reason breakdown.
//!
//! The shared driver [`run_fabric_scenario`] implements the
//! build → run → collect split of the [`Scenario`] contract for every
//! scenario that drives the packet-level simulator: it builds the
//! [`System`], delegates route programming + generator spawning to the
//! scenario's [`FabricScenario::build`], runs the workload window plus a
//! drain tail, collects the standard [`TrafficReport`], and lets the
//! scenario append extra metrics via [`FabricScenario::collect`].
//!
//! Scenarios in this module:
//! - [`TrafficScenario`] — Poisson/Zipf fan-out load (port of the seed
//!   `run_traffic` driver; identical metrics for identical seed/config).
//! - [`BurstScenario`] — same routes, bursty generators.
//! - [`HotspotScenario`] — every FPGA fires at one hot FPGA.

use anyhow::Result;

use crate::extoll::network::pdes_lookahead;
use crate::extoll::torus::{DomainMap, NodeAddr};
use crate::fpga::fpga::{Fpga, TIMER_FLUSH_ALL};
use crate::fpga::lookup::{RxEntry, TxEntry};
use crate::msg::Msg;
use crate::sim::{EventQueue, Partition, Placement, Sim, Time};
use crate::util::json::Json;
use crate::util::report::Report;
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::Histogram;
use crate::wafer::system::System;
use crate::workload::generators::{
    spawn_generator, total_generated, BurstGen, GenConfig, GeneratorKind,
};

use super::config::ExperimentConfig;
use super::scenario::Scenario;

/// Aggregated result of one fabric-driven run.
///
/// Kept for compatibility with the pre-`Scenario` API; new code should
/// use the metric-keyed [`Report`] obtained from [`Scenario::run`].
#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub duration: Time,
    pub events_generated: u64,
    pub events_in: u64,
    pub events_out: u64,
    pub packets_out: u64,
    pub rx_events: u64,
    pub dropped: u64,
    pub unrouted: u64,
    pub mean_batch: f64,
    pub flush_deadline: u64,
    pub flush_full: u64,
    pub flush_evict: u64,
    pub evictions: u64,
    pub deadline_misses: u64,
    /// End-to-end event latency (source FPGA ingress → playback), ps.
    pub latency: Histogram,
    /// Peak torus-link utilization (0..1) over the run.
    pub max_link_util: f64,
    /// Throughput in delivered events/s.
    pub delivered_events_per_s: f64,
}

impl TrafficReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("duration_s", self.duration.secs_f64())
            .set("events_generated", self.events_generated)
            .set("events_in", self.events_in)
            .set("events_out", self.events_out)
            .set("packets_out", self.packets_out)
            .set("rx_events", self.rx_events)
            .set("dropped", self.dropped)
            .set("unrouted", self.unrouted)
            .set("mean_batch", self.mean_batch)
            .set("flush_deadline", self.flush_deadline)
            .set("flush_full", self.flush_full)
            .set("flush_evict", self.flush_evict)
            .set("evictions", self.evictions)
            .set("deadline_misses", self.deadline_misses)
            .set("latency_p50_ns", self.latency.p50() as f64 / 1e3)
            .set("latency_p99_ns", self.latency.p99() as f64 / 1e3)
            .set("max_link_util", self.max_link_util)
            .set("delivered_events_per_s", self.delivered_events_per_s)
    }

}

/// The build/collect half of a fabric-driven scenario. Implementors
/// program routes and spawn generators into the freshly built system;
/// the shared driver owns the simulation loop and the common collect.
pub trait FabricScenario {
    /// Program routes + spawn workload generators. `rng` is the
    /// experiment-seeded generator; draw all randomness from it so runs
    /// are reproducible.
    fn build(
        &self,
        sim: &mut Sim<Msg>,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<()>;

    /// Append scenario-specific metrics after the common collect.
    fn collect(&self, _sim: &Sim<Msg>, _sys: &System, _report: &mut Report) {}
}

/// Expected steady-state event-queue occupancy for a fabric workload:
/// one pending wake-up per HICANN link per FPGA plus a per-source
/// envelope for in-flight fabric events. Used to pre-size the queue's
/// payload slab so warmup never grows it mid-simulation.
fn expected_pending_events(cfg: &ExperimentConfig) -> usize {
    let n_fpgas = cfg.system.n_wafers * cfg.system.fpgas_per_wafer;
    (n_fpgas * (8 + 4 * cfg.workload.sources_per_fpga)).min(1 << 20)
}

/// Shared driver: build system → scenario build → run workload window +
/// drain tail → collect. Returns the simulation for post-hoc inspection.
///
/// With `cfg.domains > 1` the run loop executes as partitioned
/// conservative PDES ([`crate::sim::Partition`]): same build, same
/// external schedules, same collect — and, by the engine's merge-key
/// contract, byte-identical reports (gated in
/// `rust/tests/determinism_queue.rs`).
pub(crate) fn run_fabric_experiment(
    scn: &dyn FabricScenario,
    cfg: &ExperimentConfig,
) -> Result<(Sim<Msg>, System, TrafficReport)> {
    let mut sim: Sim<Msg> = Sim::with_queue(EventQueue::with_capacity(
        cfg.queue,
        expected_pending_events(cfg),
    ));
    let sys = System::build(&mut sim, cfg.system);
    let mut rng = Rng::new(cfg.seed);
    scn.build(&mut sim, &sys, cfg, &mut rng)?;

    let dm = DomainMap::new(cfg.system.torus, cfg.domains);
    let sim = if dm.n_domains() > 1 {
        run_loop_partitioned(sim, &sys, cfg, &dm)?
    } else {
        run_loop_serial(sim, &sys, cfg)
    };

    let report = collect_traffic(&sim, &sys, cfg);
    Ok((sim, sys, report))
}

/// The classic single-threaded run loop: workload window + drain tail.
fn run_loop_serial(mut sim: Sim<Msg>, sys: &System, cfg: &ExperimentConfig) -> Sim<Msg> {
    sim.run_until(cfg.workload.duration);
    sys.flush_all(&mut sim);
    sim.run_until(cfg.workload.duration + Time::from_ms(1));
    sim
}

/// The same run loop over a torus-partitioned [`Partition`]: identical
/// phases, identical external-schedule order (so the merge keys match the
/// serial run), merged back into one `Sim` for collection.
fn run_loop_partitioned(
    sim: Sim<Msg>,
    sys: &System,
    cfg: &ExperimentConfig,
    dm: &DomainMap,
) -> Result<Sim<Msg>> {
    let lookahead = pdes_lookahead(dm, &cfg.system.nic)
        .ok_or_else(|| anyhow::anyhow!("partition has no inter-domain links"))?;
    let owner = resolve_owners(&sim, dm)?;
    let mut part = Partition::split(sim, owner, dm.n_domains(), lookahead);
    part.run_until(cfg.workload.duration);
    // experiment barrier: same targets, same order as System::flush_all,
    // so the external-schedule merge keys match the serial run's
    for id in sys.flush_targets().collect::<Vec<_>>() {
        part.schedule(cfg.workload.duration, id, Msg::Timer(TIMER_FLUSH_ALL));
    }
    part.run_until(cfg.workload.duration + Time::from_ms(1));
    Ok(part.into_sim())
}

/// Map every actor to its PDES domain by resolving [`Placement`] chains
/// (generator → FPGA → torus node, concentrator → NIC → node, ...).
fn resolve_owners(sim: &Sim<Msg>, dm: &DomainMap) -> Result<Vec<u32>> {
    let n_nodes = dm.spec().n_nodes();
    let mut owner = Vec::with_capacity(sim.n_actors());
    for id in 0..sim.n_actors() {
        let mut cur = id;
        let mut site = None;
        for _ in 0..32 {
            match sim.placement_of(cur) {
                Some(Placement::Site(s)) => {
                    site = Some(s);
                    break;
                }
                Some(Placement::With(next)) => cur = next,
                Some(Placement::Free) => anyhow::bail!(
                    "actor {id} has no domain placement; partitioned runs \
                     (domains > 1) require every actor to resolve to a torus node"
                ),
                None => anyhow::bail!("placement chain of actor {id} hit missing actor {cur}"),
            }
        }
        let site =
            site.ok_or_else(|| anyhow::anyhow!("placement chain of actor {id} too deep"))?;
        anyhow::ensure!(
            (site as usize) < n_nodes,
            "actor {id} placed on site {site}, but the torus has {n_nodes} nodes"
        );
        owner.push(dm.domain_of(NodeAddr(site as u16)));
    }
    Ok(owner)
}

/// Drive `scn` and return the unified [`Report`]: the standard fabric
/// metrics come from [`System::fabric_report`] (single source of truth),
/// plus the generator-side count and the scenario's extra metrics.
pub fn run_fabric_scenario(
    scn: &dyn FabricScenario,
    name: &str,
    cfg: &ExperimentConfig,
) -> Result<Report> {
    let (sim, sys, _tr) = run_fabric_experiment(scn, cfg)?;
    let mut report = sys.fabric_report(&sim, name, cfg.workload.duration);
    report.push_unit("events_generated", total_generated(&sim), "events");
    // DES bookkeeping for the perf trajectory (benches/bench_events.rs):
    // total simulator events dispatched while producing this report.
    report.push_unit("des_events", sim.processed(), "events");
    scn.collect(&sim, &sys, &mut report);
    Ok(report)
}

/// Common post-run collect for fabric scenarios (stat collection lives
/// behind [`System`]'s aggregation helpers).
fn collect_traffic(sim: &Sim<Msg>, sys: &System, cfg: &ExperimentConfig) -> TrafficReport {
    let totals = sys.manager_totals(sim);
    let rx_events = sys.total_rx_events(sim);
    TrafficReport {
        duration: cfg.workload.duration,
        events_generated: total_generated(sim),
        events_in: sys.total_events_in(sim),
        events_out: sys.total_events_out(sim),
        packets_out: sys.total_packets_out(sim),
        rx_events,
        dropped: totals.dropped,
        unrouted: totals.unrouted,
        mean_batch: sys.mean_batch_size(sim),
        flush_deadline: totals.flush_deadline,
        flush_full: totals.flush_full,
        flush_evict: totals.flush_evict,
        evictions: totals.evictions,
        deadline_misses: sys.total_deadline_misses(sim),
        latency: sys.latency_histogram(sim),
        max_link_util: sys
            .fabric
            .max_link_utilization(sim, cfg.workload.duration),
        delivered_events_per_s: rx_events as f64 / cfg.workload.duration.secs_f64(),
    }
}

/// Shared generator configuration for fabric scenarios.
fn gen_config(cfg: &ExperimentConfig, sources: Vec<(u8, u16)>) -> GenConfig {
    GenConfig {
        sources,
        rate_hz: cfg.workload.rate_hz,
        deadline_offset: cfg.workload.deadline_offset,
        until: Some(cfg.workload.duration),
        burst_len: cfg.workload.burst_len,
        ..GenConfig::default()
    }
}

// ---- traffic -------------------------------------------------------------

/// Poisson/Zipf fan-out load (port of the seed `run_traffic` driver).
///
/// Every FPGA gets `sources_per_fpga` sources spread over its 8 HICANN
/// links; each source fans out to `fan_out` destination FPGAs drawn
/// Zipf(`zipf_s`) over all *other* FPGAs. GUIDs encode (destination-local
/// route id); RX entries multicast to all 8 HICANNs.
pub struct TrafficScenario;

impl FabricScenario for TrafficScenario {
    fn build(
        &self,
        sim: &mut Sim<Msg>,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<()> {
        let fpgas: Vec<_> = sys.fpgas().collect(); // (wafer, slot, actor, endpoint)
        let n = fpgas.len();
        anyhow::ensure!(n >= 2, "traffic scenario needs at least 2 FPGAs");
        let zipf = Zipf::new(n - 1, cfg.workload.zipf_s);

        // program routes + spawn generators
        let mut guid_next = vec![0u16; n]; // per-destination GUID allocator
        for (fi, &(_, _, actor, _ep)) in fpgas.iter().enumerate() {
            let mut sources = Vec::new();
            for s in 0..cfg.workload.sources_per_fpga {
                let hicann = (s % 8) as u8;
                let pulse = (s / 8) as u16;
                sources.push((hicann, pulse));
                // fan-out destinations (distinct, excluding self)
                let mut picked = std::collections::BTreeSet::new();
                while picked.len() < cfg.workload.fan_out.min(n - 1) {
                    let mut d = zipf.sample(rng);
                    if d >= fi {
                        d += 1; // skip self
                    }
                    picked.insert(d);
                }
                for d in picked {
                    let dest = fpgas[d].3;
                    let guid = guid_next[d];
                    guid_next[d] = guid_next[d].wrapping_add(1) & 0x7FFF;
                    sim.get_mut::<Fpga>(actor)
                        .tx_lut
                        .add(hicann, pulse, TxEntry { dest, guid });
                    sim.get_mut::<Fpga>(fpgas[d].2).rx_lut.set(
                        guid,
                        RxEntry {
                            hicann_mask: 0xFF,
                            pulse_addr: pulse,
                        },
                    );
                }
            }
            let gen_id = spawn_generator(
                sim,
                cfg.workload.generator,
                gen_config(cfg, sources),
                actor,
                rng.next_u64(),
            );
            sim.schedule(Time::ZERO, gen_id, Msg::Timer(0));
        }
        Ok(())
    }
}

impl Scenario for TrafficScenario {
    fn name(&self) -> &'static str {
        "traffic"
    }

    fn about(&self) -> &'static str {
        "multi-wafer Poisson spike traffic with Zipf fan-out destinations"
    }

    fn run(&self, cfg: &ExperimentConfig) -> Result<Report> {
        run_fabric_scenario(self, Scenario::name(self), cfg)
    }
}

// ---- burst ---------------------------------------------------------------

/// Same routes as [`TrafficScenario`], but the load arrives in
/// link-rate-paced bursts — the synchronized-population regime that
/// stresses bucket fill and renaming.
pub struct BurstScenario;

impl FabricScenario for BurstScenario {
    fn build(
        &self,
        sim: &mut Sim<Msg>,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<()> {
        let mut cfg = cfg.clone();
        cfg.workload.generator = GeneratorKind::Burst;
        TrafficScenario.build(sim, sys, &cfg, rng)
    }

    fn collect(&self, sim: &Sim<Msg>, _sys: &System, report: &mut Report) {
        let mut bursts = 0u64;
        for id in 0..sim.n_actors() {
            if let Some(g) = sim.try_get::<BurstGen>(id) {
                bursts += g.bursts;
            }
        }
        report.push_unit("bursts", bursts, "bursts");
    }
}

impl Scenario for BurstScenario {
    fn name(&self) -> &'static str {
        "burst"
    }

    fn about(&self) -> &'static str {
        "traffic routes under bursty (synchronized-population) load"
    }

    fn run(&self, cfg: &ExperimentConfig) -> Result<Report> {
        run_fabric_scenario(self, Scenario::name(self), cfg)
    }
}

// ---- hotspot -------------------------------------------------------------

/// All traffic converges on one hot FPGA (wafer 0, slot 0): every other
/// FPGA's sources route there. Stresses the destination's concentrator
/// ingress and RX path — the worst case for the paper's topology claim.
pub struct HotspotScenario;

impl FabricScenario for HotspotScenario {
    fn build(
        &self,
        sim: &mut Sim<Msg>,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<()> {
        let fpgas: Vec<_> = sys.fpgas().collect();
        let n = fpgas.len();
        anyhow::ensure!(n >= 2, "hotspot scenario needs at least 2 FPGAs");
        anyhow::ensure!(
            cfg.workload.sources_per_fpga * (n - 1) <= 1 << 15,
            "hotspot GUID space exceeded: {} sources × {} senders",
            cfg.workload.sources_per_fpga,
            n - 1
        );
        let hot = 0usize;
        let (_, _, hot_actor, hot_ep) = fpgas[hot];
        let mut guid_next: u16 = 0;
        for (fi, &(_, _, actor, _)) in fpgas.iter().enumerate() {
            if fi == hot {
                continue; // the hot FPGA only receives
            }
            let mut sources = Vec::new();
            for s in 0..cfg.workload.sources_per_fpga {
                let hicann = (s % 8) as u8;
                let pulse = (s / 8) as u16;
                sources.push((hicann, pulse));
                let guid = guid_next;
                guid_next = guid_next.wrapping_add(1) & 0x7FFF;
                sim.get_mut::<Fpga>(actor).tx_lut.add(
                    hicann,
                    pulse,
                    TxEntry { dest: hot_ep, guid },
                );
                sim.get_mut::<Fpga>(hot_actor).rx_lut.set(
                    guid,
                    RxEntry {
                        hicann_mask: 0xFF,
                        pulse_addr: pulse,
                    },
                );
            }
            let gen_id = spawn_generator(
                sim,
                cfg.workload.generator,
                gen_config(cfg, sources),
                actor,
                rng.next_u64(),
            );
            sim.schedule(Time::ZERO, gen_id, Msg::Timer(0));
        }
        Ok(())
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let hot_actor = sys.wafers[0].fpgas[0];
        let hot: &Fpga = sim.get(hot_actor);
        report.push_unit("hot_rx_events", hot.stats.rx_events, "events");
        report.push_unit("hot_rx_packets", hot.stats.rx_packets, "packets");
    }
}

impl Scenario for HotspotScenario {
    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn about(&self) -> &'static str {
        "all traffic converges on one hot FPGA (worst-case convergence)"
    }

    fn run(&self, cfg: &ExperimentConfig) -> Result<Report> {
        run_fabric_scenario(self, Scenario::name(self), cfg)
    }
}

// ---- deprecated wrapper --------------------------------------------------

/// Program random routes and run Poisson traffic over the system.
#[deprecated(
    since = "0.2.0",
    note = "use the Scenario registry: coordinator::scenario::find(\"traffic\")"
)]
pub fn run_traffic(cfg: &ExperimentConfig) -> Result<TrafficReport> {
    let (_sim, _sys, report) = run_fabric_experiment(&TrafficScenario, cfg)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::sim::{QueueKind, Time};
    use crate::wafer::system::SystemConfig;

    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(500);
        cfg
    }

    fn run(cfg: &ExperimentConfig) -> TrafficReport {
        run_fabric_experiment(&TrafficScenario, cfg).unwrap().2
    }

    #[test]
    fn traffic_run_is_loss_free() {
        let cfg = small();
        let r = run(&cfg);
        assert!(r.events_generated > 0);
        assert_eq!(r.events_in, r.events_generated);
        assert_eq!(r.unrouted, 0);
        assert_eq!(r.dropped, 0);
        // every event generated is eventually delivered (fan_out 1)
        assert_eq!(r.rx_events, r.events_generated, "event loss in fabric");
        assert!(r.mean_batch >= 1.0);
        assert!(r.latency.count() > 0);
    }

    #[test]
    fn fan_out_multiplies_delivery() {
        let mut cfg = small();
        cfg.workload.fan_out = 3;
        let r = run(&cfg);
        assert_eq!(r.rx_events, 3 * r.events_generated, "fan-out mismatch");
    }

    #[test]
    fn higher_rate_improves_aggregation() {
        let mut lo = small();
        lo.workload.rate_hz = 0.5e6;
        let mut hi = small();
        hi.workload.rate_hz = 20e6;
        let r_lo = run(&lo);
        let r_hi = run(&hi);
        assert!(
            r_hi.mean_batch > r_lo.mean_batch,
            "aggregation should grow with rate: {} vs {}",
            r_hi.mean_batch,
            r_lo.mean_batch
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small();
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.events_generated, b.events_generated);
        assert_eq!(a.rx_events, b.rx_events);
        assert_eq!(a.packets_out, b.packets_out);
        assert_eq!(a.latency.p99(), b.latency.p99());
    }

    #[test]
    fn backend_choice_does_not_change_physics() {
        let mut heap_cfg = small();
        heap_cfg.queue = QueueKind::Heap;
        let mut wheel_cfg = small();
        wheel_cfg.queue = QueueKind::Wheel;
        let a = TrafficScenario.run(&heap_cfg).unwrap();
        let b = TrafficScenario.run(&wheel_cfg).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.get_count("des_events").unwrap() > 0);
    }

    #[test]
    fn domain_count_does_not_change_physics() {
        // the tentpole invariant: partitioned conservative PDES is a perf
        // knob only — byte-identical reports at any domain count
        let mut base = small();
        base.workload.fan_out = 2;
        let serial = TrafficScenario.run(&base).unwrap();
        for d in [2usize, 4] {
            let mut cfg = base.clone();
            cfg.domains = d;
            let r = TrafficScenario.run(&cfg).unwrap();
            assert_eq!(
                serial.to_json().to_string(),
                r.to_json().to_string(),
                "report diverged at domains={d}"
            );
        }
    }

    #[test]
    fn deprecated_wrapper_matches_scenario() {
        let cfg = small();
        #[allow(deprecated)]
        let wrapper = run_traffic(&cfg).unwrap();
        let report = TrafficScenario.run(&cfg).unwrap();
        assert_eq!(
            report.get_count("events_generated"),
            Some(wrapper.events_generated)
        );
        assert_eq!(report.get_count("rx_events"), Some(wrapper.rx_events));
        assert_eq!(report.get_count("packets_out"), Some(wrapper.packets_out));
        assert_eq!(
            report.get_f64("latency_p99"),
            Some(wrapper.latency.p99() as f64 / 1e3)
        );
        assert_eq!(
            report.get_f64("mean_batch"),
            Some(wrapper.mean_batch)
        );
    }

    #[test]
    fn burst_scenario_smoke() {
        let cfg = small();
        let r = BurstScenario.run(&cfg).unwrap();
        assert_eq!(r.scenario(), "burst");
        assert!(r.get_count("events_generated").unwrap() > 0);
        assert!(r.get_count("rx_events").unwrap() > 0);
        assert!(r.get_count("bursts").unwrap() > 0, "no bursts recorded");
        assert_eq!(r.get_count("unrouted"), Some(0));
    }

    #[test]
    fn hotspot_scenario_converges_on_hot_fpga() {
        let cfg = small();
        let r = HotspotScenario.run(&cfg).unwrap();
        assert_eq!(r.scenario(), "hotspot");
        let generated = r.get_count("events_generated").unwrap();
        let rx = r.get_count("rx_events").unwrap();
        let dropped = r.get_count("dropped").unwrap();
        assert!(generated > 0);
        assert_eq!(r.get_count("unrouted"), Some(0));
        // every accepted event is delivered, and all of it lands on the
        // hot FPGA
        assert_eq!(rx + dropped, generated, "event loss in fabric");
        assert_eq!(r.get_count("hot_rx_events"), Some(rx));
    }
}
