//! The `Scenario` experiment API: two-phase lifecycle, resource cache,
//! metric schemas, registry, generic dispatch.
//!
//! A scenario is one self-contained experiment: it consumes an
//! [`ExperimentConfig`], drives whatever machinery it needs (packet-level
//! DES, neural co-simulation, flow-level analysis), and returns a unified
//! metric-keyed [`Report`]. The CLI (`bss-extoll run <scenario>`), the
//! sweep runner and tests all dispatch through the [`registry`], so adding
//! a scenario is one type + one registry line.
//!
//! ## The two-phase lifecycle
//!
//! Experiment execution is split along the expensive/cheap boundary:
//!
//! - [`Scenario::prepare`] builds the **immutable, config-subset-keyed
//!   resources**: loaded shard artifacts + LIF weight matrices, the
//!   microcircuit structure, placement/flow tables, route programs. The
//!   result is an `Arc<dyn Prepared>` that depends *only* on the config
//!   fields named by [`Scenario::cache_key`].
//! - [`Scenario::execute`] runs the simulation against those resources
//!   and collects the report. Everything mutable (the `Sim`, actor
//!   state, RNG streams beyond the prepare-owned ones) is created here,
//!   so one `Prepared` can back any number of concurrent executes.
//!
//! [`Scenario::run`] survives as a default-impl convenience that calls
//! `prepare` + `execute` — one-shot callers keep the old single-call
//! shape and, by construction, the old byte-identical results.
//!
//! The payoff is the [`ResourceCache`]: the sweep runner keys prepared
//! resources by [`Scenario::cache_key`], so N sweep points that share an
//! artifact load it once — including under `sweep --jobs N`, where the
//! cache serializes each key's first build behind a per-key latch (so
//! hit/miss counts, and therefore sweep artifacts, are identical to the
//! serial run's).
//!
//! ## Cache-key discipline
//!
//! `cache_key(cfg)` must name **every** config field the prepared
//! resources read — equal keys promise interchangeable resources
//! (property-tested in `rust/tests/proptest_invariants.rs`). Listing a
//! field the resources ignore only costs sharing; omitting one the
//! resources read is a correctness bug (two configs would share state
//! they must not). When in doubt, include the field.
//!
//! ## Declared metric schemas
//!
//! [`Scenario::metrics`] declares the report schema (name, unit, kind)
//! as a static slice. Reports built with [`Report::with_schema`]
//! validate every push against it, `run --list` prints it, and the sweep
//! CSV orders its metric columns by it instead of by insertion order.
//!
//! ## Migration note (PR 4)
//!
//! Before this redesign the trait was a single opaque
//! `run(&cfg) -> Report`. Migrating a scenario:
//!
//! 1. move the expensive, config-subset-derived setup into `prepare`,
//!    returning it as an `Arc<dyn Prepared>` (a plain struct + a one-line
//!    [`Prepared::as_any`] impl);
//! 2. keep the simulation + collection in `execute`, reading the setup
//!    back via [`downcast_prepared`];
//! 3. declare `cache_key` over exactly the fields step 1 read;
//! 4. declare `metrics` and build the report with [`Report::with_schema`];
//! 5. delete the hand-written `run` — the default impl replaces it.
//!
//! Fabric-driven scenarios implement [`super::traffic::FabricScenario`]
//! (a plan/collect split) instead and inherit all of the above from the
//! shared driver in `coordinator/traffic.rs`.
//!
//! ## Contract
//!
//! - [`Scenario::name`] is the stable CLI identifier (lowercase, no
//!   spaces) and the `scenario` field of the resulting [`Report`].
//! - `prepare` and `execute` must be **deterministic**: the same config
//!   (including `seed`) must produce the same report, and executing
//!   against a cached `Prepared` must be byte-identical to executing
//!   against a freshly prepared one (gated in
//!   `rust/tests/determinism_queue.rs`). Draw all randomness from
//!   [`crate::util::rng::Rng`] streams seeded with `cfg.seed`.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::extoll::analysis::{Flow, FlowAnalysis};
use crate::msg::Msg;
use crate::sim::Sim;
use crate::util::report::Report;
use crate::wafer::system::System;
use crate::workload::microcircuit::{Microcircuit, Placement};

pub use crate::util::report::{MetricDecl, MetricKind};

use super::config::ExperimentConfig;
use super::faults::{FaultSweepScenario, LatencyDistScenario, ReliabilitySweepScenario};
use super::microcircuit::MicrocircuitScenario;
use super::rack::MicrocircuitRackScenario;
use super::traffic::{BurstScenario, HotspotScenario, TrafficScenario};

/// Immutable resources produced by [`Scenario::prepare`] and shared
/// (via `Arc`) across executes. `Send + Sync` is part of the contract:
/// the parallel sweep runner hands one `Prepared` to several worker
/// threads at once.
pub trait Prepared: Send + Sync + 'static {
    /// Concrete-type escape hatch for [`downcast_prepared`].
    fn as_any(&self) -> &dyn Any;

    /// Approximate resident heap footprint of this resource set, in
    /// bytes. Feeds the byte-accounted LRU in [`ResourceCache`]; the
    /// estimate only has to be honest about relative magnitude (weight
    /// matrices ≫ flow tables), not exact. The default is a nominal
    /// constant so scenarios without a meaningful estimate still
    /// participate in eviction accounting.
    fn resident_bytes(&self) -> u64 {
        1024
    }
}

/// Recover the concrete prepared type inside [`Scenario::execute`].
pub fn downcast_prepared<'a, T: Prepared>(
    prepared: &'a dyn Prepared,
    scenario: &str,
) -> Result<&'a T> {
    prepared.as_any().downcast_ref::<T>().ok_or_else(|| {
        anyhow::anyhow!(
            "scenario '{scenario}': prepared resources have the wrong concrete \
             type — execute() was handed resources prepared by an incompatible \
             scenario (cache-key family collision?)"
        )
    })
}

/// Identity of a prepared-resource set: a family name plus the rendered
/// values of every config field the resources depend on. Equal keys
/// promise interchangeable [`Prepared`] values (the cache-key
/// discipline in the module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    family: &'static str,
    fields: Vec<(&'static str, String)>,
}

impl CacheKey {
    /// Start a key. `family` names the resource kind; scenarios whose
    /// prepare is identical (e.g. `traffic` and `burst` share one route
    /// plan) use the same family on purpose so sweeps across them share
    /// cache entries.
    pub fn new(family: &'static str) -> CacheKey {
        CacheKey {
            family,
            fields: Vec::new(),
        }
    }

    /// Append one config field this resource set depends on.
    pub fn field(mut self, name: &'static str, value: impl std::fmt::Display) -> CacheKey {
        self.fields.push((name, value.to_string()));
        self
    }

    /// The resource-family name.
    pub fn family(&self) -> &'static str {
        self.family
    }
}

impl std::fmt::Display for CacheKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.family)?;
        for (name, value) in &self.fields {
            write!(f, ";{name}={value}")?;
        }
        Ok(())
    }
}

/// Append the machine-shape fields (wafers, torus dimensions,
/// FPGA/concentrator layout) that determine [`System::build`]'s actor
/// and endpoint layout. Every cache key whose prepare reads the built
/// system must include these — one shared helper so a new shape field
/// only has to be added here (used by the fabric plans and `analyze`).
pub fn machine_shape_fields(key: CacheKey, cfg: &ExperimentConfig) -> CacheKey {
    key.field("n_wafers", cfg.system.n_wafers)
        .field(
            "torus",
            format!(
                "{}x{}x{}",
                cfg.system.torus.nx, cfg.system.torus.ny, cfg.system.torus.nz
            ),
        )
        .field("fpgas_per_wafer", cfg.system.fpgas_per_wafer)
        .field(
            "concentrators_per_wafer",
            cfg.system.concentrators_per_wafer,
        )
}

/// One registered experiment.
///
/// `Send + Sync` is part of the contract: the parallel sweep runner
/// (`sweep --jobs N`) calls [`Scenario::execute`] concurrently from
/// worker threads, so scenarios must keep all run state local to
/// `execute` (every registered scenario is a stateless unit struct).
pub trait Scenario: Send + Sync {
    /// Stable identifier used by the CLI and the report.
    fn name(&self) -> &'static str;

    /// One-line description for `bss-extoll run --list`.
    fn about(&self) -> &'static str;

    /// The config the CLI starts from when the user supplies none.
    /// Scenarios with machine-shape requirements (e.g. the microcircuit
    /// must match its artifact's shard count) override this.
    fn default_config(&self) -> ExperimentConfig {
        ExperimentConfig::default()
    }

    /// The declared metric schema: every metric `execute` will push,
    /// in report/CSV column order. Validated on push, printed by
    /// `run --list`.
    fn metrics(&self) -> &'static [MetricDecl];

    /// The config fields [`Scenario::prepare`]'s resources depend on
    /// (see the cache-key discipline in the module docs).
    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey;

    /// Phase 1: build the expensive immutable resources for `cfg`.
    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>>;

    /// Phase 2: run the experiment against `prepared` and collect its
    /// metrics. `prepared` must have come from [`Scenario::prepare`] on
    /// a config with the same [`Scenario::cache_key`] as `cfg`.
    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report>;

    /// One-shot convenience: prepare + execute. This is the whole old
    /// single-phase API, kept as a default impl — do not override it.
    fn run(&self, cfg: &ExperimentConfig) -> Result<Report> {
        let prepared = self.prepare(cfg)?;
        self.execute(prepared.as_ref(), cfg)
    }
}

// ---- resource cache ------------------------------------------------------

/// Cache counters of a [`ResourceCache`] (or a delta between two
/// snapshots — see [`CacheStats::since`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// `get_or_prepare` calls served from an existing (or in-flight)
    /// prepared entry.
    pub hits: u64,
    /// Calls that had to run [`Scenario::prepare`].
    pub misses: u64,
    /// Entries evicted by the byte-accounted LRU (0 on an unbounded
    /// cache).
    pub evictions: u64,
    /// Bytes currently accounted resident (a snapshot, not a counter).
    pub resident_bytes: u64,
}

impl CacheStats {
    /// The counter delta since an `earlier` snapshot of the same cache.
    /// `resident_bytes` is a point-in-time gauge, so the later
    /// snapshot's value is kept as-is.
    pub fn since(self, earlier: CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            resident_bytes: self.resident_bytes,
        }
    }
}

/// State of one cache entry: prepared exactly once, then shared.
enum SlotState {
    Pending,
    Ready(Arc<dyn Prepared>),
    Failed(String),
}

/// Per-key latch: the first claimant prepares, everyone else waits on
/// the condvar. This is what makes hit/miss counts — and therefore sweep
/// artifacts — deterministic under `--jobs N`: concurrent requests for
/// one key are exactly one miss plus hits, never racing duplicate
/// prepares.
struct Slot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            state: Mutex::new(SlotState::Pending),
            ready: Condvar::new(),
        }
    }

    fn fulfill(&self, state: SlotState) {
        *self.state.lock().expect("cache slot poisoned") = state;
        self.ready.notify_all();
    }

    fn wait(&self) -> Result<Arc<dyn Prepared>> {
        let mut state = self.state.lock().expect("cache slot poisoned");
        loop {
            match &*state {
                SlotState::Pending => {
                    state = self.ready.wait(state).expect("cache slot poisoned");
                }
                SlotState::Ready(prepared) => return Ok(prepared.clone()),
                SlotState::Failed(e) => {
                    anyhow::bail!("shared prepare failed: {e}")
                }
            }
        }
    }
}

/// One resident cache entry: the shared latch plus the LRU/byte
/// bookkeeping. `bytes` is 0 while the slot is still `Pending` —
/// eviction never selects a pending entry, so an in-flight prepare can
/// never be yanked out from under its waiters.
struct Entry {
    slot: Arc<Slot>,
    last_used: u64,
    bytes: u64,
}

#[derive(Default)]
struct CacheInner {
    slots: HashMap<CacheKey, Entry>,
    /// Monotonic access clock for LRU ordering (bumped per lookup).
    tick: u64,
    /// Sum of `Entry::bytes` over all resident entries.
    resident_bytes: u64,
}

/// Shared cache of prepared scenario resources, keyed by
/// [`Scenario::cache_key`]. Contention-safe: callers on any number of
/// threads get one prepare per distinct key (see [`Slot`]).
///
/// ## Eviction
///
/// With a byte budget ([`ResourceCache::with_budget`]) the cache is a
/// byte-accounted LRU: each successful prepare charges
/// [`Prepared::resident_bytes`], and whenever the accounted total
/// exceeds the budget the least-recently-used *ready* entries are
/// dropped until it fits (an entry larger than the whole budget is
/// evicted immediately after insertion, so the accounted total never
/// stays over budget). Callers holding an `Arc` to an evicted entry
/// keep using it safely; a later request for the key simply re-runs
/// prepare. Re-prepare is byte-identical by the cache-key contract —
/// equal keys promise interchangeable resources — so eviction is
/// invisible to results, only to timing (gated in
/// `rust/tests/serve_mode.rs`).
#[derive(Default)]
pub struct ResourceCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// LRU byte budget; `None` = unbounded (the batch/sweep default).
    budget: Option<u64>,
}

impl ResourceCache {
    /// An unbounded cache (no eviction) — the batch CLI and sweep
    /// runner default.
    pub fn new() -> ResourceCache {
        ResourceCache::default()
    }

    /// A byte-budgeted cache. `budget_bytes == 0` means unbounded
    /// (mirrors the `--cache-bytes 0` CLI spelling).
    pub fn with_budget(budget_bytes: u64) -> ResourceCache {
        ResourceCache {
            budget: (budget_bytes > 0).then_some(budget_bytes),
            ..ResourceCache::default()
        }
    }

    /// The configured byte budget (`None` = unbounded).
    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Prepared resources for `cfg`, building them via
    /// `scenario.prepare` on first use of the key. On a prepare error
    /// the key is vacated (so a later call can retry) and the error
    /// propagates to the owner and every waiter.
    pub fn get_or_prepare(
        &self,
        scenario: &dyn Scenario,
        cfg: &ExperimentConfig,
    ) -> Result<Arc<dyn Prepared>> {
        let key = scenario.cache_key(cfg);
        let (slot, owner) = {
            let mut inner = self.inner.lock().expect("cache map poisoned");
            inner.tick += 1;
            let tick = inner.tick;
            match inner.slots.get_mut(&key) {
                Some(entry) => {
                    entry.last_used = tick;
                    (entry.slot.clone(), false)
                }
                None => {
                    let slot = Arc::new(Slot::new());
                    inner.slots.insert(
                        key.clone(),
                        Entry {
                            slot: slot.clone(),
                            last_used: tick,
                            bytes: 0,
                        },
                    );
                    (slot, true)
                }
            }
        };
        if !owner {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return slot.wait();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);

        // A panic inside prepare (e.g. a machine-shape assert in
        // System::build) must not strand waiters on a Pending slot
        // forever: this guard fails the slot and vacates the key on
        // unwind. It stays panic-tolerant itself (no lock().expect()
        // while already unwinding — a poisoned lock would turn the
        // panic into an abort).
        struct PrepareGuard<'a> {
            cache: &'a ResourceCache,
            key: &'a CacheKey,
            slot: &'a Slot,
            armed: bool,
        }
        impl Drop for PrepareGuard<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                if let Ok(mut state) = self.slot.state.lock() {
                    *state = SlotState::Failed("prepare panicked".to_string());
                }
                self.slot.ready.notify_all();
                if let Ok(mut inner) = self.cache.inner.lock() {
                    inner.slots.remove(self.key);
                }
            }
        }
        let mut guard = PrepareGuard {
            cache: self,
            key: &key,
            slot: &slot,
            armed: true,
        };
        let prepared = scenario.prepare(cfg);
        guard.armed = false;
        drop(guard);

        match prepared {
            Ok(prepared) => {
                slot.fulfill(SlotState::Ready(prepared.clone()));
                self.account_and_evict(&key, prepared.resident_bytes().max(1));
                Ok(prepared)
            }
            Err(e) => {
                slot.fulfill(SlotState::Failed(format!("{e:#}")));
                self.inner
                    .lock()
                    .expect("cache map poisoned")
                    .slots
                    .remove(&key);
                Err(e)
            }
        }
    }

    /// Charge a freshly readied entry's bytes, then evict
    /// least-recently-used ready entries while the accounted total
    /// exceeds the budget. The just-inserted entry is itself a
    /// candidate (it is the LRU victim when it alone exceeds the
    /// budget), which keeps `resident_bytes ≤ budget` an invariant.
    fn account_and_evict(&self, key: &CacheKey, bytes: u64) {
        let mut inner = self.inner.lock().expect("cache map poisoned");
        // Still resident (failure is the only other remover, and this
        // entry succeeded): charge its real footprint.
        match inner.slots.get_mut(key) {
            Some(entry) => entry.bytes = bytes,
            None => return,
        }
        inner.resident_bytes += bytes;
        let Some(budget) = self.budget else { return };
        while inner.resident_bytes > budget {
            // LRU among ready entries only (bytes > 0 ⇔ accounted ⇔
            // the slot was fulfilled Ready).
            let victim = inner
                .slots
                .iter()
                .filter(|(_, e)| e.bytes > 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            let freed = inner.slots.remove(&victim).expect("victim vanished").bytes;
            inner.resident_bytes -= freed;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Cumulative counters plus the resident-byte gauge (snapshot).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: self
                .inner
                .lock()
                .expect("cache map poisoned")
                .resident_bytes,
        }
    }

    /// Number of resident prepared entries.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("cache map poisoned").slots.len()
    }

    /// Whether `key` is resident (or being prepared) right now. Only a
    /// point-in-time answer — another thread may evict or insert the
    /// key immediately after — so use it for labels/telemetry, never
    /// for correctness decisions.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.inner
            .lock()
            .expect("cache map poisoned")
            .slots
            .contains_key(key)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---- registry ------------------------------------------------------------

/// All registered scenarios, in listing order — one static table, no
/// per-call boxing (`find`/`names` and per-sweep-point lookups all
/// borrow from it).
///
/// Adding a scenario = implement [`Scenario`] + add one line here.
static REGISTRY: [&dyn Scenario; 9] = [
    &TrafficScenario,
    &MicrocircuitScenario,
    &MicrocircuitRackScenario,
    &BurstScenario,
    &HotspotScenario,
    &AnalyzeScenario,
    &FaultSweepScenario,
    &ReliabilitySweepScenario,
    &LatencyDistScenario,
];

/// All registered scenarios, in listing order.
pub fn registry() -> &'static [&'static dyn Scenario] {
    &REGISTRY
}

/// Look up a scenario by name.
pub fn find(name: &str) -> Option<&'static dyn Scenario> {
    registry().iter().copied().find(|s| s.name() == name)
}

/// Registered scenario names, in listing order.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|s| s.name()).collect()
}

// ---- analyze -------------------------------------------------------------

/// Declared metric schema of [`AnalyzeScenario`].
pub const ANALYZE_METRICS: &[MetricDecl] = &[
    MetricDecl::count("n_wafers", "wafers"),
    MetricDecl::text("torus"),
    MetricDecl::count("neurons", "neurons"),
    MetricDecl::real("total_spike_rate", "events/s"),
    MetricDecl::count("fabric_flows", "flows"),
    MetricDecl::real("offered_load", "Gbit/s"),
    MetricDecl::real("max_link_util", "1"),
    MetricDecl::real("mean_active_link_util", "1"),
    MetricDecl::real("sustainable_fraction", "1"),
    MetricDecl::text("bottleneck"),
];

/// Prepared resources of [`AnalyzeScenario`]: the microcircuit-derived
/// fabric flow table (placement + traffic matrix), which depends only on
/// the machine shape and `mc_scale` — not on the NIC link rate the
/// analysis itself sweeps.
pub struct AnalyzePrepared {
    flows: Vec<Flow>,
    n_neurons: u32,
    total_spike_rate_hz: f64,
}

impl Prepared for AnalyzePrepared {
    fn as_any(&self) -> &dyn Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        (std::mem::size_of::<AnalyzePrepared>()
            + self.flows.len() * std::mem::size_of::<Flow>()) as u64
    }
}

/// Flow-level topology bandwidth analysis (paper Fig. 1): route the
/// cortical-microcircuit traffic matrix over the configured torus and
/// report utilizations and the saturation bottleneck — no packet
/// simulation involved.
pub struct AnalyzeScenario;

impl Scenario for AnalyzeScenario {
    fn name(&self) -> &'static str {
        "analyze"
    }

    fn about(&self) -> &'static str {
        "flow-level torus bandwidth analysis of microcircuit traffic"
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        ANALYZE_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        machine_shape_fields(
            CacheKey::new("analyze_flows").field("mc_scale", cfg.workload.mc_scale),
            cfg,
        )
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        // a throwaway system instance: only its endpoint layout feeds the
        // placement; nothing is simulated
        let mut sim: Sim<Msg> = Sim::new();
        let sys = System::build(&mut sim, cfg.system);
        let mc = Microcircuit::new(cfg.workload.mc_scale);
        let placement = Placement::spread(&mc, &sys);
        let flows = placement.flows(&mc, 32.0);
        Ok(Arc::new(AnalyzePrepared {
            flows,
            n_neurons: mc.total_neurons(),
            total_spike_rate_hz: mc.total_rate_hz(),
        }))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let prep: &AnalyzePrepared = downcast_prepared(prepared, self.name())?;
        let analysis =
            FlowAnalysis::run(&cfg.system.torus, &prep.flows, cfg.system.nic.link_gbps());

        let mut r = Report::with_schema(self.name(), self.metrics());
        r.push_unit("n_wafers", cfg.system.n_wafers, "wafers");
        r.push(
            "torus",
            format!(
                "{}x{}x{}",
                cfg.system.torus.nx, cfg.system.torus.ny, cfg.system.torus.nz
            ),
        );
        r.push_unit("neurons", prep.n_neurons, "neurons");
        r.push_unit("total_spike_rate", prep.total_spike_rate_hz, "events/s");
        r.push_unit("fabric_flows", prep.flows.len(), "flows");
        r.push_unit("offered_load", analysis.total_offered_gbps, "Gbit/s");
        r.push_unit("max_link_util", analysis.max_utilization(), "1");
        r.push_unit(
            "mean_active_link_util",
            analysis.mean_active_utilization(),
            "1",
        );
        r.push_unit(
            "sustainable_fraction",
            analysis.sustainable_fraction(),
            "1",
        );
        if let Some(((node, dir), load)) = analysis.bottleneck() {
            r.push(
                "bottleneck",
                format!("{node} {dir:?} @ {:.3} Gbit/s", load.gbps),
            );
        }
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::sim::Time;
    use crate::wafer::system::SystemConfig;

    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(200);
        cfg
    }

    #[test]
    fn registry_contains_required_scenarios() {
        let names = names();
        for required in [
            "traffic",
            "microcircuit",
            "microcircuit_rack",
            "burst",
            "hotspot",
            "analyze",
            "fault_sweep",
            "reliability_sweep",
            "latency_dist",
        ] {
            assert!(names.contains(&required), "missing scenario {required}");
        }
        assert!(names.len() >= 9);
    }

    #[test]
    fn registry_is_static_and_stable() {
        // the registry is one static table: repeated calls hand out the
        // same trait objects (no re-boxing per lookup)
        let a = registry();
        let b = registry();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            // compare data addresses (not vtable pointers, which may be
            // duplicated across codegen units)
            let xa = *x as *const dyn Scenario as *const ();
            let ya = *y as *const dyn Scenario as *const ();
            assert!(std::ptr::eq(xa, ya));
        }
    }

    #[test]
    fn registry_names_unique() {
        let mut names = names();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate scenario names");
    }

    #[test]
    fn every_scenario_declares_a_coherent_schema() {
        for s in registry() {
            let schema = s.metrics();
            assert!(!schema.is_empty(), "{}: empty metric schema", s.name());
            let mut seen = std::collections::BTreeSet::new();
            for d in schema {
                assert!(
                    seen.insert(d.name),
                    "{}: duplicate metric declaration '{}'",
                    s.name(),
                    d.name
                );
            }
        }
    }

    #[test]
    fn find_dispatches_by_name() {
        let s = find("traffic").expect("traffic registered");
        assert_eq!(s.name(), "traffic");
        assert!(!s.about().is_empty());
        assert!(find("no_such_scenario").is_none());
    }

    #[test]
    fn dispatched_run_produces_named_report() {
        let cfg = small();
        let report = find("traffic").unwrap().run(&cfg).unwrap();
        assert_eq!(report.scenario(), "traffic");
        assert!(report.get_count("events_generated").unwrap() > 0);
    }

    #[test]
    fn dispatch_is_deterministic() {
        let cfg = small();
        let a = find("burst").unwrap().run(&cfg).unwrap();
        let b = find("burst").unwrap().run(&cfg).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn run_equals_prepare_plus_execute() {
        // for every packetless-prepare scenario: the default-impl run()
        // and an explicit two-phase call are byte-identical
        let cfg = small();
        for name in ["traffic", "burst", "hotspot", "analyze"] {
            let s = find(name).unwrap();
            let one_shot = s.run(&cfg).unwrap();
            let prepared = s.prepare(&cfg).unwrap();
            let two_phase = s.execute(prepared.as_ref(), &cfg).unwrap();
            assert_eq!(
                one_shot.to_json().to_string(),
                two_phase.to_json().to_string(),
                "{name}: run() diverged from prepare+execute"
            );
        }
    }

    #[test]
    fn prepared_resources_are_reusable() {
        // one prepare, many executes: all byte-identical
        let cfg = small();
        let s = find("traffic").unwrap();
        let prepared = s.prepare(&cfg).unwrap();
        let first = s.execute(prepared.as_ref(), &cfg).unwrap();
        for _ in 0..2 {
            let again = s.execute(prepared.as_ref(), &cfg).unwrap();
            assert_eq!(first.to_json().to_string(), again.to_json().to_string());
        }
    }

    #[test]
    fn cache_key_ignores_execute_only_knobs() {
        let s = find("traffic").unwrap();
        let a = small();
        let mut b = small();
        b.workload.rate_hz *= 4.0;
        b.workload.duration = Time::from_us(400);
        b.domains = 2;
        assert_eq!(s.cache_key(&a), s.cache_key(&b));
        let mut c = small();
        c.workload.fan_out = 2;
        assert_ne!(s.cache_key(&a), s.cache_key(&c));
        let mut d = small();
        d.seed ^= 1;
        assert_ne!(s.cache_key(&a), s.cache_key(&d));
    }

    #[test]
    fn resource_cache_shares_prepared_entries() {
        let s = find("traffic").unwrap();
        let cache = ResourceCache::new();
        let a = small();
        let mut b = small();
        b.workload.rate_hz *= 2.0; // same cache key as `a`
        let pa = cache.get_or_prepare(s, &a).unwrap();
        let pb = cache.get_or_prepare(s, &b).unwrap();
        assert!(Arc::ptr_eq(&pa, &pb), "same key must share one Prepared");
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (1, 1, 0));
        assert!(st.resident_bytes > 0, "ready entries must be accounted");
        assert_eq!(cache.len(), 1);

        let mut c = small();
        c.workload.fan_out = 2; // key changes
        let pc = cache.get_or_prepare(s, &c).unwrap();
        assert!(!Arc::ptr_eq(&pa, &pc));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (1, 2, 0));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn resource_cache_is_contention_safe() {
        // many threads, one key: exactly one miss, one shared Arc
        let s = find("traffic").unwrap();
        let cache = ResourceCache::new();
        let cfg = small();
        let prepared: Vec<Arc<dyn Prepared>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(|| cache.get_or_prepare(s, &cfg).unwrap()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &prepared[1..] {
            assert!(Arc::ptr_eq(&prepared[0], p));
        }
        let stats = cache.stats();
        assert_eq!(stats.misses, 1, "duplicate prepare under contention");
        assert_eq!(stats.hits, 7);
    }

    #[test]
    fn failed_prepare_vacates_the_key() {
        let s = find("microcircuit").unwrap();
        let cache = ResourceCache::new();
        let mut cfg = ExperimentConfig::default();
        cfg.neuro.artifact = "no_such_artifact".to_string();
        assert!(cache.get_or_prepare(s, &cfg).is_err());
        assert!(cache.is_empty(), "failed key must not stay resident");
        // a retry runs prepare again (another miss, not a poisoned hit)
        assert!(cache.get_or_prepare(s, &cfg).is_err());
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn panicking_prepare_fails_waiters_instead_of_deadlocking() {
        let s = find("traffic").unwrap();
        let cache = ResourceCache::new();
        let mut cfg = small();
        // 5 FPGAs per wafer cannot divide over 2 concentrators: the
        // throwaway System::build inside prepare panics
        cfg.system.fpgas_per_wafer = 5;
        let outcomes: Vec<Result<(), ()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| {
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            cache.get_or_prepare(s, &cfg).map(|_| ()).map_err(|_| ())
                        }))
                        .unwrap_or(Err(()))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // nobody deadlocks: every call ends in a caught panic (owners)
        // or a "shared prepare failed" error (waiters)
        assert!(outcomes.iter().all(|o| o.is_err()));
        assert!(cache.is_empty(), "panicked key must be vacated");
    }

    /// Fixed-footprint scenario for deterministic eviction tests: every
    /// prepared entry charges exactly `BYTES`, keyed by `cfg.seed`.
    struct BytePrepared(u64);
    impl Prepared for BytePrepared {
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn resident_bytes(&self) -> u64 {
            BYTE_SCENARIO_BYTES
        }
    }
    const BYTE_SCENARIO_BYTES: u64 = 100;
    struct ByteScenario;
    impl Scenario for ByteScenario {
        fn name(&self) -> &'static str {
            "byte_test"
        }
        fn about(&self) -> &'static str {
            "eviction test fixture"
        }
        fn metrics(&self) -> &'static [MetricDecl] {
            &[MetricDecl::count("seed", "1")]
        }
        fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
            CacheKey::new("byte_test").field("seed", cfg.seed)
        }
        fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
            Ok(Arc::new(BytePrepared(cfg.seed)))
        }
        fn execute(&self, prepared: &dyn Prepared, _cfg: &ExperimentConfig) -> Result<Report> {
            let p: &BytePrepared = downcast_prepared(prepared, self.name())?;
            let mut r = Report::with_schema(self.name(), self.metrics());
            r.push_unit("seed", p.0, "1");
            Ok(r)
        }
    }

    fn seeded(seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn eviction_respects_byte_budget() {
        // budget fits two 100-byte entries; a third insert evicts the LRU
        let cache = ResourceCache::with_budget(2 * BYTE_SCENARIO_BYTES + 50);
        assert_eq!(cache.budget(), Some(250));
        for seed in 1..=5u64 {
            cache.get_or_prepare(&ByteScenario, &seeded(seed)).unwrap();
            assert!(
                cache.stats().resident_bytes <= 250,
                "resident bytes exceeded budget"
            );
        }
        let st = cache.stats();
        assert_eq!((st.misses, st.evictions), (5, 3));
        assert_eq!(cache.len(), 2);
        assert_eq!(st.resident_bytes, 2 * BYTE_SCENARIO_BYTES);
    }

    #[test]
    fn eviction_is_least_recently_used() {
        let cache = ResourceCache::with_budget(2 * BYTE_SCENARIO_BYTES);
        cache.get_or_prepare(&ByteScenario, &seeded(1)).unwrap(); // miss
        cache.get_or_prepare(&ByteScenario, &seeded(2)).unwrap(); // miss
        cache.get_or_prepare(&ByteScenario, &seeded(1)).unwrap(); // hit: 1 now MRU
        cache.get_or_prepare(&ByteScenario, &seeded(3)).unwrap(); // miss: evicts 2
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.evictions), (1, 3, 1));
        // 1 survived (hit), 2 was the LRU victim (miss on re-request)
        cache.get_or_prepare(&ByteScenario, &seeded(1)).unwrap();
        assert_eq!(cache.stats().hits, 2, "key 1 should have stayed resident");
        cache.get_or_prepare(&ByteScenario, &seeded(2)).unwrap();
        assert_eq!(cache.stats().misses, 4, "key 2 should have been evicted");
    }

    #[test]
    fn oversized_entry_never_leaves_accounting_over_budget() {
        // one entry alone exceeds the budget: it is admitted (the caller
        // holds the Arc) but immediately evicted from the accounting
        let cache = ResourceCache::with_budget(BYTE_SCENARIO_BYTES / 2);
        let p = cache.get_or_prepare(&ByteScenario, &seeded(7)).unwrap();
        let st = cache.stats();
        assert_eq!((st.misses, st.evictions), (1, 1));
        assert_eq!(st.resident_bytes, 0);
        assert!(cache.is_empty());
        // the caller's Arc stays valid regardless
        let r = ByteScenario.execute(p.as_ref(), &seeded(7)).unwrap();
        assert_eq!(r.get_count("seed").unwrap(), 7);
    }

    #[test]
    fn eviction_then_reprepare_is_byte_identical() {
        // the CacheKey ⇒ Prepared interchangeability contract in action:
        // evicting a real fabric plan and re-preparing it must change
        // nothing about the resulting report bytes
        let s = find("traffic").unwrap();
        let cfg = small();
        let unlimited = ResourceCache::new();
        let p1 = unlimited.get_or_prepare(s, &cfg).unwrap();
        let baseline = s.execute(p1.as_ref(), &cfg).unwrap();

        let tiny = ResourceCache::with_budget(1);
        let p2 = tiny.get_or_prepare(s, &cfg).unwrap();
        assert!(tiny.is_empty(), "tiny budget must evict immediately");
        let evicted_run = s.execute(p2.as_ref(), &cfg).unwrap();
        let p3 = tiny.get_or_prepare(s, &cfg).unwrap();
        assert!(!Arc::ptr_eq(&p2, &p3), "re-request must re-prepare");
        let reprepared_run = s.execute(p3.as_ref(), &cfg).unwrap();
        assert_eq!(tiny.stats().misses, 2);

        let want = baseline.to_json().to_string();
        assert_eq!(want, evicted_run.to_json().to_string());
        assert_eq!(want, reprepared_run.to_json().to_string());
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let cache = ResourceCache::new();
        assert_eq!(cache.budget(), None);
        for seed in 0..16u64 {
            cache.get_or_prepare(&ByteScenario, &seeded(seed)).unwrap();
        }
        let st = cache.stats();
        assert_eq!(st.evictions, 0);
        assert_eq!(cache.len(), 16);
        assert_eq!(st.resident_bytes, 16 * BYTE_SCENARIO_BYTES);
        // with_budget(0) is the same spelling of "unbounded"
        assert_eq!(ResourceCache::with_budget(0).budget(), None);
    }

    #[test]
    fn cache_key_display_is_stable() {
        let k = CacheKey::new("fam").field("a", 1).field("b", "x");
        assert_eq!(k.to_string(), "fam;a=1;b=x");
        assert_eq!(k.family(), "fam");
    }

    #[test]
    fn analyze_scenario_reports_flow_metrics() {
        let mut cfg = small();
        cfg.workload.mc_scale = 0.1;
        let r = AnalyzeScenario.run(&cfg).unwrap();
        assert_eq!(r.scenario(), "analyze");
        assert!(r.get_count("fabric_flows").unwrap() > 0);
        assert!(r.get_f64("offered_load").unwrap() > 0.0);
        assert!(r.get_f64("max_link_util").unwrap() > 0.0);
        let s = r.get_f64("sustainable_fraction").unwrap();
        assert!(s > 0.0 && s <= 1.0);
        assert!(r.get("bottleneck").is_some());
    }
}
