//! The `Scenario` experiment API: trait, registry, generic dispatch.
//!
//! A scenario is one self-contained experiment: it consumes an
//! [`ExperimentConfig`], drives whatever machinery it needs (packet-level
//! DES, neural co-simulation, flow-level analysis), and returns a unified
//! metric-keyed [`Report`]. The CLI (`bss-extoll run <scenario>`), the
//! sweep runner and tests all dispatch through the [`registry`], so adding
//! a scenario is one type + one registry line.
//!
//! ## Contract
//!
//! - [`Scenario::name`] is the stable CLI identifier (lowercase, no
//!   spaces) and the `scenario` field of the resulting [`Report`].
//! - [`Scenario::run`] must be **deterministic**: the same config
//!   (including `seed`) must produce the same report. Draw all randomness
//!   from an [`crate::util::rng::Rng`] seeded with `cfg.seed`.
//! - Fabric-driven scenarios should implement
//!   [`super::traffic::FabricScenario`] (a build/collect split) and let
//!   [`super::traffic::run_fabric_scenario`] own the simulation loop, so
//!   every scenario reports the same standard communication metrics.

use anyhow::Result;

use crate::extoll::analysis::FlowAnalysis;
use crate::msg::Msg;
use crate::sim::Sim;
use crate::util::report::Report;
use crate::wafer::system::System;
use crate::workload::microcircuit::{Microcircuit, Placement};

use super::config::ExperimentConfig;
use super::microcircuit::MicrocircuitScenario;
use super::traffic::{BurstScenario, HotspotScenario, TrafficScenario};

/// One registered experiment.
///
/// `Send + Sync` is part of the contract: the parallel sweep runner
/// (`sweep --jobs N`) calls [`Scenario::run`] concurrently from worker
/// threads, so scenarios must keep all run state local to `run` (every
/// registered scenario is a stateless unit struct).
pub trait Scenario: Send + Sync {
    /// Stable identifier used by the CLI and the report.
    fn name(&self) -> &'static str;

    /// One-line description for `bss-extoll run --list`.
    fn about(&self) -> &'static str;

    /// The config the CLI starts from when the user supplies none.
    /// Scenarios with machine-shape requirements (e.g. the microcircuit
    /// must match its artifact's shard count) override this.
    fn default_config(&self) -> ExperimentConfig {
        ExperimentConfig::default()
    }

    /// Execute the experiment and collect its metrics.
    fn run(&self, cfg: &ExperimentConfig) -> Result<Report>;
}

/// All registered scenarios, in listing order.
///
/// Adding a scenario = implement [`Scenario`] + add one line here.
pub fn registry() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(TrafficScenario),
        Box::new(MicrocircuitScenario),
        Box::new(BurstScenario),
        Box::new(HotspotScenario),
        Box::new(AnalyzeScenario),
    ]
}

/// Look up a scenario by name.
pub fn find(name: &str) -> Option<Box<dyn Scenario>> {
    registry().into_iter().find(|s| s.name() == name)
}

/// Registered scenario names, in listing order.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|s| s.name()).collect()
}

// ---- analyze -------------------------------------------------------------

/// Flow-level topology bandwidth analysis (paper Fig. 1): route the
/// cortical-microcircuit traffic matrix over the configured torus and
/// report utilizations and the saturation bottleneck — no packet
/// simulation involved.
pub struct AnalyzeScenario;

impl Scenario for AnalyzeScenario {
    fn name(&self) -> &'static str {
        "analyze"
    }

    fn about(&self) -> &'static str {
        "flow-level torus bandwidth analysis of microcircuit traffic"
    }

    fn run(&self, cfg: &ExperimentConfig) -> Result<Report> {
        let mut sim: Sim<Msg> = Sim::new();
        let sys = System::build(&mut sim, cfg.system);
        let mc = Microcircuit::new(cfg.workload.mc_scale);
        let placement = Placement::spread(&mc, &sys);
        let flows = placement.flows(&mc, 32.0);
        let analysis = FlowAnalysis::run(&cfg.system.torus, &flows, cfg.system.nic.link_gbps());

        let mut r = Report::new(self.name());
        r.push_unit("n_wafers", cfg.system.n_wafers, "wafers");
        r.push(
            "torus",
            format!(
                "{}x{}x{}",
                cfg.system.torus.nx, cfg.system.torus.ny, cfg.system.torus.nz
            ),
        );
        r.push_unit("neurons", mc.total_neurons(), "neurons");
        r.push_unit("total_spike_rate", mc.total_rate_hz(), "events/s");
        r.push_unit("fabric_flows", flows.len(), "flows");
        r.push_unit("offered_load", analysis.total_offered_gbps, "Gbit/s");
        r.push_unit("max_link_util", analysis.max_utilization(), "1");
        r.push_unit(
            "mean_active_link_util",
            analysis.mean_active_utilization(),
            "1",
        );
        r.push_unit(
            "sustainable_fraction",
            analysis.sustainable_fraction(),
            "1",
        );
        if let Some(((node, dir), load)) = analysis.bottleneck() {
            r.push(
                "bottleneck",
                format!("{node} {dir:?} @ {:.3} Gbit/s", load.gbps),
            );
        }
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::TorusSpec;
    use crate::sim::Time;
    use crate::wafer::system::SystemConfig;

    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(200);
        cfg
    }

    #[test]
    fn registry_contains_required_scenarios() {
        let names = names();
        for required in ["traffic", "microcircuit", "burst", "hotspot"] {
            assert!(names.contains(&required), "missing scenario {required}");
        }
        assert!(names.len() >= 4);
    }

    #[test]
    fn registry_names_unique() {
        let mut names = names();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate scenario names");
    }

    #[test]
    fn find_dispatches_by_name() {
        let s = find("traffic").expect("traffic registered");
        assert_eq!(s.name(), "traffic");
        assert!(!s.about().is_empty());
        assert!(find("no_such_scenario").is_none());
    }

    #[test]
    fn dispatched_run_produces_named_report() {
        let cfg = small();
        let report = find("traffic").unwrap().run(&cfg).unwrap();
        assert_eq!(report.scenario(), "traffic");
        assert!(report.get_count("events_generated").unwrap() > 0);
    }

    #[test]
    fn dispatch_is_deterministic() {
        let cfg = small();
        let a = find("burst").unwrap().run(&cfg).unwrap();
        let b = find("burst").unwrap().run(&cfg).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn analyze_scenario_reports_flow_metrics() {
        let mut cfg = small();
        cfg.workload.mc_scale = 0.1;
        let r = AnalyzeScenario.run(&cfg).unwrap();
        assert_eq!(r.scenario(), "analyze");
        assert!(r.get_count("fabric_flows").unwrap() > 0);
        assert!(r.get_f64("offered_load").unwrap() > 0.0);
        assert!(r.get_f64("max_link_util").unwrap() > 0.0);
        let s = r.get_f64("sustainable_fraction").unwrap();
        assert!(s > 0.0 && s <= 1.0);
        assert!(r.get("bottleneck").is_some());
    }
}
