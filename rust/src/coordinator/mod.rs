//! Experiment coordination: configuration, the two-phase `Scenario` API,
//! the resource cache, the sweep runner, and unified result reporting.
//!
//! ## The `Scenario` API
//!
//! Experiments are orchestrated through the [`scenario::Scenario`] trait:
//!
//! ```text
//! trait Scenario {
//!     fn name(&self)      -> &'static str;             // CLI id + report tag
//!     fn about(&self)     -> &'static str;             // one-line description
//!     fn metrics(&self)   -> &'static [MetricDecl];    // declared report schema
//!     fn cache_key(&self, cfg) -> CacheKey;            // what prepare depends on
//!     fn prepare(&self, cfg)   -> Result<Arc<dyn Prepared>>; // expensive, immutable
//!     fn execute(&self, prepared, cfg) -> Result<Report>;    // the simulation
//!     fn run(&self, cfg)  -> Result<Report> { /* prepare + execute */ }
//! }
//! ```
//!
//! **Contract.** `name()` is the stable identifier used by
//! `bss-extoll run <scenario>` and stamped into the report. `prepare()`
//! builds the expensive immutable resources (artifact loads, weight
//! matrices, route plans, flow tables) and must depend only on the
//! config fields named by `cache_key()`; `execute()` runs the
//! simulation against them. Both must be deterministic for a fixed
//! config (derive all randomness from `cfg.seed`) and collect every
//! result into the schema-validated, metric-keyed
//! [`Report`](crate::util::report::Report) so the CLI table renderer,
//! the JSON emitter and the [`sweep::SweepRunner`] can handle any
//! scenario generically. The full lifecycle contract (cache-key
//! discipline, determinism rules) is documented in
//! `docs/ARCHITECTURE.md` §4 and the [`scenario`] module docs, which
//! also carry the migration note from the old single-phase `run` API.
//!
//! Scenarios that drive the packet-level simulator implement the
//! plan/collect split of [`traffic::FabricScenario`] instead and get the
//! prepare/execute machinery plus the standard communication metrics
//! from the shared driver ([`traffic::plan_fabric`] /
//! [`traffic::execute_fabric_plan`]).
//!
//! **Registry.** [`scenario::registry`] is one static table
//! (`&'static [&'static dyn Scenario]`); adding a scenario is a single
//! type implementing the trait plus one registry line. Registered
//! today: `traffic`, `microcircuit`, `microcircuit_rack`, `burst`,
//! `hotspot`, `analyze`, `fault_sweep`, `reliability_sweep`,
//! `latency_dist`.
//!
//! **Sweeps.** [`sweep::SweepRunner`] runs one scenario over a cartesian
//! grid of config overrides (`rate_hz=1e6,5e6 × n_wafers=2,4 × ...`) and
//! aggregates one report row per point into JSON/CSV artifacts. Points
//! share prepared resources through a [`scenario::ResourceCache`] keyed
//! by `cache_key()` — N points over one artifact load it once, also
//! under `sweep --jobs N`, whose scoped worker pool keeps result
//! ordering (and artifacts, including the surfaced cache hit/miss
//! counters) identical to the serial run.
//!
//! The pre-scenario entry points [`run_traffic`] / [`run_microcircuit`]
//! remain as deprecated thin wrappers for one release.

pub mod config;
pub mod faults;
pub mod microcircuit;
pub mod rack;
pub mod scenario;
pub mod sweep;
pub mod traffic;

pub use config::{ExperimentConfig, NeuroConfig, WorkloadConfig};
pub use faults::{
    FaultSweepScenario, LatencyDistScenario, FAULT_SWEEP_METRICS, LATENCY_DIST_METRICS,
};
pub use microcircuit::{
    shard_slices, MicrocircuitPrepared, MicrocircuitScenario, NeuroReport,
    MICROCIRCUIT_METRICS,
};
pub use rack::{MicrocircuitRackScenario, RACK_METRICS};
pub use scenario::{
    downcast_prepared, find, machine_shape_fields, names, registry, AnalyzeScenario,
    CacheKey, CacheStats, Prepared, ResourceCache, Scenario,
};
pub use sweep::{apply_override, parse_grid, SweepResult, SweepRunner};
pub use traffic::{
    execute_fabric_plan, plan_fabric, BurstScenario, FabricPlan, FabricScenario,
    FpgaPlan, HotspotScenario, TrafficReport, TrafficScenario, BURST_METRICS,
    HOTSPOT_METRICS, TRAFFIC_METRICS,
};

#[allow(deprecated)]
pub use microcircuit::run_microcircuit;
#[allow(deprecated)]
pub use traffic::run_traffic;
