//! Experiment coordination: configuration, the `Scenario` API, the sweep
//! runner, and unified result reporting.
//!
//! ## The `Scenario` API
//!
//! Experiments are orchestrated through the [`scenario::Scenario`] trait:
//!
//! ```text
//! trait Scenario {
//!     fn name(&self)  -> &'static str;            // CLI id + report tag
//!     fn about(&self) -> &'static str;            // one-line description
//!     fn run(&self, cfg: &ExperimentConfig) -> Result<Report>;
//! }
//! ```
//!
//! **Contract.** `name()` is the stable identifier used by
//! `bss-extoll run <scenario>` and stamped into the report. `run()`
//! must be deterministic for a fixed config (derive all randomness from
//! `cfg.seed`) and collect every result into the metric-keyed
//! [`Report`](crate::util::report::Report) so the CLI table renderer,
//! the JSON emitter and the [`sweep::SweepRunner`] can handle any
//! scenario generically.
//!
//! Scenarios that drive the packet-level simulator implement the
//! build/run/collect split of [`traffic::FabricScenario`] instead and get
//! the simulation loop plus the standard communication metrics from
//! [`traffic::run_fabric_scenario`].
//!
//! **Registry.** [`scenario::registry`] lists every scenario; adding one
//! is a single type implementing the trait plus one registry line.
//! Registered today: `traffic`, `microcircuit`, `burst`, `hotspot`,
//! `analyze`.
//!
//! **Sweeps.** [`sweep::SweepRunner`] runs one scenario over a cartesian
//! grid of config overrides (`rate_hz=1e6,5e6 × n_wafers=2,4 × ...`) and
//! aggregates one report row per point into JSON/CSV artifacts. Grid
//! points are independent simulations: `SweepRunner::jobs(n)` (CLI:
//! `sweep --jobs N`) evaluates them on a scoped worker pool with result
//! ordering — and therefore artifacts — identical to the serial run.
//!
//! The pre-scenario entry points [`run_traffic`] / [`run_microcircuit`]
//! remain as deprecated thin wrappers for one release.

pub mod config;
pub mod microcircuit;
pub mod scenario;
pub mod sweep;
pub mod traffic;

pub use config::{ExperimentConfig, NeuroConfig, WorkloadConfig};
pub use microcircuit::{shard_slices, MicrocircuitScenario, NeuroReport};
pub use scenario::{find, names, registry, AnalyzeScenario, Scenario};
pub use sweep::{apply_override, parse_grid, SweepResult, SweepRunner};
pub use traffic::{
    run_fabric_scenario, BurstScenario, FabricScenario, HotspotScenario, TrafficReport,
    TrafficScenario,
};

#[allow(deprecated)]
pub use microcircuit::run_microcircuit;
#[allow(deprecated)]
pub use traffic::run_traffic;
