//! Experiment coordination: configuration, orchestration of the simulated
//! machine + PJRT neuron shards, and result reporting.

pub mod config;
pub mod microcircuit;
pub mod traffic;

pub use config::{ExperimentConfig, NeuroConfig, WorkloadConfig};
pub use microcircuit::{run_microcircuit, shard_slices, NeuroReport};
pub use traffic::{run_traffic, TrafficReport};
