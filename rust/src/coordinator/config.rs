//! Experiment configuration: JSON-backed, with sensible defaults for every
//! knob so configs only state what they change.
//!
//! Per-knob tuning guidance (when to flip `queue=`, `jobs=`, `domains=`,
//! and every other `--set` key) lives in `docs/TUNING.md`; the engine and
//! layering contract behind them in `docs/ARCHITECTURE.md`.

use anyhow::{Context, Result};

use crate::extoll::link::{LinkReliabilityConfig, Reliability};
use crate::extoll::nic::NicConfig;
use crate::extoll::torus::TorusSpec;
use crate::fault::FaultConfig;
use crate::fpga::bucket::BucketConfig;
use crate::fpga::manager::{EvictionPolicy, ManagerConfig};
use crate::sim::{QueueKind, SyncMode, Time};
use crate::util::json::Json;
use crate::wafer::system::SystemConfig;
use crate::workload::generators::GeneratorKind;

/// Fabric reuse across executes (the `reuse=` knob).
///
/// `fabric` (default) parks the built `Sim` + `System` of a finished
/// fabric execute in a thread-local pool; the next execute with an
/// identical fabric plan (same machine, fault set, seed, queue) rewinds
/// it with [`crate::sim::Sim::reset_to_epoch`] instead of re-allocating
/// and re-wiring every actor. `off` cold-builds every time. Reports are
/// byte-identical in both modes — reset restores the exact post-build
/// state, and the reset-vs-rebuild axis is swept by the differential
/// harness (`rust/tests/differential_sync.rs`). See docs/TUNING.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReuseMode {
    /// Cold-build the fabric for every execute.
    Off,
    /// Reset-and-reuse the previous execute's fabric when the plan matches.
    #[default]
    Fabric,
}

impl ReuseMode {
    pub fn as_str(self) -> &'static str {
        match self {
            ReuseMode::Off => "off",
            ReuseMode::Fabric => "fabric",
        }
    }

    pub fn parse(s: &str) -> Option<ReuseMode> {
        match s {
            "off" => Some(ReuseMode::Off),
            "fabric" => Some(ReuseMode::Fabric),
            _ => None,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Simulated machine.
    pub system: SystemConfig,
    /// Workload parameters (traffic experiments).
    pub workload: WorkloadConfig,
    /// Neural co-simulation parameters (microcircuit experiments).
    pub neuro: NeuroConfig,
    /// RNG seed for everything derived.
    pub seed: u64,
    /// Event-queue backend for the discrete-event simulation
    /// (`wheel` default; `heap` kept for A/B benchmarking — PERF.md).
    pub queue: QueueKind,
    /// PDES domain count for fabric scenarios: `1` (default) runs the
    /// classic serial event loop; `N > 1` partitions the torus into `N`
    /// conservatively synchronized domains advanced on worker threads
    /// (clamped to the node count; reports are byte-identical either
    /// way — see docs/TUNING.md and docs/ARCHITECTURE.md).
    pub domains: usize,
    /// PDES synchronization protocol for partitioned runs (`domains > 1`):
    /// `channel` (default) bounds each domain by the per-neighbor CMB
    /// channel clocks of every domain that can reach it (accumulated
    /// path lookahead); `free` uses the same bounds with no barriers at
    /// all (lock-free per-channel queues + published EOT atomics — best
    /// for sparse traffic); `window` is the lock-step global-minimum
    /// reference protocol. Byte-identical reports in every mode
    /// (docs/ARCHITECTURE.md §2.3); no effect at `domains = 1`.
    pub sync: SyncMode,
    /// Fault injection: link failure/degradation schedules plus
    /// stochastic packet loss and latency jitter (default: none — the
    /// build is then byte-identical to the pre-fault fabric). Set from a
    /// config `"fault"` object or the `--set fault=` spec string
    /// (`docs/TUNING.md`).
    pub fault: FaultConfig,
    /// Fabric reuse across executes (`fabric` default, `off` to force
    /// cold rebuilds) — see [`ReuseMode`].
    pub reuse: ReuseMode,
}

/// Spike-traffic workload knobs.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Aggregate event rate per FPGA (events/s).
    pub rate_hz: f64,
    /// Sources per FPGA (spread over the 8 HICANN links).
    pub sources_per_fpga: usize,
    /// Fan-out: destination FPGAs per source.
    pub fan_out: usize,
    /// Zipf skew of destination popularity (0 = uniform).
    pub zipf_s: f64,
    /// Deadline offset in systime units (210 MHz cycles).
    pub deadline_offset: u16,
    /// Simulated duration.
    pub duration: Time,
    /// Traffic generator kind (scenario-selectable; "poisson" default).
    pub generator: GeneratorKind,
    /// Events per burst (burst generator only).
    pub burst_len: u32,
    /// Microcircuit scale for the flow-level `analyze` scenario
    /// (1.0 = the full 77k-neuron circuit).
    pub mc_scale: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            rate_hz: 10e6,
            sources_per_fpga: 64,
            fan_out: 1,
            zipf_s: 0.0,
            deadline_offset: 2000,
            duration: Time::from_ms(2),
            generator: GeneratorKind::Poisson,
            burst_len: 64,
            mc_scale: 1.0,
        }
    }
}

/// Neural co-simulation knobs.
#[derive(Clone, Debug)]
pub struct NeuroConfig {
    /// Artifact name (must exist under `artifacts/`).
    pub artifact: String,
    /// Timesteps to run.
    pub steps: usize,
    /// Hardware time per neural timestep.
    pub dt: Time,
    /// Excitatory / inhibitory synaptic efficacies.
    pub w_exc: f32,
    pub w_inh: f32,
    /// Connection-probability scale (compensates down-scaled networks).
    pub k_scale: f64,
    /// Initial membrane potential range (uniform).
    pub v_init: (f32, f32),
}

impl Default for NeuroConfig {
    fn default() -> Self {
        NeuroConfig {
            artifact: "shard_256x1024".to_string(),
            steps: 200,
            dt: Time::from_us(1),
            w_exc: 6.0,
            w_inh: -24.0,
            k_scale: 1.0,
            v_init: (0.0, 1.1),
        }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            system: SystemConfig::default(),
            workload: WorkloadConfig::default(),
            neuro: NeuroConfig::default(),
            seed: 0xB55,
            queue: QueueKind::default(),
            domains: 1,
            sync: SyncMode::default(),
            fault: FaultConfig::default(),
            reuse: ReuseMode::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON document; missing fields keep their defaults.
    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig {
            seed: j.u64_or("seed", 0xB55),
            queue: {
                let name = j.str_or("queue", QueueKind::default().as_str());
                QueueKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown queue kind '{name}' (heap|wheel)"))?
            },
            domains: {
                let d = j.u64_or("domains", 1) as usize;
                anyhow::ensure!(d >= 1, "domains must be >= 1");
                d
            },
            sync: {
                let name = j.str_or("sync", SyncMode::default().as_str());
                SyncMode::parse(name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown sync mode '{name}' (window|channel|free)")
                    })?
            },
            reuse: {
                let name = j.str_or("reuse", ReuseMode::default().as_str());
                ReuseMode::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown reuse mode '{name}' (off|fabric)"))?
            },
            ..ExperimentConfig::default()
        };
        if let Some(sys) = j.get("system") {
            let d = SystemConfig::default();
            let tor = sys.get("torus");
            let dims = |i: usize, dflt: u16| -> u16 {
                tor.and_then(|t| t.as_arr())
                    .and_then(|a| a.get(i))
                    .and_then(Json::as_u64)
                    .map(|v| v as u16)
                    .unwrap_or(dflt)
            };
            cfg.system = SystemConfig {
                n_wafers: sys.usize_or("n_wafers", d.n_wafers),
                torus: TorusSpec::new(dims(0, 4), dims(1, 2), dims(2, 2)),
                fpgas_per_wafer: sys.usize_or("fpgas_per_wafer", d.fpgas_per_wafer),
                concentrators_per_wafer: sys
                    .usize_or("concentrators_per_wafer", d.concentrators_per_wafer),
                fpga_egress_gbps: sys.f64_or("fpga_egress_gbps", d.fpga_egress_gbps),
                nic: NicConfig {
                    lanes: sys.u64_or("nic_lanes", 12) as u32,
                    credits_per_vc: sys.u64_or("nic_credits", 8) as u32,
                    retx: {
                        let dr = LinkReliabilityConfig::default();
                        LinkReliabilityConfig {
                            window: sys.u64_or("retx_window", dr.window as u64) as u32,
                            timeout: Time::from_ns(
                                sys.u64_or("retx_timeout_ns", dr.timeout.ps() / 1000),
                            ),
                            max_retries: sys.u64_or("retx_max_retries", dr.max_retries as u64)
                                as u32,
                            backoff_cap: sys.u64_or("retx_backoff_cap", dr.backoff_cap as u64)
                                as u32,
                        }
                    },
                    ..NicConfig::default()
                },
                manager: ManagerConfig {
                    n_buckets: sys.usize_or("buckets", 32),
                    bucket: BucketConfig {
                        capacity: sys.usize_or("bucket_capacity", 124),
                        deadline_margin: sys.u64_or("deadline_margin", 420) as u16,
                        concurrent: sys.bool_or("concurrent_flush", true),
                    },
                    eviction: match sys.str_or("eviction", "most_urgent") {
                        "most_urgent" => EvictionPolicy::MostUrgent,
                        "fullest" => EvictionPolicy::Fullest,
                        "oldest" => EvictionPolicy::Oldest,
                        "round_robin" => EvictionPolicy::RoundRobin,
                        other => anyhow::bail!("unknown eviction policy '{other}'"),
                    },
                },
                ..d
            };
        }
        if let Some(w) = j.get("workload") {
            let d = WorkloadConfig::default();
            cfg.workload = WorkloadConfig {
                rate_hz: w.f64_or("rate_hz", d.rate_hz),
                sources_per_fpga: w.usize_or("sources_per_fpga", d.sources_per_fpga),
                fan_out: w.usize_or("fan_out", d.fan_out),
                zipf_s: w.f64_or("zipf_s", d.zipf_s),
                deadline_offset: w.u64_or("deadline_offset", d.deadline_offset as u64) as u16,
                duration: Time::from_secs_f64(w.f64_or("duration_s", 2e-3)),
                generator: {
                    let name = w.str_or("generator", d.generator.as_str());
                    GeneratorKind::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown generator '{name}'"))?
                },
                burst_len: w.u64_or("burst_len", d.burst_len as u64) as u32,
                mc_scale: w.f64_or("mc_scale", d.mc_scale),
            };
        }
        // Top-level like `queue`/`sync` (it selects a protocol, not a
        // machine dimension), applied after the `system` block so it
        // composes with `retx_*` knobs from either source.
        {
            let name = j.str_or("reliability", Reliability::default().as_str());
            cfg.system.nic.reliability = Reliability::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown reliability mode '{name}' (off|link)"))?;
        }
        if let Some(f) = j.get("fault") {
            cfg.fault = FaultConfig::from_json(f).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(n) = j.get("neuro") {
            let d = NeuroConfig::default();
            cfg.neuro = NeuroConfig {
                artifact: n.str_or("artifact", &d.artifact).to_string(),
                steps: n.usize_or("steps", d.steps),
                dt: Time::from_secs_f64(n.f64_or("dt_s", 1e-6)),
                w_exc: n.f64_or("w_exc", d.w_exc as f64) as f32,
                w_inh: n.f64_or("w_inh", d.w_inh as f64) as f32,
                k_scale: n.f64_or("k_scale", d.k_scale),
                v_init: (
                    n.f64_or("v_init_lo", 0.0) as f32,
                    n.f64_or("v_init_hi", 1.1) as f32,
                ),
            };
        }
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        Self::from_json(&j)
    }

    /// Apply a `"key=v;key=v"` override list onto this config — the CLI
    /// `--set` form and the service-mode submission `set` field share
    /// this one parser (keys are the sweep-axis keys of
    /// [`super::sweep::apply_override`]).
    pub fn apply_set(&mut self, spec: &str) -> Result<()> {
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("set entry '{part}' is not key=value"))?;
            super::sweep::apply_override(self, key.trim(), value.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_json() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.system.n_wafers, 2);
        assert_eq!(cfg.workload.fan_out, 1);
        assert_eq!(cfg.neuro.artifact, "shard_256x1024");
    }

    #[test]
    fn overrides_apply() {
        let j = Json::parse(
            r#"{
                "seed": 7,
                "system": {"n_wafers": 1, "torus": [2,2,2], "buckets": 16,
                           "eviction": "fullest", "concurrent_flush": false},
                "workload": {"rate_hz": 5e6, "fan_out": 3, "duration_s": 1e-3},
                "neuro": {"steps": 10, "w_exc": 2.5}
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.system.n_wafers, 1);
        assert_eq!(cfg.system.torus.n_nodes(), 8);
        assert_eq!(cfg.system.manager.n_buckets, 16);
        assert_eq!(cfg.system.manager.eviction, EvictionPolicy::Fullest);
        assert!(!cfg.system.manager.bucket.concurrent);
        assert_eq!(cfg.workload.fan_out, 3);
        assert_eq!(cfg.workload.duration, Time::from_ms(1));
        assert_eq!(cfg.neuro.steps, 10);
        assert_eq!(cfg.neuro.w_exc, 2.5);
    }

    #[test]
    fn domains_knob_parses() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.domains, 1);
        let j = Json::parse(r#"{"domains": 4}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().domains, 4);
        let j = Json::parse(r#"{"domains": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn sync_knob_parses() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.sync, SyncMode::Channel);
        let j = Json::parse(r#"{"sync": "window"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().sync, SyncMode::Window);
        let j = Json::parse(r#"{"sync": "channel"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().sync, SyncMode::Channel);
        let j = Json::parse(r#"{"sync": "free"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().sync, SyncMode::Free);
        let j = Json::parse(r#"{"sync": "global"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn reuse_knob_parses() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.reuse, ReuseMode::Fabric, "reuse defaults on");
        let j = Json::parse(r#"{"reuse": "off"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().reuse, ReuseMode::Off);
        let j = Json::parse(r#"{"reuse": "fabric"}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&j).unwrap().reuse,
            ReuseMode::Fabric
        );
        let j = Json::parse(r#"{"reuse": "always"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn queue_kind_parses() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.queue, QueueKind::Wheel);
        let j = Json::parse(r#"{"queue": "heap"}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&j).unwrap().queue,
            QueueKind::Heap
        );
        let j = Json::parse(r#"{"queue": "splay"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn fault_knob_parses() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.fault.is_default());
        let j = Json::parse(r#"{"fault": {"fail": 0.25, "loss": 0.01, "jitter_ns": 50}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.fault.fail, 0.25);
        assert_eq!(cfg.fault.loss, 0.01);
        assert_eq!(cfg.fault.jitter_ns, 50.0);
        let j = Json::parse(r#"{"fault": {"fail": 1.5}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"fault": {"bogus": 1}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn reliability_knob_parses() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.system.nic.reliability, Reliability::Off);
        let j = Json::parse(r#"{"reliability": "link"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.system.nic.reliability, Reliability::Link);
        assert_eq!(cfg.system.nic.retx, LinkReliabilityConfig::default());
        let j = Json::parse(
            r#"{"reliability": "link",
                "system": {"retx_window": 8, "retx_timeout_ns": 750,
                           "retx_max_retries": 4, "retx_backoff_cap": 2}}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.system.nic.reliability, Reliability::Link);
        assert_eq!(cfg.system.nic.retx.window, 8);
        assert_eq!(cfg.system.nic.retx.timeout, Time::from_ns(750));
        assert_eq!(cfg.system.nic.retx.max_retries, 4);
        assert_eq!(cfg.system.nic.retx.backoff_cap, 2);
        let j = Json::parse(r#"{"reliability": "tcp"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn apply_set_parses_override_lists() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_set("rate_hz=5e6; fan_out=2 ;seed=9").unwrap();
        assert_eq!(cfg.workload.rate_hz, 5e6);
        assert_eq!(cfg.workload.fan_out, 2);
        assert_eq!(cfg.seed, 9);
        // empty entries are tolerated, malformed ones are not
        cfg.apply_set("").unwrap();
        cfg.apply_set(";;").unwrap();
        assert!(cfg.apply_set("rate_hz").is_err());
        assert!(cfg.apply_set("no_such_knob=1").is_err());
    }

    #[test]
    fn bad_eviction_rejected() {
        let j = Json::parse(r#"{"system": {"eviction": "bogus"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn generator_kind_parses() {
        let j = Json::parse(r#"{"workload": {"generator": "burst", "burst_len": 16}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workload.generator, GeneratorKind::Burst);
        assert_eq!(cfg.workload.burst_len, 16);
        assert_eq!(
            ExperimentConfig::from_json(&Json::parse("{}").unwrap())
                .unwrap()
                .workload
                .generator,
            GeneratorKind::Poisson
        );
        let j = Json::parse(r#"{"workload": {"generator": "bogus"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }
}

#[cfg(test)]
mod config_file_tests {
    use super::*;

    /// Every shipped example config must load and be internally coherent.
    #[test]
    fn shipped_configs_parse() {
        for name in [
            "configs/traffic_2wafer.json",
            "configs/microcircuit_4shard.json",
            "configs/microcircuit_rack.json",
            "configs/eviction_ablation.json",
            "configs/fault_lossy.json",
            "configs/fault_degraded.json",
        ] {
            let cfg = ExperimentConfig::from_file(name)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(
                cfg.system.torus.n_nodes()
                    >= cfg.system.n_wafers * cfg.system.concentrators_per_wafer,
                "{name}: torus too small"
            );
            assert!(cfg.system.fpgas_per_wafer % cfg.system.concentrators_per_wafer == 0);
        }
    }

    #[test]
    fn microcircuit_config_matches_artifact_layout() {
        let cfg = ExperimentConfig::from_file("configs/microcircuit_4shard.json").unwrap();
        assert_eq!(cfg.neuro.artifact, "shard_256x1024");
        // 4 shards expected by the 256x1024 artifact
        assert_eq!(cfg.system.n_wafers * cfg.system.fpgas_per_wafer, 4);
    }
}
