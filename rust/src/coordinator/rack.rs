//! The `microcircuit_rack` scenario: cortical-microcircuit-patterned
//! spike load at rack scale.
//!
//! The paper's target deployment is a rack of 20 wafer modules bridged
//! by the Extoll torus; the natural workload at that scale is many
//! copies of the 77k-neuron cortical microcircuit whose connectivity is
//! dominated by *local* projections, with a long-range tail. This
//! scenario models that shape on the packet-level fabric: every FPGA
//! hosts `sources_per_fpga` neurons, and each neuron fans out to
//! `fan_out` destination FPGAs drawn Zipf(`zipf_s`) over the *distance
//! rank* of the other FPGAs (rank 0 = nearest by endpoint index, i.e.
//! same wafer first) — high skew concentrates traffic on wafer-local
//! links exactly like the microcircuit's connection-probability
//! falloff, while `zipf_s = 0` degrades to uniform all-to-all.
//!
//! On top of the standard fabric metrics the report carries the
//! rack-scale memory/communication figures of merit: the neuron count,
//! total wire bytes injected, wire **bytes per neuron**, and the
//! resident bytes of the prepared plan (the quantity the byte-accounted
//! [`super::scenario::ResourceCache`] charges). As a
//! [`FabricScenario`] it inherits the shared driver end to end — plan
//! caching, PDES partitioning, and the `reuse=fabric` rewind pool — so
//! the rack runs byte-identically at any `domains`/`sync`/`reuse`
//! combination (gated by `rust/tests/differential_sync.rs`).

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::Result;

use crate::extoll::torus::TorusSpec;
use crate::fpga::fpga::Fpga;
use crate::fpga::lookup::{RxEntry, TxEntry};
use crate::msg::Msg;
use crate::sim::{Sim, Time};
use crate::util::report::{MetricDecl, Report};
use crate::util::rng::{Rng, Zipf};
use crate::wafer::system::System;

use super::config::ExperimentConfig;
use super::scenario::{downcast_prepared, CacheKey, Prepared, Scenario};
use super::traffic::{
    execute_fabric_plan, fabric_key_base, fabric_schema, plan_fabric, FabricPlan,
    FabricScenario, FpgaPlan,
};

/// Declared metric schema of [`MicrocircuitRackScenario`].
pub const RACK_METRICS: &[MetricDecl] = fabric_schema![
    MetricDecl::count("wire_bytes_out", "B"),
    MetricDecl::count("n_neurons", "neurons"),
    MetricDecl::real("bytes_per_neuron", "B/neuron"),
    MetricDecl::count("resident_bytes", "B"),
];

/// Map a Zipf-sampled distance rank to an FPGA index near `fi`:
/// rank 0 → `fi + 1`, rank 1 → `fi - 1`, rank 2 → `fi + 2`, ... with
/// wrap-around. FPGAs are enumerated wafer-major, so small ranks stay
/// on the same wafer — the locality knob of the scenario.
fn neighbor_by_rank(fi: usize, rank: usize, n: usize) -> usize {
    let offset = rank / 2 + 1;
    if rank % 2 == 0 {
        (fi + offset) % n
    } else {
        (fi + n - offset) % n
    }
}

/// Rack-scale microcircuit load (see the module docs).
pub struct MicrocircuitRackScenario;

impl FabricScenario for MicrocircuitRackScenario {
    fn plan(
        &self,
        sys: &System,
        cfg: &ExperimentConfig,
        rng: &mut Rng,
    ) -> Result<FabricPlan> {
        let fpgas: Vec<_> = sys.fpgas().collect(); // (wafer, slot, actor, endpoint)
        let n = fpgas.len();
        anyhow::ensure!(n >= 2, "microcircuit_rack needs at least 2 FPGAs");
        anyhow::ensure!(
            cfg.workload.sources_per_fpga * cfg.workload.fan_out <= 1 << 15,
            "rack GUID space exceeded: {} neurons × fan_out {}",
            cfg.workload.sources_per_fpga,
            cfg.workload.fan_out
        );
        let zipf = Zipf::new(n - 1, cfg.workload.zipf_s);

        let mut guid_next = vec![0u16; n]; // per-destination GUID allocator
        let mut per_fpga = Vec::with_capacity(n);
        let mut rx = Vec::new();
        for fi in 0..n {
            let mut sources = Vec::new();
            let mut tx = Vec::new();
            for s in 0..cfg.workload.sources_per_fpga {
                let hicann = (s % 8) as u8;
                let pulse = (s / 8) as u16;
                sources.push((hicann, pulse));
                // locality-biased fan-out: Zipf over the distance rank
                let mut picked = BTreeSet::new();
                while picked.len() < cfg.workload.fan_out.min(n - 1) {
                    let d = neighbor_by_rank(fi, zipf.sample(rng), n);
                    picked.insert(d);
                }
                for d in picked {
                    let dest = fpgas[d].3;
                    let guid = guid_next[d];
                    guid_next[d] = guid_next[d].wrapping_add(1) & 0x7FFF;
                    tx.push((hicann, pulse, TxEntry { dest, guid }));
                    rx.push((
                        d,
                        guid,
                        RxEntry {
                            hicann_mask: 0xFF,
                            pulse_addr: pulse,
                        },
                    ));
                }
            }
            per_fpga.push(FpgaPlan {
                sources,
                gen_seed: Some(rng.next_u64()),
                tx,
            });
        }
        Ok(FabricPlan { per_fpga, rx })
    }

    fn collect(&self, sim: &Sim<Msg>, sys: &System, report: &mut Report) {
        let mut wire_bytes = 0u64;
        for (_, _, id, _) in sys.fpgas() {
            wire_bytes += sim.get::<Fpga>(id).stats.tx_wire_bytes;
        }
        report.push_unit("wire_bytes_out", wire_bytes, "B");
    }
}

impl Scenario for MicrocircuitRackScenario {
    fn name(&self) -> &'static str {
        "microcircuit_rack"
    }

    fn about(&self) -> &'static str {
        "rack-scale (20-wafer) microcircuit load with locality-biased fan-out"
    }

    /// The paper's rack: 20 wafer modules on an 8×5×4 torus (160 nodes
    /// = 20 wafers × 8 concentrators), 48 FPGAs each, 80 neurons per
    /// FPGA ≈ the 77k-neuron cortical microcircuit spread over the
    /// machine. Rate and duration are scaled down so the default run
    /// stays a smoke test; sweeps raise them.
    fn default_config(&self) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system.n_wafers = 20;
        cfg.system.torus = TorusSpec::new(8, 5, 4);
        cfg.system.fpgas_per_wafer = 48;
        cfg.system.concentrators_per_wafer = 8;
        cfg.workload.sources_per_fpga = 80;
        cfg.workload.fan_out = 2;
        cfg.workload.zipf_s = 1.3;
        cfg.workload.rate_hz = 1e6;
        cfg.workload.duration = Time::from_us(100);
        cfg
    }

    fn metrics(&self) -> &'static [MetricDecl] {
        RACK_METRICS
    }

    fn cache_key(&self, cfg: &ExperimentConfig) -> CacheKey {
        fabric_key_base("rack_plan", cfg)
            .field("fan_out", cfg.workload.fan_out)
            .field("zipf_s", cfg.workload.zipf_s)
    }

    fn prepare(&self, cfg: &ExperimentConfig) -> Result<Arc<dyn Prepared>> {
        Ok(Arc::new(plan_fabric(self, cfg)?))
    }

    fn execute(&self, prepared: &dyn Prepared, cfg: &ExperimentConfig) -> Result<Report> {
        let plan: &FabricPlan = downcast_prepared(prepared, Scenario::name(self))?;
        let mut report =
            execute_fabric_plan(self, Scenario::name(self), RACK_METRICS, plan, cfg)?;
        let n_neurons: u64 = plan.per_fpga.iter().map(|fp| fp.sources.len() as u64).sum();
        let wire = report.get_count("wire_bytes_out").unwrap_or(0);
        report.push_unit("n_neurons", n_neurons, "neurons");
        report.push_unit(
            "bytes_per_neuron",
            if n_neurons == 0 {
                f64::NAN
            } else {
                wire as f64 / n_neurons as f64
            },
            "B/neuron",
        );
        // what the byte-accounted ResourceCache charges for this point's
        // prepared plan — surfaced so sweeps can plot memory vs. wafers
        report.push_unit("resident_bytes", prepared.resident_bytes(), "B");
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::QueueKind;
    use crate::wafer::system::SystemConfig;

    /// A rack in miniature: same scenario, toy machine.
    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.sources_per_fpga = 8;
        cfg.workload.fan_out = 2;
        cfg.workload.zipf_s = 1.3;
        cfg.workload.rate_hz = 2e6;
        cfg.workload.duration = Time::from_us(500);
        cfg
    }

    #[test]
    fn rack_run_emits_neuron_metrics() {
        let s = MicrocircuitRackScenario;
        let r = Scenario::run(&s, &small()).unwrap();
        assert_eq!(r.get_count("n_neurons"), Some(8 * 8));
        let wire = r.get_count("wire_bytes_out").unwrap();
        assert!(wire > 0, "no wire bytes recorded");
        let bpn = r.get_f64("bytes_per_neuron").unwrap();
        assert!((bpn - wire as f64 / 64.0).abs() < 1e-9);
        assert!(r.get_count("resident_bytes").unwrap() > 0);
        // every generated event is delivered fan_out times
        let generated = r.get_count("events_generated").unwrap();
        assert_eq!(r.get_count("rx_events"), Some(2 * generated));
    }

    #[test]
    fn rack_is_deterministic_and_reuse_safe() {
        let s = MicrocircuitRackScenario;
        let warm_cfg = small();
        assert_eq!(warm_cfg.reuse, super::super::config::ReuseMode::Fabric);
        let first = Scenario::run(&s, &warm_cfg).unwrap().to_json().to_string();
        // second run acquires the parked fabric (warm path)
        let second = Scenario::run(&s, &warm_cfg).unwrap().to_json().to_string();
        // cold rebuild for reference
        let mut cold_cfg = small();
        cold_cfg.reuse = super::super::config::ReuseMode::Off;
        let cold = Scenario::run(&s, &cold_cfg).unwrap().to_json().to_string();
        assert_eq!(first, second, "warm rerun diverged");
        assert_eq!(first, cold, "fabric reuse diverged from cold rebuild");
    }

    #[test]
    fn locality_bias_prefers_near_fpgas() {
        let cfg = small();
        // the same throwaway system plan_fabric builds, to map endpoints
        // back to FPGA indices
        let mut sim: Sim<Msg> = Sim::new();
        let sys = System::build(&mut sim, cfg.system);
        let index_of: std::collections::BTreeMap<_, _> = sys
            .fpgas()
            .enumerate()
            .map(|(i, (_, _, _, ep))| (ep, i))
            .collect();
        let plan = plan_fabric(&MicrocircuitRackScenario, &cfg).unwrap();
        let n = plan.per_fpga.len();
        let (mut near, mut far) = (0u64, 0u64);
        for (fi, fp) in plan.per_fpga.iter().enumerate() {
            for &(_, _, entry) in &fp.tx {
                let d = index_of[&entry.dest];
                let dist = (d as i64 - fi as i64).rem_euclid(n as i64);
                let dist = dist.min(n as i64 - dist);
                if dist <= 1 {
                    near += 1;
                } else {
                    far += 1;
                }
            }
        }
        assert!(
            near > far,
            "Zipf(1.3) rank bias should favor adjacent FPGAs: near={near} far={far}"
        );
    }

    #[test]
    fn rack_works_on_both_queue_kinds() {
        let s = MicrocircuitRackScenario;
        let mut cfg = small();
        cfg.queue = QueueKind::Heap;
        let heap = Scenario::run(&s, &cfg).unwrap();
        cfg.queue = QueueKind::Wheel;
        let wheel = Scenario::run(&s, &cfg).unwrap();
        // physics (not DES bookkeeping) must match across backends
        for key in ["rx_events", "wire_bytes_out", "packets_out"] {
            assert_eq!(heap.get_count(key), wheel.get_count(key), "{key} diverged");
        }
    }

    #[test]
    fn default_config_is_the_paper_rack() {
        let cfg = MicrocircuitRackScenario.default_config();
        assert_eq!(cfg.system.n_wafers, 20);
        assert!(
            cfg.system.torus.n_nodes()
                >= cfg.system.n_wafers * cfg.system.concentrators_per_wafer
        );
        assert_eq!(
            cfg.system.n_wafers * cfg.system.fpgas_per_wafer * cfg.workload.sources_per_fpga,
            76_800 // ≈ the 77k-neuron cortical microcircuit
        );
    }
}
