//! Parameter-sweep runner: one scenario × a grid of config overrides.
//!
//! A [`SweepRunner`] takes a base [`ExperimentConfig`] plus named axes
//! (`rate_hz = 1e6, 5e6 × n_wafers = 2, 4 × ...`), runs the scenario at
//! every point of the cartesian product, and collects one [`Report`] row
//! per point. Results aggregate into a single JSON document or CSV —
//! the artifact behind every "metric vs. parameter" figure.
//!
//! Axis values are strings, parsed per-parameter by [`apply_override`]
//! (the same override path the CLI `--set` flag uses), so numeric and
//! symbolic knobs (e.g. `eviction=fullest`) sweep uniformly.
//!
//! Grid points are independent simulations, so the runner can evaluate
//! them on a [`std::thread::scope`] worker pool (`sweep --jobs N`); the
//! result order — and therefore every JSON/CSV artifact — is identical
//! to the serial run's, regardless of worker scheduling.
//!
//! ## Shared prepared resources
//!
//! Every point is evaluated through the runner's [`ResourceCache`]:
//! `prepare` runs once per distinct [`Scenario::cache_key`], and points
//! that share a key (e.g. a `rate_hz` sweep that never touches the route
//! plan, or a microcircuit `steps` sweep that never touches the
//! artifact) share one `Prepared`. The per-key latch in the cache makes
//! hit/miss counts deterministic under `--jobs N`, so the aggregate JSON
//! (which surfaces them under `"cache"`) stays byte-identical to the
//! serial run's. Point reports themselves carry no cache metrics — their
//! bytes are exactly the pre-cache output.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::sim::Time;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::report::{MetricDecl, Report, Value};
use crate::workload::generators::GeneratorKind;

use super::config::ExperimentConfig;
use super::scenario::{CacheStats, ResourceCache, Scenario};

/// Apply one `key=value` override onto a config. Shared by the sweep
/// axes and the CLI `--set` flag.
pub fn apply_override(cfg: &mut ExperimentConfig, key: &str, value: &str) -> Result<()> {
    fn num(key: &str, value: &str) -> Result<f64> {
        value
            .parse::<f64>()
            .map_err(|_| anyhow::anyhow!("--{key}: '{value}' is not a number"))
    }
    fn int(key: &str, value: &str) -> Result<u64> {
        let x = num(key, value)?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("--{key}: '{value}' is not a non-negative integer");
        }
        Ok(x as u64)
    }
    match key {
        "seed" => cfg.seed = int(key, value)?,
        "queue" => {
            cfg.queue = crate::sim::QueueKind::parse(value)
                .ok_or_else(|| anyhow::anyhow!("unknown queue kind '{value}' (heap|wheel)"))?
        }
        "domains" => {
            let d = int(key, value)? as usize;
            if d == 0 {
                bail!("--domains: must be >= 1");
            }
            cfg.domains = d;
        }
        "sync" => {
            cfg.sync = crate::sim::SyncMode::parse(value)
                .ok_or_else(|| {
                    anyhow::anyhow!("unknown sync mode '{value}' (window|channel|free)")
                })?
        }
        // fabric reuse across executes: rewind-and-reuse (`fabric`,
        // default) vs cold rebuilds (`off`) — byte-identical either way
        "reuse" => {
            cfg.reuse = super::config::ReuseMode::parse(value)
                .ok_or_else(|| anyhow::anyhow!("unknown reuse mode '{value}' (off|fabric)"))?
        }
        // fault injection: "none", "fail:0.25|loss:0.01", a JSON object,
        // or "@path" to load a calibrated preset file (the compact form
        // is comma-free so it survives as a sweep-axis value — axis
        // values split on ',')
        "fault" => {
            cfg.fault = match value.strip_prefix('@') {
                Some(path) => fault_from_preset(path)?,
                None => crate::fault::FaultConfig::parse_spec(value)
                    .map_err(|e| anyhow::anyhow!("--fault: {e}"))?,
            }
        }
        // link-level reliability (extoll::link): retransmission on/off
        // plus its tuning knobs — see docs/TUNING.md
        "reliability" => {
            cfg.system.nic.reliability = crate::extoll::link::Reliability::parse(value)
                .ok_or_else(|| anyhow::anyhow!("unknown reliability mode '{value}' (off|link)"))?
        }
        "retx_window" => {
            let w = int(key, value)?;
            if w == 0 {
                bail!("--retx_window: must be >= 1");
            }
            cfg.system.nic.retx.window = w as u32;
        }
        "retx_timeout_ns" => {
            let t = int(key, value)?;
            if t == 0 {
                bail!("--retx_timeout_ns: must be >= 1");
            }
            cfg.system.nic.retx.timeout = Time::from_ns(t);
        }
        "retx_max_retries" => cfg.system.nic.retx.max_retries = int(key, value)? as u32,
        "retx_backoff_cap" => cfg.system.nic.retx.backoff_cap = int(key, value)? as u32,
        // workload
        "rate_hz" => cfg.workload.rate_hz = num(key, value)?,
        "sources_per_fpga" => cfg.workload.sources_per_fpga = int(key, value)? as usize,
        "fan_out" => cfg.workload.fan_out = int(key, value)? as usize,
        "zipf_s" => cfg.workload.zipf_s = num(key, value)?,
        "deadline_offset" => cfg.workload.deadline_offset = int(key, value)? as u16,
        "duration_s" => cfg.workload.duration = Time::from_secs_f64(num(key, value)?),
        "generator" => {
            cfg.workload.generator = GeneratorKind::parse(value)
                .ok_or_else(|| anyhow::anyhow!("unknown generator '{value}'"))?
        }
        "burst_len" => cfg.workload.burst_len = int(key, value)? as u32,
        "mc_scale" => cfg.workload.mc_scale = num(key, value)?,
        // system
        "n_wafers" => cfg.system.n_wafers = int(key, value)? as usize,
        "fpgas_per_wafer" => cfg.system.fpgas_per_wafer = int(key, value)? as usize,
        "concentrators_per_wafer" => {
            cfg.system.concentrators_per_wafer = int(key, value)? as usize
        }
        "torus" => {
            let dims: Vec<u16> = value
                .split('x')
                .map(|s| s.parse::<u16>())
                .collect::<Result<_, _>>()
                .map_err(|_| anyhow::anyhow!("--torus: expected XxYxZ, got '{value}'"))?;
            if dims.len() != 3 {
                bail!("--torus: expected XxYxZ, got '{value}'");
            }
            cfg.system.torus = crate::extoll::torus::TorusSpec::new(dims[0], dims[1], dims[2]);
        }
        "buckets" => cfg.system.manager.n_buckets = int(key, value)? as usize,
        "bucket_capacity" => cfg.system.manager.bucket.capacity = int(key, value)? as usize,
        "deadline_margin" => cfg.system.manager.bucket.deadline_margin = int(key, value)? as u16,
        "eviction" => {
            use crate::fpga::manager::EvictionPolicy;
            cfg.system.manager.eviction = match value {
                "most_urgent" => EvictionPolicy::MostUrgent,
                "fullest" => EvictionPolicy::Fullest,
                "oldest" => EvictionPolicy::Oldest,
                "round_robin" => EvictionPolicy::RoundRobin,
                other => bail!("unknown eviction policy '{other}'"),
            }
        }
        // neuro
        "steps" => cfg.neuro.steps = int(key, value)? as usize,
        "artifact" => cfg.neuro.artifact = value.to_string(),
        "dt_s" => cfg.neuro.dt = Time::from_secs_f64(num(key, value)?),
        "w_exc" => cfg.neuro.w_exc = num(key, value)? as f32,
        "w_inh" => cfg.neuro.w_inh = num(key, value)? as f32,
        "k_scale" => cfg.neuro.k_scale = num(key, value)?,
        other => bail!(
            "unknown parameter '{other}' (known: seed, queue, domains, sync, \
             reuse, fault, reliability, retx_window, retx_timeout_ns, \
             retx_max_retries, retx_backoff_cap, rate_hz, sources_per_fpga, \
             fan_out, zipf_s, deadline_offset, duration_s, generator, \
             burst_len, mc_scale, n_wafers, fpgas_per_wafer, \
             concentrators_per_wafer, torus, buckets, bucket_capacity, \
             deadline_margin, eviction, steps, artifact, dt_s, w_exc, \
             w_inh, k_scale — see docs/TUNING.md)"
        ),
    }
    Ok(())
}

/// Load a fault preset file for `--set fault=@path` / a `fault=@path`
/// sweep-axis value. The file may be a full experiment config (its
/// `"fault"` block is taken, e.g. `configs/fault_lossy.json`) or a bare
/// fault object.
fn fault_from_preset(path: &str) -> Result<crate::fault::FaultConfig> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("fault preset '{path}': {e}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("fault preset '{path}': {e}"))?;
    crate::fault::FaultConfig::from_json(j.get("fault").unwrap_or(&j))
        .map_err(|e| anyhow::anyhow!("fault preset '{path}': {e}"))
}

/// Parse `"a=1,2;b=x,y"` into sweep axes.
pub fn parse_grid(spec: &str) -> Result<Vec<(String, Vec<String>)>> {
    let mut axes = Vec::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (key, values) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("grid axis '{part}' is not key=v1,v2,..."))?;
        let values: Vec<String> = values
            .split(',')
            .map(|v| v.trim().to_string())
            .filter(|v| !v.is_empty())
            .collect();
        if values.is_empty() {
            bail!("grid axis '{key}' has no values");
        }
        axes.push((key.trim().to_string(), values));
    }
    if axes.is_empty() {
        bail!("empty sweep grid");
    }
    Ok(axes)
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// The overrides applied at this point, in axis order.
    pub params: Vec<(String, String)>,
    pub report: Report,
}

/// All points of a finished sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub scenario: String,
    /// The scenario's declared metric schema (stable CSV column order).
    pub schema: &'static [MetricDecl],
    pub points: Vec<SweepPoint>,
    /// Resource-cache hit/miss counters of this run (deterministic
    /// across `--jobs N` — see the module docs).
    pub cache: CacheStats,
}

impl SweepResult {
    /// Aggregate JSON artifact:
    /// `{"scenario":.., "n_points":..,
    ///   "cache":{"hits":..,"misses":..,"evictions":..,"resident_bytes":..},
    ///   "points":[{"params":{..},"metrics":{..}},..]}`.
    pub fn to_json(&self) -> Json {
        let mut pts = Json::arr();
        for p in &self.points {
            let mut params = Json::obj();
            for (k, v) in &p.params {
                match v.parse::<f64>() {
                    Ok(x) => params.insert(k, x),
                    Err(_) => params.insert(k, v.as_str()),
                }
            }
            pts.push(
                Json::obj()
                    .set("params", params)
                    .set("metrics", p.report.to_flat_json()),
            );
        }
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("n_points", self.points.len())
            .set(
                "cache",
                Json::obj()
                    .set("hits", self.cache.hits)
                    .set("misses", self.cache.misses)
                    .set("evictions", self.cache.evictions)
                    .set("resident_bytes", self.cache.resident_bytes),
            )
            .set("points", pts)
    }

    /// Metric columns: the declared schema order first (restricted to
    /// metrics some point actually reported — conditional metrics like
    /// `bottleneck` only appear when emitted), then any undeclared
    /// stragglers in first-seen order so no point's data is dropped.
    fn metric_columns(&self) -> Vec<String> {
        let mut keys: Vec<String> = Vec::new();
        for d in self.schema {
            if self.points.iter().any(|p| p.report.get(d.name).is_some()) {
                keys.push(d.name.to_string());
            }
        }
        for p in &self.points {
            for k in p.report.keys() {
                if !keys.iter().any(|e| e == k) {
                    keys.push(k.to_string());
                }
            }
        }
        keys
    }

    /// CSV artifact: one column per axis, then one per metric.
    pub fn to_csv(&self) -> String {
        let Some(first) = self.points.first() else {
            return String::new();
        };
        let metric_keys = self.metric_columns();
        let mut out = String::new();
        let header: Vec<String> = first
            .params
            .iter()
            .map(|(k, _)| k.clone())
            .chain(metric_keys.iter().cloned())
            .collect();
        push_csv_row(&mut out, &header);
        for p in &self.points {
            let row: Vec<String> = p
                .params
                .iter()
                .map(|(_, v)| v.clone())
                .chain(metric_keys.iter().map(|k| match p.report.get(k) {
                    Some(Value::Count(c)) => c.to_string(),
                    Some(Value::Real(x)) => format!("{x}"),
                    Some(Value::Text(s)) => s.clone(),
                    // comma-free percentile summary (HistSummary::render);
                    // the full buckets live in the JSON artifact
                    Some(Value::Hist(h)) => h.render(),
                    None => String::new(),
                }))
                .collect();
            push_csv_row(&mut out, &row);
        }
        out
    }

    /// Render as a (wide) table: axes + every metric column.
    pub fn table(&self) -> Table {
        let Some(first) = self.points.first() else {
            return Table::new("sweep (no points)", &[]);
        };
        let metric_keys = self.metric_columns();
        let columns: Vec<String> = first
            .params
            .iter()
            .map(|(k, _)| k.clone())
            .chain(metric_keys.iter().cloned())
            .collect();
        let col_refs: Vec<&str> = columns.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("{} sweep — {} points", self.scenario, self.points.len()),
            &col_refs,
        );
        for p in &self.points {
            let row: Vec<String> = p
                .params
                .iter()
                .map(|(_, v)| v.clone())
                .chain(
                    metric_keys
                        .iter()
                        .map(|k| p.report.get(k).map(Value::render).unwrap_or_default()),
                )
                .collect();
            t.row(row);
        }
        t
    }
}

fn push_csv_row(out: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            out.push('"');
            out.push_str(&cell.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

/// One result slot per grid point, written by whichever worker claims
/// the point; collected in index order after the pool joins.
type PointSlot = Mutex<Option<Result<SweepPoint>>>;

/// Config grid × scenario → one report per point.
///
/// Prepared resources are shared across points (and across repeated
/// `run` calls on the same runner) through the embedded
/// [`ResourceCache`] — see the module docs.
pub struct SweepRunner {
    base: ExperimentConfig,
    axes: Vec<(String, Vec<String>)>,
    jobs: usize,
    cache: ResourceCache,
}

impl SweepRunner {
    pub fn new(base: ExperimentConfig) -> SweepRunner {
        SweepRunner {
            base,
            axes: Vec::new(),
            jobs: 1,
            cache: ResourceCache::new(),
        }
    }

    /// Build from a `"a=1,2;b=x,y"` grid spec.
    pub fn from_grid(base: ExperimentConfig, spec: &str) -> Result<SweepRunner> {
        Ok(SweepRunner {
            base,
            axes: parse_grid(spec)?,
            jobs: 1,
            cache: ResourceCache::new(),
        })
    }

    /// Cumulative cache counters of this runner (across all `run` calls).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Add one sweep axis (builder style).
    pub fn axis(mut self, key: &str, values: &[&str]) -> SweepRunner {
        self.axes
            .push((key.to_string(), values.iter().map(|v| v.to_string()).collect()));
        self
    }

    /// Evaluate grid points on `jobs` worker threads (builder style).
    /// `1` (the default) runs serially on the calling thread.
    pub fn jobs(mut self, jobs: usize) -> SweepRunner {
        self.jobs = jobs.max(1);
        self
    }

    /// Number of grid points (product of axis lengths; 1 when no axes).
    pub fn n_points(&self) -> usize {
        self.axes.iter().map(|(_, v)| v.len()).product()
    }

    /// Parameter assignments of every grid point, row-major (last axis
    /// fastest) — the canonical result order for both execution modes.
    fn grid_points(&self) -> Result<Vec<Vec<(String, String)>>> {
        for (key, values) in &self.axes {
            anyhow::ensure!(!values.is_empty(), "sweep axis '{key}' has no values");
        }
        let mut points = Vec::with_capacity(self.n_points());
        let mut idx = vec![0usize; self.axes.len()];
        loop {
            let params: Vec<(String, String)> = self
                .axes
                .iter()
                .enumerate()
                .map(|(ai, (key, values))| (key.clone(), values[idx[ai]].clone()))
                .collect();
            points.push(params);

            // odometer increment, last axis fastest
            let mut ai = self.axes.len();
            while ai > 0 {
                idx[ai - 1] += 1;
                if idx[ai - 1] < self.axes[ai - 1].1.len() {
                    break;
                }
                idx[ai - 1] = 0;
                ai -= 1;
            }
            if ai == 0 {
                break;
            }
        }
        Ok(points)
    }

    /// Evaluate one grid point: base config + overrides → prepared
    /// resources (cached by [`Scenario::cache_key`]) → execute → report.
    fn eval_point(
        &self,
        scenario: &dyn Scenario,
        params: &[(String, String)],
    ) -> Result<SweepPoint> {
        let mut cfg = self.base.clone();
        for (key, value) in params {
            apply_override(&mut cfg, key, value)?;
        }
        let prepared = self.cache.get_or_prepare(scenario, &cfg)?;
        let report = scenario.execute(prepared.as_ref(), &cfg)?;
        Ok(SweepPoint {
            params: params.to_vec(),
            report,
        })
    }

    /// Run `scenario` at every grid point (row-major: last axis fastest),
    /// serially. `progress` is invoked before each point with
    /// (index, n_points).
    pub fn run_with_progress(
        &self,
        scenario: &dyn Scenario,
        mut progress: impl FnMut(usize, usize),
    ) -> Result<SweepResult> {
        let cache_before = self.cache.stats();
        let grid = self.grid_points()?;
        let n = grid.len();
        let mut points = Vec::with_capacity(n);
        for params in &grid {
            progress(points.len(), n);
            points.push(self.eval_point(scenario, params)?);
        }
        Ok(SweepResult {
            scenario: scenario.name().to_string(),
            schema: scenario.metrics(),
            points,
            cache: self.cache.stats().since(cache_before),
        })
    }

    /// Run `scenario` at every grid point on `self.jobs` worker threads.
    ///
    /// Workers claim points from a shared counter and write results into
    /// per-point slots, so the returned order (and every artifact derived
    /// from it) is byte-identical to the serial run's. On errors, the
    /// lowest-indexed failure is reported — again matching the serial
    /// run — and workers stop claiming further points.
    /// `progress(done, n_points)` fires after each completed point,
    /// possibly out of order; it must be thread-safe (`Fn + Sync`).
    pub fn run_parallel(
        &self,
        scenario: &dyn Scenario,
        progress: impl Fn(usize, usize) + Sync,
    ) -> Result<SweepResult> {
        let cache_before = self.cache.stats();
        let grid = self.grid_points()?;
        let n = grid.len();
        let workers = self.jobs.min(n).max(1);
        let next = AtomicUsize::new(0);
        let done = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let slots: Vec<PointSlot> = (0..n).map(|_| Mutex::new(None)).collect();
        {
            let (grid, slots, next, done) = (&grid, &slots, &next, &done);
            let (progress, failed) = (&progress, &failed);
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(move || loop {
                        // stop claiming new points once any point failed;
                        // points claimed earlier (all lower-indexed) still
                        // finish, so the lowest-indexed error is recorded
                        if failed.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let point = self.eval_point(scenario, &grid[i]);
                        if point.is_err() {
                            failed.store(true, Ordering::Relaxed);
                        }
                        *slots[i].lock().expect("sweep slot poisoned") = Some(point);
                        progress(done.fetch_add(1, Ordering::Relaxed) + 1, n);
                    });
                }
            });
        }
        let mut points = Vec::with_capacity(n);
        for slot in slots {
            match slot.into_inner().expect("sweep slot poisoned") {
                Some(Ok(point)) => points.push(point),
                Some(Err(e)) => return Err(e),
                // only reachable past the lowest-indexed error, which the
                // match arm above returns first
                None => bail!("sweep aborted before this point was evaluated"),
            }
        }
        Ok(SweepResult {
            scenario: scenario.name().to_string(),
            schema: scenario.metrics(),
            points,
            cache: self.cache.stats().since(cache_before),
        })
    }

    /// Run without progress reporting (parallel when `jobs > 1`).
    pub fn run(&self, scenario: &dyn Scenario) -> Result<SweepResult> {
        if self.jobs > 1 {
            self.run_parallel(scenario, |_, _| {})
        } else {
            self.run_with_progress(scenario, |_, _| {})
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::find;
    use crate::extoll::torus::TorusSpec;
    use crate::wafer::system::SystemConfig;

    fn small() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 16;
        cfg.workload.duration = Time::from_us(200);
        cfg
    }

    #[test]
    fn grid_parses() {
        let axes = parse_grid("rate_hz=1e6,5e6; fan_out = 1,2 ;eviction=fullest").unwrap();
        assert_eq!(axes.len(), 3);
        assert_eq!(axes[0].0, "rate_hz");
        assert_eq!(axes[0].1, vec!["1e6", "5e6"]);
        assert_eq!(axes[1].1, vec!["1", "2"]);
        assert_eq!(axes[2].1, vec!["fullest"]);
        assert!(parse_grid("").is_err());
        assert!(parse_grid("novalues=").is_err());
        assert!(parse_grid("noequals").is_err());
    }

    #[test]
    fn overrides_touch_all_layers() {
        let mut cfg = ExperimentConfig::default();
        apply_override(&mut cfg, "rate_hz", "5e6").unwrap();
        apply_override(&mut cfg, "n_wafers", "4").unwrap();
        apply_override(&mut cfg, "torus", "4x4x2").unwrap();
        apply_override(&mut cfg, "eviction", "oldest").unwrap();
        apply_override(&mut cfg, "generator", "burst").unwrap();
        apply_override(&mut cfg, "steps", "17").unwrap();
        assert_eq!(cfg.workload.rate_hz, 5e6);
        assert_eq!(cfg.system.n_wafers, 4);
        assert_eq!(cfg.system.torus.n_nodes(), 32);
        assert_eq!(cfg.neuro.steps, 17);
        assert!(apply_override(&mut cfg, "no_such_knob", "1").is_err());
        assert!(apply_override(&mut cfg, "rate_hz", "fast").is_err());
        assert!(apply_override(&mut cfg, "torus", "4x4").is_err());
    }

    #[test]
    fn sweep_2x2_is_deterministic_and_complete() {
        let runner = SweepRunner::new(small())
            .axis("rate_hz", &["1e6", "4e6"])
            .axis("fan_out", &["1", "2"]);
        assert_eq!(runner.n_points(), 4);
        let scenario = find("traffic").unwrap();
        let a = runner.run(scenario).unwrap();
        assert_eq!(a.points.len(), 4);
        for p in &a.points {
            assert_eq!(p.params.len(), 2);
            assert!(p.report.get_count("events_generated").unwrap() > 0);
        }
        // last axis fastest: fan_out toggles first
        assert_eq!(a.points[0].params[1].1, "1");
        assert_eq!(a.points[1].params[1].1, "2");
        assert_eq!(a.points[0].params[0].1, "1e6");
        assert_eq!(a.points[2].params[0].1, "4e6");
        // the fan_out axis is visible in the physics of each point
        for (pi, fan_out) in [(0usize, 1u64), (1, 2), (2, 1), (3, 2)] {
            let r = &a.points[pi].report;
            assert_eq!(
                r.get_count("rx_events").unwrap(),
                fan_out * r.get_count("events_generated").unwrap(),
                "point {pi}: fan-out accounting"
            );
        }
        // deterministic end to end: a fresh runner (cold cache) produces
        // the identical artifact ...
        let b = SweepRunner::new(small())
            .axis("rate_hz", &["1e6", "4e6"])
            .axis("fan_out", &["1", "2"])
            .run(scenario)
            .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // ... and a warm re-run on the same runner reuses every plan:
        // same points, all hits
        let warm = runner.run(scenario).unwrap();
        assert_eq!(a.to_csv(), warm.to_csv());
        assert_eq!(warm.cache.misses, 0);
        assert_eq!(warm.cache.hits, 4);
    }

    #[test]
    fn sweep_shares_plans_across_points() {
        // rate_hz is an execute-time knob: 3 points, one route plan
        let runner = SweepRunner::new(small()).axis("rate_hz", &["1e6", "2e6", "4e6"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.cache.misses, 1, "route plan rebuilt per point");
        assert_eq!(result.cache.hits, 2);
        assert_eq!(runner.cache_stats().misses, 1);
        // fan_out is a plan input: a fan_out axis forces one plan per value
        let runner = SweepRunner::new(small()).axis("fan_out", &["1", "2"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.cache.misses, 2);
        assert_eq!(result.cache.hits, 0);
    }

    #[test]
    fn sweep_json_surfaces_cache_counters() {
        let runner = SweepRunner::new(small()).axis("rate_hz", &["1e6", "2e6"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        let j = result.to_json();
        assert_eq!(j.at(&["cache", "misses"]).unwrap().as_u64(), Some(1));
        assert_eq!(j.at(&["cache", "hits"]).unwrap().as_u64(), Some(1));
        assert_eq!(j.at(&["cache", "evictions"]).unwrap().as_u64(), Some(0));
        assert!(
            j.at(&["cache", "resident_bytes"]).unwrap().as_u64().unwrap() > 0,
            "resident bytes of the shared plan must be surfaced"
        );
    }

    #[test]
    fn csv_columns_follow_declared_schema_order() {
        // build a result whose reports insert metrics in scrambled order;
        // the CSV must follow the declared schema, not insertion order
        const SCHEMA: &[crate::util::report::MetricDecl] = &[
            crate::util::report::MetricDecl::count("alpha", "x"),
            crate::util::report::MetricDecl::count("beta", "x"),
            crate::util::report::MetricDecl::count("gamma", "x"),
        ];
        let mut report = Report::with_schema("unit", SCHEMA);
        report.push_unit("gamma", 3u64, "x");
        report.push_unit("alpha", 1u64, "x");
        let result = SweepResult {
            scenario: "unit".to_string(),
            schema: SCHEMA,
            points: vec![SweepPoint {
                params: vec![("p".to_string(), "0".to_string())],
                report,
            }],
            cache: CacheStats::default(),
        };
        let csv = result.to_csv();
        let header = csv.lines().next().unwrap();
        // beta was never reported → dropped; alpha precedes gamma even
        // though gamma was pushed first
        assert_eq!(header, "p,alpha,gamma");
    }

    #[test]
    fn fault_override_parses_both_spec_forms() {
        let mut cfg = ExperimentConfig::default();
        apply_override(&mut cfg, "fault", "fail:0.25|loss:0.01").unwrap();
        assert_eq!(cfg.fault.fail, 0.25);
        assert_eq!(cfg.fault.loss, 0.01);
        apply_override(&mut cfg, "fault", r#"{"jitter_ns": 50}"#).unwrap();
        assert_eq!(cfg.fault.jitter_ns, 50.0);
        assert_eq!(cfg.fault.fail, 0.0, "each spec replaces the whole config");
        apply_override(&mut cfg, "fault", "none").unwrap();
        assert!(cfg.fault.is_default());
        assert!(apply_override(&mut cfg, "fault", "fail:2.0").is_err());
        assert!(apply_override(&mut cfg, "fault", "bogus:1").is_err());
    }

    #[test]
    fn csv_renders_histogram_metrics_comma_free() {
        const SCHEMA: &[crate::util::report::MetricDecl] = &[
            crate::util::report::MetricDecl::histogram("lat", "ps"),
        ];
        let mut h = crate::util::stats::Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record(v);
        }
        let mut report = Report::with_schema("unit", SCHEMA);
        report.push_unit("lat", &h, "ps");
        let result = SweepResult {
            scenario: "unit".to_string(),
            schema: SCHEMA,
            points: vec![SweepPoint {
                params: vec![("p".to_string(), "0".to_string())],
                report,
            }],
            cache: CacheStats::default(),
        };
        let csv = result.to_csv();
        let lines: Vec<&str> = csv.trim_end().lines().collect();
        assert_eq!(lines[0], "p,lat");
        assert!(lines[1].contains("n=5"), "{}", lines[1]);
        assert!(lines[1].contains("p95="), "{}", lines[1]);
        // the summary must not force CSV quoting
        assert!(!lines[1].contains('"'), "{}", lines[1]);
    }

    #[test]
    fn parallel_sweep_matches_serial_byte_for_byte() {
        let runner = SweepRunner::new(small())
            .axis("rate_hz", &["1e6", "2e6", "4e6"])
            .axis("fan_out", &["1", "2"]);
        let scenario = find("traffic").unwrap();
        let serial = runner.run(scenario).unwrap();
        let parallel = SweepRunner::new(small())
            .axis("rate_hz", &["1e6", "2e6", "4e6"])
            .axis("fan_out", &["1", "2"])
            .jobs(4)
            .run(scenario)
            .unwrap();
        assert_eq!(serial.points.len(), 6);
        assert_eq!(serial.to_csv(), parallel.to_csv());
        assert_eq!(
            serial.to_json().to_string(),
            parallel.to_json().to_string()
        );
    }

    #[test]
    fn parallel_progress_counts_every_point() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let runner = SweepRunner::new(small())
            .axis("fan_out", &["1", "2", "3"])
            .jobs(3);
        let calls = AtomicUsize::new(0);
        let result = runner
            .run_parallel(find("traffic").unwrap(), |done, n| {
                assert!((1..=n).contains(&done));
                calls.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(result.points.len(), 3);
        assert_eq!(calls.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn parallel_sweep_reports_first_bad_override() {
        let runner = SweepRunner::new(small())
            .axis("rate_hz", &["1e6", "not_a_number"])
            .jobs(2);
        let err = runner.run(find("traffic").unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("rate_hz"), "{err:#}");
    }

    #[test]
    fn queue_override_sweeps_backends_identically() {
        let runner = SweepRunner::new(small()).axis("queue", &["heap", "wheel"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.points.len(), 2);
        // same physics on both backends: every metric column agrees
        let a = result.points[0].report.to_flat_json().to_string();
        let b = result.points[1].report.to_flat_json().to_string();
        assert_eq!(a, b);
        let mut cfg = small();
        assert!(apply_override(&mut cfg, "queue", "splay").is_err());
    }

    #[test]
    fn domains_override_sweeps_identically() {
        // domain count is a perf knob: every metric must agree at 1/2/4
        let runner = SweepRunner::new(small()).axis("domains", &["1", "2", "4"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.points.len(), 3);
        let a = result.points[0].report.to_flat_json().to_string();
        for p in &result.points[1..] {
            assert_eq!(a, p.report.to_flat_json().to_string());
        }
        let mut cfg = small();
        assert!(apply_override(&mut cfg, "domains", "0").is_err());
        apply_override(&mut cfg, "domains", "2").unwrap();
        assert_eq!(cfg.domains, 2);
    }

    #[test]
    fn sync_override_sweeps_identically() {
        // the sync protocol is a perf knob: window × channel × free ×
        // any domain count must agree on every metric
        let runner = SweepRunner::new(small())
            .axis("sync", &["window", "channel", "free"])
            .axis("domains", &["1", "4"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.points.len(), 6);
        let a = result.points[0].report.to_flat_json().to_string();
        for p in &result.points[1..] {
            assert_eq!(a, p.report.to_flat_json().to_string());
        }
        let mut cfg = small();
        assert!(apply_override(&mut cfg, "sync", "global").is_err());
        apply_override(&mut cfg, "sync", "window").unwrap();
        assert_eq!(cfg.sync, crate::sim::SyncMode::Window);
    }

    #[test]
    fn reuse_override_sweeps_identically() {
        // fabric reuse is a perf knob: a sweep across off/fabric (the
        // second and later `fabric` points recycle pooled fabrics) must
        // agree on every metric
        let runner = SweepRunner::new(small())
            .axis("reuse", &["off", "fabric"])
            .axis("rate_hz", &["1e6", "4e6"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.points.len(), 4);
        // points pair up by rate (last axis fastest): off/1e6 vs
        // fabric/1e6, off/4e6 vs fabric/4e6
        for (off, fab) in [(0usize, 2usize), (1, 3)] {
            assert_eq!(
                result.points[off].report.to_flat_json().to_string(),
                result.points[fab].report.to_flat_json().to_string(),
                "reuse diverged from cold rebuild"
            );
        }
        let mut cfg = small();
        assert!(apply_override(&mut cfg, "reuse", "always").is_err());
        apply_override(&mut cfg, "reuse", "off").unwrap();
        assert_eq!(cfg.reuse, super::super::config::ReuseMode::Off);
    }

    #[test]
    fn reliability_override_parses() {
        use crate::extoll::link::Reliability;
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.system.nic.reliability, Reliability::Off);
        apply_override(&mut cfg, "reliability", "link").unwrap();
        assert_eq!(cfg.system.nic.reliability, Reliability::Link);
        apply_override(&mut cfg, "reliability", "off").unwrap();
        assert_eq!(cfg.system.nic.reliability, Reliability::Off);
        assert!(apply_override(&mut cfg, "reliability", "tcp").is_err());
        apply_override(&mut cfg, "retx_window", "8").unwrap();
        apply_override(&mut cfg, "retx_timeout_ns", "750").unwrap();
        apply_override(&mut cfg, "retx_max_retries", "4").unwrap();
        apply_override(&mut cfg, "retx_backoff_cap", "2").unwrap();
        assert_eq!(cfg.system.nic.retx.window, 8);
        assert_eq!(cfg.system.nic.retx.timeout, Time::from_ns(750));
        assert_eq!(cfg.system.nic.retx.max_retries, 4);
        assert_eq!(cfg.system.nic.retx.backoff_cap, 2);
        assert!(apply_override(&mut cfg, "retx_window", "0").is_err());
        assert!(apply_override(&mut cfg, "retx_timeout_ns", "0").is_err());
        assert!(apply_override(&mut cfg, "retx_max_retries", "-1").is_err());
    }

    #[test]
    fn fault_preset_files_load_via_at_syntax() {
        // the shipped calibrated presets are full experiment configs;
        // `fault=@path` extracts just their fault block
        let mut cfg = ExperimentConfig::default();
        apply_override(&mut cfg, "fault", "@configs/fault_lossy.json").unwrap();
        assert_eq!(cfg.fault.loss, 0.02);
        assert_eq!(cfg.fault.jitter_ns, 25.0);
        assert_eq!(cfg.fault.fail, 0.0);
        apply_override(&mut cfg, "fault", "@configs/fault_degraded.json").unwrap();
        assert_eq!(cfg.fault.degrade, 0.25);
        assert_eq!(cfg.fault.degrade_factor, 2.0);
        assert_eq!(cfg.fault.loss, 0.005);
        assert_eq!(cfg.fault.jitter_ns, 50.0);
        let err = apply_override(&mut cfg, "fault", "@configs/no_such_preset.json");
        assert!(format!("{:#}", err.unwrap_err()).contains("no_such_preset"));
    }

    #[test]
    fn reliability_axis_is_transparent_on_a_healthy_fabric() {
        // at loss=0 the link layer stamps, ACKs and retires but never
        // retransmits or stalls, so every physics metric matches the
        // off point exactly
        let runner = SweepRunner::new(small()).axis("reliability", &["off", "link"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        assert_eq!(result.points.len(), 2);
        assert_eq!(
            result.points[0].report.to_flat_json().to_string(),
            result.points[1].report.to_flat_json().to_string(),
            "reliability=link must be metric-transparent at loss=0"
        );
        // reliability is an execute-time knob: both points share one plan
        assert_eq!(result.cache.misses, 1);
        assert_eq!(result.cache.hits, 1);
    }

    #[test]
    fn csv_and_json_artifacts_cover_every_point() {
        let runner = SweepRunner::new(small()).axis("rate_hz", &["1e6", "2e6"]);
        let result = runner.run(find("traffic").unwrap()).unwrap();
        let csv = result.to_csv();
        let lines: Vec<&str> = csv.trim_end().lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 rows");
        assert!(lines[0].starts_with("rate_hz,"));
        assert!(lines[0].contains("rx_events"));
        let j = result.to_json();
        assert_eq!(j.u64_or("n_points", 0), 2);
        let pts = j.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(
            pts[0].at(&["params", "rate_hz"]).unwrap().as_f64().unwrap(),
            1e6
        );
        assert!(pts[0].at(&["metrics", "rx_events"]).unwrap().as_u64().unwrap() > 0);
    }
}
