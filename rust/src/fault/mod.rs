//! Fault-injection subsystem: degraded-fabric modeling for the Extoll
//! torus.
//!
//! Real BrainScaleS deployments fight dead links, flaky cables and pulse
//! loss/jitter (the commissioning and off-wafer characterization papers
//! document exactly these failure modes). This module models them on top
//! of the perfect-fabric simulator:
//!
//! - **Link failure** — a sampled fraction of physical cables fails, either
//!   permanently from t=0 or at a scheduled instant (`fail_at_s`). Both
//!   directions of a cable always fail together, so credit returns on the
//!   reverse direction stay consistent with forwarding.
//! - **Bandwidth degradation** — a disjoint sampled fraction of cables
//!   serializes packets `degrade_factor`× slower (lower effective lane
//!   count), in both directions.
//! - **Stochastic packet loss** — every torus-link traversal is dropped
//!   with probability `loss` at the receiver (the "link CRC failed"
//!   model); credits are still returned upstream so flow control never
//!   leaks.
//! - **Latency jitter** — every torus-link traversal adds an
//!   exponentially distributed latency with mean `jitter_ns`.
//!
//! ## Determinism contract
//!
//! Everything is seeded from the experiment RNG: the cable sample is a
//! single Fisher–Yates shuffle of the canonical [`TorusSpec::cables`]
//! order under a salt of `cfg.seed`, and each NIC draws loss/jitter from
//! its own [`FaultModel::nic_rng`] stream (derived from the model seed and
//! the node address, never from simulation scheduling). Per-NIC event
//! delivery order is partition-independent by the engine's merge-key
//! contract, so reports stay **byte-identical** across `domains`, `sync`
//! modes, queue backends and `--jobs` for a fixed config — gated in
//! `rust/tests/determinism_queue.rs`.
//!
//! Degradation and jitter only ever *add* latency and loss only removes
//! packets, so the healthy per-link minimum latency remains a sound
//! conservative-PDES lookahead bound; links that are dead from t=0 carry
//! no messages at all and are excluded from the channel-clock bounds
//! entirely (see `extoll::network::pdes_lookahead_with`).

use crate::extoll::routing::LinkStatus;
use crate::extoll::torus::{Dir, NodeAddr, TorusSpec, TORUS_PORTS};
use crate::sim::Time;
use crate::util::json::Json;
use crate::util::rng::{splitmix64, Rng};

/// Salt mixed into the experiment seed for the fault-sampling stream, so
/// fault draws never alias workload-generator draws.
const FAULT_SEED_SALT: u64 = 0xFA17_1D3A_5EED_C0DE;

/// User-facing fault specification (the `ExperimentConfig.fault` block /
/// `--set fault=` knob). All fields default to "no faults"; see
/// `docs/TUNING.md` for the knob reference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Fraction of physical cables that fail (both directions), in [0,1].
    pub fail: f64,
    /// Simulated time (seconds) at which the sampled cables fail; `None`
    /// means they are dead from t=0.
    pub fail_at_s: Option<f64>,
    /// Fraction of cables (disjoint from the failed set) degraded to
    /// `degrade_factor`× serialization time, in [0,1].
    pub degrade: f64,
    /// Serialization-time multiplier on degraded cables (≥ 1).
    pub degrade_factor: f64,
    /// Per-link-traversal packet loss probability, in [0,1).
    pub loss: f64,
    /// Mean of the additive exponential per-link latency jitter, ns (≥ 0).
    pub jitter_ns: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            fail: 0.0,
            fail_at_s: None,
            degrade: 0.0,
            degrade_factor: 1.0,
            loss: 0.0,
            jitter_ns: 0.0,
        }
    }
}

impl FaultConfig {
    /// True when this config models a perfect fabric (the default): no
    /// fault machinery is instantiated at all, so zero-fault runs are
    /// byte-identical to the pre-fault-model simulator.
    pub fn is_default(&self) -> bool {
        *self == FaultConfig::default()
    }

    fn validate(&self) -> Result<(), String> {
        fn frac(name: &str, v: f64) -> Result<(), String> {
            if (0.0..=1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("fault.{name} must be in [0,1], got {v}"))
            }
        }
        frac("fail", self.fail)?;
        frac("degrade", self.degrade)?;
        if !(0.0..1.0).contains(&self.loss) {
            return Err(format!(
                "fault.loss must be in [0,1), got {} — a link that loses every \
                 packet is a dead link; model it with fail:1 instead",
                self.loss
            ));
        }
        if !(self.degrade_factor >= 1.0) {
            return Err(format!(
                "fault.degrade_factor must be >= 1, got {}",
                self.degrade_factor
            ));
        }
        if !(self.jitter_ns >= 0.0) {
            return Err(format!(
                "fault.jitter_ns must be >= 0, got {}",
                self.jitter_ns
            ));
        }
        if let Some(t) = self.fail_at_s {
            if !(t >= 0.0) {
                return Err(format!("fault.fail_at_s must be >= 0, got {t}"));
            }
        }
        Ok(())
    }

    /// Parse the JSON object form (`"fault": {"fail": 0.25, ...}`).
    pub fn from_json(j: &Json) -> Result<FaultConfig, String> {
        let mut cfg = FaultConfig::default();
        let Json::Obj(map) = j else {
            return Err(format!("fault config must be an object, got {j:?}"));
        };
        for key in map.keys() {
            if !matches!(
                key.as_str(),
                "fail" | "fail_at_s" | "degrade" | "degrade_factor" | "loss" | "jitter_ns"
            ) {
                return Err(format!(
                    "unknown fault config key '{key}' (valid: fail, fail_at_s, \
                     degrade, degrade_factor, loss, jitter_ns)"
                ));
            }
        }
        cfg.fail = j.f64_or("fail", cfg.fail);
        if let Some(Json::Num(t)) = j.get("fail_at_s") {
            cfg.fail_at_s = Some(*t);
        }
        cfg.degrade = j.f64_or("degrade", cfg.degrade);
        cfg.degrade_factor = j.f64_or("degrade_factor", cfg.degrade_factor);
        cfg.loss = j.f64_or("loss", cfg.loss);
        cfg.jitter_ns = j.f64_or("jitter_ns", cfg.jitter_ns);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse either form of the `--set fault=` / sweep-axis value:
    /// a JSON object (`{"fail": 0.25}`) or the compact comma-free spec
    /// (`fail:0.25|loss:0.01`, `none`) that survives the sweep grammar's
    /// `,`-splitting of axis values.
    pub fn parse_spec(s: &str) -> Result<FaultConfig, String> {
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(FaultConfig::default());
        }
        if s.starts_with('{') {
            let j = Json::parse(s).map_err(|e| format!("fault spec JSON: {e}"))?;
            return FaultConfig::from_json(&j);
        }
        let mut cfg = FaultConfig::default();
        let mut seen: Vec<&str> = Vec::new();
        for part in s.split('|') {
            let (key, value) = part
                .split_once(':')
                .ok_or_else(|| format!("fault spec '{part}': expected key:value"))?;
            if seen.contains(&key) {
                return Err(format!(
                    "duplicate fault spec key '{key}' — each key may appear once"
                ));
            }
            seen.push(key);
            let num = || -> Result<f64, String> {
                value
                    .parse::<f64>()
                    .map_err(|_| format!("fault spec '{part}': bad number '{value}'"))
            };
            match key {
                "fail" => cfg.fail = num()?,
                "fail_at_s" => cfg.fail_at_s = Some(num()?),
                "degrade" => cfg.degrade = num()?,
                "degrade_factor" => cfg.degrade_factor = num()?,
                "loss" => cfg.loss = num()?,
                "jitter_ns" => cfg.jitter_ns = num()?,
                other => {
                    return Err(format!(
                        "unknown fault spec key '{other}' (expected fail, fail_at_s, \
                         degrade, degrade_factor, loss, jitter_ns)"
                    ))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Canonical compact rendering (the inverse of [`parse_spec`]'s
    /// compact form, `"none"` for the default). Stable for a given
    /// config, so it is safe inside cache keys and report text.
    pub fn to_spec(&self) -> String {
        if self.is_default() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.fail > 0.0 {
            parts.push(format!("fail:{}", self.fail));
        }
        if let Some(t) = self.fail_at_s {
            parts.push(format!("fail_at_s:{t}"));
        }
        if self.degrade > 0.0 {
            parts.push(format!("degrade:{}", self.degrade));
        }
        if self.degrade_factor != 1.0 {
            parts.push(format!("degrade_factor:{}", self.degrade_factor));
        }
        if self.loss > 0.0 {
            parts.push(format!("loss:{}", self.loss));
        }
        if self.jitter_ns > 0.0 {
            parts.push(format!("jitter_ns:{}", self.jitter_ns));
        }
        parts.join("|")
    }
}

/// The instantiated fault state of one experiment: per-directed-link
/// failure schedules and degradation factors plus the stochastic
/// loss/jitter parameters, all precomputed at build time from
/// `(FaultConfig, TorusSpec, seed)` — partition-independent by
/// construction.
#[derive(Clone, Debug)]
pub struct FaultModel {
    spec: TorusSpec,
    /// Per directed link (`node * TORUS_PORTS + port`): the instant (ps)
    /// at/after which the link is dead. `0` = dead from t=0,
    /// `u64::MAX` = never fails.
    fail_at_ps: Vec<u64>,
    /// Per directed link: serialization-time multiplier (1.0 = healthy).
    ser_scale: Vec<f64>,
    /// Earliest failure instant over all links (`u64::MAX` when no link
    /// ever fails) — the fast fault-free cutoff for [`FaultView`].
    min_fail_at_ps: u64,
    loss: f64,
    jitter_ns: f64,
    /// Base of the per-NIC loss/jitter streams ([`FaultModel::nic_rng`]).
    packet_seed: u64,
    failed_cables: usize,
    degraded_cables: usize,
}

impl FaultModel {
    /// Sample the fault state for `spec` under `cfg`, deterministically
    /// from `seed` (the experiment seed; a salt keeps this stream
    /// independent of every other consumer of the seed).
    pub fn build(cfg: &FaultConfig, spec: TorusSpec, seed: u64) -> FaultModel {
        let n_links = spec.n_nodes() * TORUS_PORTS as usize;
        let mut rng = Rng::new(seed ^ FAULT_SEED_SALT);

        let mut cables = spec.cables();
        rng.shuffle(&mut cables);
        let n_cables = cables.len();
        let n_fail = ((cfg.fail * n_cables as f64).round() as usize).min(n_cables);
        let n_degrade =
            ((cfg.degrade * n_cables as f64).round() as usize).min(n_cables - n_fail);

        let fail_at = match cfg.fail_at_s {
            None => 0u64,
            Some(t) => (t * 1e12).round() as u64,
        };
        let mut fail_at_ps = vec![u64::MAX; n_links];
        let mut ser_scale = vec![1.0f64; n_links];
        for &(a, d) in &cables[..n_fail] {
            let b = spec.neighbor(a, d);
            fail_at_ps[Self::idx(a, d)] = fail_at;
            fail_at_ps[Self::idx(b, d.opposite())] = fail_at;
        }
        for &(a, d) in &cables[n_fail..n_fail + n_degrade] {
            let b = spec.neighbor(a, d);
            ser_scale[Self::idx(a, d)] = cfg.degrade_factor;
            ser_scale[Self::idx(b, d.opposite())] = cfg.degrade_factor;
        }
        let min_fail_at_ps = if n_fail == 0 { u64::MAX } else { fail_at };

        FaultModel {
            spec,
            fail_at_ps,
            ser_scale,
            min_fail_at_ps,
            loss: cfg.loss,
            jitter_ns: cfg.jitter_ns,
            packet_seed: rng.next_u64(),
            failed_cables: n_fail,
            degraded_cables: n_degrade,
        }
    }

    #[inline]
    fn idx(a: NodeAddr, d: Dir) -> usize {
        a.0 as usize * TORUS_PORTS as usize + d.port() as usize
    }

    pub fn spec(&self) -> &TorusSpec {
        &self.spec
    }

    /// Number of physical cables failed by the schedule.
    pub fn failed_cables(&self) -> usize {
        self.failed_cables
    }

    /// Number of physical cables degraded to a slower serialization rate.
    pub fn degraded_cables(&self) -> usize {
        self.degraded_cables
    }

    /// Is the directed link usable at simulated time `now`?
    #[inline]
    pub fn link_alive_at(&self, from: NodeAddr, dir: Dir, now: Time) -> bool {
        now.ps() < self.fail_at_ps[Self::idx(from, dir)]
    }

    /// Does the directed link carry traffic at *any* point of the run?
    /// `false` exactly for links dead from t=0 — those never enter the
    /// PDES channel-clock bounds (`extoll::network::pdes_lookahead_with`).
    #[inline]
    pub fn link_ever_alive(&self, from: NodeAddr, dir: Dir) -> bool {
        self.fail_at_ps[Self::idx(from, dir)] > 0
    }

    /// Serialization-time multiplier of the directed link (1.0 = healthy).
    #[inline]
    pub fn ser_scale(&self, from: NodeAddr, dir: Dir) -> f64 {
        self.ser_scale[Self::idx(from, dir)]
    }

    /// Per-link-traversal loss probability.
    pub fn loss(&self) -> f64 {
        self.loss
    }

    /// Mean additive per-link latency jitter, ns (0 = none).
    pub fn jitter_ns(&self) -> f64 {
        self.jitter_ns
    }

    /// Does any NIC need an RNG stream (loss or jitter draws)?
    pub fn has_stochastic(&self) -> bool {
        self.loss > 0.0 || self.jitter_ns > 0.0
    }

    /// The loss/jitter stream of the NIC at `addr`: a fixed function of
    /// the model seed and the node address, so per-NIC draw sequences are
    /// identical however the simulation is partitioned.
    pub fn nic_rng(&self, addr: NodeAddr) -> Rng {
        let mut s = self
            .packet_seed
            .wrapping_add((addr.0 as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Rng::new(splitmix64(&mut s))
    }

    /// The [`LinkStatus`] view of this model at simulated time `now`.
    pub fn view(&self, now: Time) -> FaultView<'_> {
        FaultView {
            model: self,
            now_ps: now.ps(),
        }
    }
}

/// A [`FaultModel`] frozen at one simulation instant — the [`LinkStatus`]
/// the adaptive router evaluates.
#[derive(Clone, Copy)]
pub struct FaultView<'a> {
    model: &'a FaultModel,
    now_ps: u64,
}

impl LinkStatus for FaultView<'_> {
    #[inline]
    fn alive(&self, from: NodeAddr, dir: Dir) -> bool {
        self.now_ps < self.model.fail_at_ps[FaultModel::idx(from, dir)]
    }

    #[inline]
    fn fault_free(&self) -> bool {
        self.now_ps < self.model.min_fail_at_ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::routing::{live_distances, next_hop, next_hop_with, Hop};
    use crate::extoll::torus::DIRS;

    #[test]
    fn default_config_is_no_faults() {
        let cfg = FaultConfig::default();
        assert!(cfg.is_default());
        assert_eq!(cfg.to_spec(), "none");
        assert_eq!(FaultConfig::parse_spec("none").unwrap(), cfg);
        assert_eq!(FaultConfig::parse_spec("").unwrap(), cfg);
    }

    #[test]
    fn compact_spec_roundtrips() {
        let cfg = FaultConfig::parse_spec(
            "fail:0.25|fail_at_s:0.0001|degrade:0.1|degrade_factor:4|loss:0.01|jitter_ns:5",
        )
        .unwrap();
        assert_eq!(cfg.fail, 0.25);
        assert_eq!(cfg.fail_at_s, Some(0.0001));
        assert_eq!(cfg.degrade, 0.1);
        assert_eq!(cfg.degrade_factor, 4.0);
        assert_eq!(cfg.loss, 0.01);
        assert_eq!(cfg.jitter_ns, 5.0);
        assert_eq!(FaultConfig::parse_spec(&cfg.to_spec()).unwrap(), cfg);
    }

    #[test]
    fn json_spec_matches_compact_spec() {
        let compact = FaultConfig::parse_spec("fail:0.5|loss:0.02").unwrap();
        let json =
            FaultConfig::parse_spec(r#"{"fail": 0.5, "loss": 0.02}"#).unwrap();
        assert_eq!(compact, json);
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(FaultConfig::parse_spec("fail:1.5").is_err());
        assert!(FaultConfig::parse_spec("loss:1.0").is_err());
        assert!(FaultConfig::parse_spec("degrade_factor:0.5").is_err());
        assert!(FaultConfig::parse_spec("jitter_ns:-1").is_err());
        assert!(FaultConfig::parse_spec("frobnicate:1").is_err());
        assert!(FaultConfig::parse_spec("fail=0.5").is_err());
        assert!(FaultConfig::from_json(&Json::parse(r#"{"frobnicate": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn validation_errors_are_actionable_at_the_boundaries() {
        // loss == 1.0 sits exactly on the open bound: the message must
        // say what to use instead, not just reject
        let e = FaultConfig::parse_spec("loss:1.0").unwrap_err();
        assert!(e.contains("[0,1)"), "{e}");
        assert!(e.contains("fail:1"), "loss:1 error should point at fail: {e}");
        // NaN never satisfies a >= comparison, so every NaN knob errors
        let e = FaultConfig::parse_spec("jitter_ns:NaN").unwrap_err();
        assert!(e.contains("jitter_ns"), "{e}");
        assert!(FaultConfig::parse_spec("loss:NaN").is_err());
        assert!(FaultConfig::parse_spec("fail_at_s:NaN").is_err());
        assert!(FaultConfig::parse_spec("degrade_factor:NaN").is_err());
        // the closed bounds stay accepted
        assert!(FaultConfig::parse_spec("fail:1.0").is_ok());
        assert!(FaultConfig::parse_spec("degrade:1.0|degrade_factor:1.0").is_ok());
        assert!(FaultConfig::parse_spec("jitter_ns:0").is_ok());
    }

    #[test]
    fn unknown_json_key_error_lists_the_valid_keys() {
        let e = FaultConfig::from_json(&Json::parse(r#"{"frobnicate": 1}"#).unwrap())
            .unwrap_err();
        assert!(e.contains("frobnicate"), "{e}");
        for key in ["fail", "fail_at_s", "degrade", "degrade_factor", "loss", "jitter_ns"] {
            assert!(e.contains(key), "error must list valid key '{key}': {e}");
        }
    }

    #[test]
    fn duplicate_spec_keys_rejected() {
        let e = FaultConfig::parse_spec("loss:0.1|loss:0.2").unwrap_err();
        assert!(e.contains("duplicate"), "{e}");
        assert!(e.contains("loss"), "{e}");
        assert!(FaultConfig::parse_spec("fail:0.1|fail:0.1").is_err());
        // distinct keys that merely share a prefix are fine
        assert!(FaultConfig::parse_spec("fail:0.1|fail_at_s:1e-4").is_ok());
        assert!(FaultConfig::parse_spec("degrade:0.1|degrade_factor:2").is_ok());
    }

    #[test]
    fn build_is_deterministic_and_counts_match() {
        let spec = TorusSpec::new(4, 4, 4);
        let cfg = FaultConfig::parse_spec("fail:0.25|degrade:0.25|degrade_factor:2").unwrap();
        let a = FaultModel::build(&cfg, spec, 0xB55);
        let b = FaultModel::build(&cfg, spec, 0xB55);
        assert_eq!(a.fail_at_ps, b.fail_at_ps);
        assert_eq!(a.ser_scale, b.ser_scale);
        assert_eq!(a.packet_seed, b.packet_seed);

        let n_cables = spec.cables().len();
        assert_eq!(a.failed_cables(), (0.25 * n_cables as f64).round() as usize);
        assert_eq!(a.degraded_cables(), (0.25 * n_cables as f64).round() as usize);

        // a different seed samples a different fault set
        let c = FaultModel::build(&cfg, spec, 0xB56);
        assert_ne!(a.fail_at_ps, c.fail_at_ps);
    }

    #[test]
    fn cable_failures_are_symmetric() {
        let spec = TorusSpec::new(4, 2, 2);
        let cfg = FaultConfig::parse_spec("fail:0.5").unwrap();
        let m = FaultModel::build(&cfg, spec, 7);
        let now = Time::ZERO;
        for a in spec.nodes() {
            for d in DIRS {
                let b = spec.neighbor(a, d);
                if b == a {
                    continue;
                }
                assert_eq!(
                    m.link_alive_at(a, d, now),
                    m.link_alive_at(b, d.opposite(), now),
                    "cable ({a}, {d:?}) failed asymmetrically"
                );
            }
        }
    }

    #[test]
    fn fail_at_schedules_the_cutover() {
        let spec = TorusSpec::new(4, 1, 1);
        let cfg = FaultConfig::parse_spec("fail:1|fail_at_s:0.000001").unwrap(); // 1 µs
        let m = FaultModel::build(&cfg, spec, 1);
        assert_eq!(m.failed_cables(), spec.cables().len());
        let (a, d) = spec.cables()[0];
        assert!(m.link_alive_at(a, d, Time::ZERO));
        assert!(m.link_alive_at(a, d, Time::from_ns(999)));
        assert!(!m.link_alive_at(a, d, Time::from_us(1)));
        // scheduled-failure links did carry traffic before the cutover
        assert!(m.link_ever_alive(a, d));
        // the early view is still fault-free (fast path stays exact)
        assert!(m.view(Time::ZERO).fault_free());
        assert!(!m.view(Time::from_us(1)).fault_free());
    }

    #[test]
    fn zero_fault_model_is_fault_free_forever() {
        let spec = TorusSpec::new(2, 2, 2);
        let m = FaultModel::build(&FaultConfig::default(), spec, 3);
        assert_eq!(m.failed_cables(), 0);
        assert!(m.view(Time::from_ms(100)).fault_free());
        assert!(!m.has_stochastic());
        for a in spec.nodes() {
            for d in DIRS {
                assert!(m.link_ever_alive(a, d));
                assert_eq!(m.ser_scale(a, d), 1.0);
            }
        }
    }

    #[test]
    fn degraded_cables_scale_but_stay_alive() {
        let spec = TorusSpec::new(4, 1, 1);
        let cfg = FaultConfig::parse_spec("degrade:1|degrade_factor:3").unwrap();
        let m = FaultModel::build(&cfg, spec, 5);
        assert_eq!(m.failed_cables(), 0);
        assert_eq!(m.degraded_cables(), spec.cables().len());
        for (a, d) in spec.cables() {
            assert_eq!(m.ser_scale(a, d), 3.0);
            assert!(m.link_alive_at(a, d, Time::from_ms(10)));
        }
        // degradation alone keeps the fast routing path
        assert!(m.view(Time::from_ms(10)).fault_free());
    }

    #[test]
    fn nic_rng_streams_are_deterministic_and_distinct() {
        let spec = TorusSpec::new(2, 2, 1);
        let cfg = FaultConfig::parse_spec("loss:0.1").unwrap();
        let m = FaultModel::build(&cfg, spec, 9);
        assert!(m.has_stochastic());
        let mut a1 = m.nic_rng(NodeAddr(0));
        let mut a2 = m.nic_rng(NodeAddr(0));
        let mut b = m.nic_rng(NodeAddr(1));
        let mut same = 0;
        for _ in 0..64 {
            let x = a1.next_u64();
            assert_eq!(x, a2.next_u64());
            if x == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0, "per-NIC streams must be independent");
    }

    #[test]
    fn routing_detours_under_a_built_model() {
        // moderate failure rate on a well-connected torus: every pair
        // that remains connected must still route, loop-free
        let spec = TorusSpec::new(4, 4, 1);
        let cfg = FaultConfig::parse_spec("fail:0.2").unwrap();
        let m = FaultModel::build(&cfg, spec, 0xB55);
        assert!(m.failed_cables() > 0);
        let view = m.view(Time::ZERO);
        for dst in spec.nodes() {
            let dist = live_distances(&spec, &view, dst);
            for src in spec.nodes() {
                match next_hop_with(&spec, &view, src, dst) {
                    Hop::Deliver => assert_eq!(src, dst),
                    Hop::Unreachable => {
                        assert_eq!(dist[src.0 as usize], u32::MAX)
                    }
                    Hop::Via(d) => {
                        assert!(view.alive(src, d), "routed over a dead link");
                        let n = spec.neighbor(src, d);
                        assert_eq!(
                            dist[n.0 as usize] + 1,
                            dist[src.0 as usize],
                            "hop does not close in on {dst}"
                        );
                        // dimension-order preference: if the preferred dir
                        // closes in, it is the one chosen
                        let pref = next_hop(&spec, src, dst).unwrap();
                        let pn = spec.neighbor(src, pref);
                        if view.alive(src, pref)
                            && dist[pn.0 as usize] != u32::MAX
                            && dist[pn.0 as usize] + 1 == dist[src.0 as usize]
                        {
                            assert_eq!(d, pref);
                        }
                    }
                }
            }
        }
    }
}
