//! Tourmalet NIC model (paper §1).
//!
//! Each Tourmalet offers **7 links**: six form the 3D torus, the seventh
//! attaches the local unit (the wafer's concentrator, or a host). Every
//! link comprises up to **12 serial lanes of 8.4 Gbit/s** each. Routing is
//! done entirely in the NIC from the 16-bit destination address
//! (dimension-order, wrap-aware — see [`super::routing`]).
//!
//! The model is packet-granular store-and-forward: a packet occupies its
//! egress serializer for `wire_bytes · 8 / link_rate`, then arrives at the
//! neighbor after cable propagation plus the router pipeline latency.
//! Link-level flow control is credit-based with two virtual channels and
//! the classic *dateline* rule — packets traversing the wrap-around edge
//! of a ring switch to VC1 and stay there for the rest of that ring, and
//! the VC resets to 0 when the packet turns into a new dimension. Combined
//! with dimension-order routing this keeps the channel-dependency graph
//! acyclic, i.e. deadlock-free with finite input buffers.
//! `credits_per_vc = 0` disables flow control (infinite buffers).
//!
//! Allocation discipline on the hot path: transit is allocation-free —
//! packets move through the port queues by value and their spike payload
//! `Vec` is never touched. The payload's birth (bucket flush) and death
//! (FPGA RX) sites are closed into a free-list loop by
//! [`super::packet::pool`] (packet-object pooling; A/B'd in
//! `benches/bench_events.rs`).

use std::collections::VecDeque;

use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};
use crate::util::stats::Histogram;

use super::packet::Packet;
use super::routing::next_hop;
use super::torus::{Dir, NodeAddr, TorusSpec, LOCAL_PORT};

/// Physical/protocol parameters of a Tourmalet NIC and its links.
#[derive(Clone, Copy, Debug)]
pub struct NicConfig {
    /// Serial lanes per link (≤ 12).
    pub lanes: u32,
    /// Per-lane line rate in Gbit/s (8.4 for Tourmalet).
    pub gbps_per_lane: f64,
    /// Router pipeline latency per hop.
    pub hop_latency: Time,
    /// Cable propagation delay per link.
    pub cable_latency: Time,
    /// Input-buffer credits per (port, VC) in packets; 0 = unbounded.
    pub credits_per_vc: u32,
    /// Encoding efficiency of the serial lanes (64b/66b ≈ 0.97).
    pub efficiency: f64,
}

impl Default for NicConfig {
    fn default() -> Self {
        NicConfig {
            lanes: 12,
            gbps_per_lane: 8.4,
            hop_latency: Time::from_ns(70),
            cable_latency: Time::from_ns(5),
            credits_per_vc: 8,
            efficiency: 64.0 / 66.0,
        }
    }
}

impl NicConfig {
    /// Effective link rate in Gbit/s (lanes × lane rate × encoding).
    pub fn link_gbps(&self) -> f64 {
        self.lanes as f64 * self.gbps_per_lane * self.efficiency
    }

    /// Serialization time for `bytes` on one link.
    pub fn ser_time(&self, bytes: u32) -> Time {
        crate::sim::ps_for_bits(bytes as u64 * 8, self.link_gbps())
    }

    /// Latency of a link-level credit return to the upstream router: the
    /// credit flit rides the reverse-direction link, so it pays the cable
    /// propagation plus the receiving router's pipeline.
    pub fn credit_return_latency(&self) -> Time {
        self.cable_latency + self.hop_latency
    }

    /// The smallest latency **any** message can incur crossing a torus
    /// link — the link's contribution to the conservative-PDES lookahead
    /// (`docs/ARCHITECTURE.md`). Packets pay `ser + cable + hop` with
    /// `ser > 0`, credits pay exactly `cable + hop`, so the minimum is
    /// the credit-return latency.
    pub fn min_link_latency(&self) -> Time {
        self.credit_return_latency()
    }
}

/// Per-port egress state. One queue **per virtual channel**: a VC0 packet
/// stalled on credits must not block a VC1 packet behind it (head-of-line
/// separation is what makes the dateline scheme actually deadlock-free).
#[derive(Debug)]
struct Port {
    queues: [VecDeque<Packet>; 2],
    busy: bool,
    /// Remaining downstream credits per VC.
    credits: [u32; 2],
    /// Last VC served (round-robin arbitration between the VC queues).
    last_vc: u8,
    /// Cumulative busy time (for utilization reporting).
    busy_time: Time,
    tx_packets: u64,
    tx_bytes: u64,
    /// Peak total queue depth observed.
    peak_queue: usize,
}

impl Port {
    fn new(credits: u32) -> Self {
        Port {
            queues: [VecDeque::new(), VecDeque::new()],
            busy: false,
            credits: [credits, credits],
            last_vc: 1,
            busy_time: Time::ZERO,
            tx_packets: 0,
            tx_bytes: 0,
            peak_queue: 0,
        }
    }

    fn queued(&self) -> usize {
        self.queues[0].len() + self.queues[1].len()
    }

    /// Pick the next VC to serve: round-robin among non-empty queues whose
    /// credits allow transmission. Returns `None` if nothing can go.
    fn arbitrate(&self, limited: bool) -> Option<u8> {
        for i in 0..2u8 {
            let vc = (self.last_vc + 1 + i) % 2;
            if !self.queues[vc as usize].is_empty()
                && (!limited || self.credits[vc as usize] > 0)
            {
                return Some(vc);
            }
        }
        None
    }
}

/// Aggregated NIC statistics (read after the run via `Sim::get`).
#[derive(Clone, Debug, Default)]
pub struct NicStats {
    pub forwarded: u64,
    pub delivered: u64,
    pub injected: u64,
    pub delivered_events: u64,
    /// Fabric transit latency (inject → deliver), picoseconds.
    pub transit_ps: Histogram,
    /// Hops of delivered packets (torus hops, local link excluded).
    pub hops: Histogram,
    /// Credit-stall occurrences (head-of-line packet without credit).
    pub credit_stalls: u64,
}

/// The NIC actor. Port indices `0..TORUS_PORTS` are the torus directions
/// in [`super::torus::DIRS`] order ([`super::torus::TORUS_PORTS`]); port
/// [`LOCAL_PORT`] is the local link.
pub struct Nic {
    pub addr: NodeAddr,
    torus: TorusSpec,
    pub cfg: NicConfig,
    /// Actor ids: six torus neighbors + the local unit (if attached).
    neighbors: [Option<ActorId>; 7],
    ports: [Port; 7],
    pub stats: NicStats,
}

impl Nic {
    pub fn new(addr: NodeAddr, torus: TorusSpec, cfg: NicConfig) -> Self {
        let credits = cfg.credits_per_vc;
        Nic {
            addr,
            torus,
            cfg,
            neighbors: [None; 7],
            ports: std::array::from_fn(|_| Port::new(credits)),
            stats: NicStats::default(),
        }
    }

    /// Wire a torus neighbor (done by the network builder).
    pub fn set_neighbor(&mut self, dir: Dir, id: ActorId) {
        self.neighbors[dir.port() as usize] = Some(id);
    }

    /// Attach the local unit on the 7th link.
    pub fn attach_local(&mut self, id: ActorId) {
        self.neighbors[LOCAL_PORT as usize] = Some(id);
    }

    /// Utilization of a port over `window` (busy fraction 0..1).
    pub fn port_utilization(&self, port: u8, window: Time) -> f64 {
        if window == Time::ZERO {
            return 0.0;
        }
        self.ports[port as usize].busy_time.ps() as f64 / window.ps() as f64
    }

    pub fn port_tx_packets(&self, port: u8) -> u64 {
        self.ports[port as usize].tx_packets
    }

    pub fn port_tx_bytes(&self, port: u8) -> u64 {
        self.ports[port as usize].tx_bytes
    }

    pub fn port_peak_queue(&self, port: u8) -> usize {
        self.ports[port as usize].peak_queue
    }

    pub fn queued_packets(&self) -> usize {
        self.ports.iter().map(|p| p.queued()).sum()
    }

    /// Egress port for `p`, plus whether the hop crosses the wrap edge.
    fn egress_for(&self, p: &Packet) -> (u8, bool) {
        match next_hop(&self.torus, self.addr, p.dst) {
            None => (LOCAL_PORT, false),
            Some(dir) => {
                let (x, y, z) = self.torus.coords_of(self.addr);
                let coord = [x, y, z][dir.axis()];
                let n = self.torus.dims(dir.axis());
                let wraps = if dir.sign() > 0 { coord + 1 == n } else { coord == 0 };
                (dir.port(), wraps)
            }
        }
    }

    /// Route `p` onto an egress queue and kick the serializer.
    ///
    /// VC discipline (dateline): entering a new dimension resets to VC0;
    /// traversing the wrap edge of a ring promotes to VC1 for the rest of
    /// that ring.
    fn enqueue(&mut self, mut p: Packet, ctx: &mut Ctx<'_, Msg>) {
        let (port, wraps) = self.egress_for(&p);
        if port != LOCAL_PORT {
            let axis = Dir::from_port(port).axis() as u8;
            if axis != p.axis {
                p.vc = 0;
                p.axis = axis;
            }
            if wraps {
                p.vc = 1;
            }
        }
        let port_state = &mut self.ports[port as usize];
        port_state.queues[p.vc as usize].push_back(p);
        port_state.peak_queue = port_state.peak_queue.max(port_state.queued());
        self.try_tx(port, ctx);
    }

    /// Start transmission on `port` if idle and some VC has both a packet
    /// and a credit (round-robin among the VCs).
    fn try_tx(&mut self, port: u8, ctx: &mut Ctx<'_, Msg>) {
        let pi = port as usize;
        let Some(dst_actor) = self.neighbors[pi] else {
            panic!("nic {} port {port}: no neighbor wired", self.addr);
        };
        let limited = self.cfg.credits_per_vc > 0 && port != LOCAL_PORT;
        let vc = {
            let port_state = &self.ports[pi];
            if port_state.busy {
                return;
            }
            match port_state.arbitrate(limited) {
                Some(vc) => vc,
                None => {
                    if port_state.queued() > 0 {
                        self.stats.credit_stalls += 1;
                    }
                    return; // retried when a Credit message arrives
                }
            }
        };
        let port_state = &mut self.ports[pi];
        let mut p = port_state.queues[vc as usize].pop_front().unwrap();
        port_state.last_vc = vc;
        debug_assert_eq!(p.vc, vc);
        if limited {
            port_state.credits[vc as usize] -= 1;
        }
        let ser = self.cfg.ser_time(p.wire_bytes());
        port_state.busy = true;
        port_state.busy_time += ser;
        port_state.tx_packets += 1;
        port_state.tx_bytes += p.wire_bytes() as u64;

        // This packet no longer occupies our input buffer → return the
        // credit upstream for the (port, vc) slot it arrived on. The
        // credit crosses the reverse link (cable + pipeline); a positive
        // latency here is also what gives cross-domain PDES its lookahead.
        if let Some((up_actor, up_port, up_vc)) = p.ingress.take() {
            ctx.send(
                up_actor,
                self.cfg.credit_return_latency(),
                Msg::Credit {
                    port: up_port,
                    vc: up_vc,
                },
            );
        }

        p.hops += 1;
        let arrival = ser + self.cfg.cable_latency + self.cfg.hop_latency;
        if port == LOCAL_PORT {
            // Delivery over the 7th link to the attached unit.
            self.stats.delivered += 1;
            self.stats.delivered_events += p.n_events() as u64;
            self.stats.hops.record(p.hops as u64 - 1);
            let transit = (ctx.now() + arrival).saturating_sub(p.injected);
            self.stats.transit_ps.record(transit.ps());
            ctx.send(dst_actor, arrival, Msg::Deliver(p));
        } else {
            self.stats.forwarded += 1;
            p.ingress = Some((ctx.self_id(), port, p.vc));
            ctx.send(dst_actor, arrival, Msg::Packet(p));
        }
        ctx.send_self(ser, Msg::TxDone { port });
    }
}

impl Actor<Msg> for Nic {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Packet(p) => self.enqueue(p, ctx),
            Msg::Inject(mut p) => {
                self.stats.injected += 1;
                p.injected = ctx.now();
                p.ingress = None;
                p.vc = 0;
                p.axis = 3;
                self.enqueue(p, ctx);
            }
            Msg::TxDone { port } => {
                self.ports[port as usize].busy = false;
                self.try_tx(port, ctx);
            }
            Msg::Credit { port, vc } => {
                if self.cfg.credits_per_vc > 0 {
                    let ps = &mut self.ports[port as usize];
                    ps.credits[vc as usize] += 1;
                    debug_assert!(
                        ps.credits[vc as usize] <= self.cfg.credits_per_vc,
                        "credit overflow on {} port {port} vc {vc}",
                        self.addr
                    );
                }
                self.try_tx(port, ctx);
            }
            other => panic!("nic {}: unexpected message {:?}", self.addr, other),
        }
    }

    fn name(&self) -> String {
        format!("nic-{}", self.addr)
    }

    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::Site(self.addr.0 as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::network::build_torus;
    use crate::extoll::packet::Packet;
    use crate::extoll::torus::TORUS_PORTS;
    use crate::sim::Sim;

    /// Local unit that records deliveries.
    pub struct Sink {
        pub received: Vec<(Time, Packet)>,
    }

    impl Actor<Msg> for Sink {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            match msg {
                Msg::Deliver(p) => self.received.push((ctx.now(), p)),
                Msg::Credit { .. } => {}
                m => panic!("sink: unexpected {m:?}"),
            }
        }
    }

    fn setup(
        dims: (u16, u16, u16),
        cfg: NicConfig,
    ) -> (Sim<Msg>, TorusSpec, Vec<ActorId>, Vec<ActorId>) {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(dims.0, dims.1, dims.2);
        let nics = build_torus(&mut sim, &spec, cfg);
        let mut sinks = Vec::new();
        for &nic in nics.iter() {
            let sink = sim.add(Sink { received: vec![] });
            sim.get_mut::<Nic>(nic).attach_local(sink);
            sinks.push(sink);
        }
        (sim, spec, nics, sinks)
    }

    #[test]
    fn single_hop_delivery_latency() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, sinks) = setup((2, 1, 1), cfg);
        let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[1]);
        assert_eq!(sink.received.len(), 1);
        let (at, p) = &sink.received[0];
        // two link traversals (torus hop + local link), ser+cable+hop each
        let ser = cfg.ser_time(520);
        let expect = (ser + cfg.cable_latency + cfg.hop_latency) * 2;
        assert_eq!(*at, expect);
        assert_eq!(p.hops, 2);
    }

    #[test]
    fn delivery_to_self_goes_over_local_link_once() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, sinks) = setup((2, 2, 1), cfg);
        let p = Packet::raw(NodeAddr(0), NodeAddr(0), 64, Time::ZERO, 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        assert_eq!(sim.get::<Sink>(sinks[0]).received.len(), 1);
        assert_eq!(sim.get::<Sink>(sinks[0]).received[0].1.hops, 1);
    }

    #[test]
    fn all_pairs_arrive_exactly_once() {
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((3, 3, 2), cfg);
        let mut seq = 0u64;
        for s in spec.nodes() {
            for d in spec.nodes() {
                seq += 1;
                let p = Packet::raw(s, d, 128, Time::ZERO, seq);
                sim.schedule(Time::from_ns(seq), nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let total: usize = sinks
            .iter()
            .map(|&s| sim.get::<Sink>(s).received.len())
            .sum();
        assert_eq!(total, spec.n_nodes() * spec.n_nodes());
        for &s in &sinks {
            assert_eq!(sim.get::<Sink>(s).received.len(), spec.n_nodes());
        }
    }

    #[test]
    fn hop_count_matches_routing_distance() {
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((4, 4, 1), cfg);
        let src = NodeAddr(0);
        let dst = spec.addr_of(2, 3, 0);
        let p = Packet::raw(src, dst, 64, Time::ZERO, 9);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[dst.0 as usize]);
        assert_eq!(
            sink.received[0].1.hops as u32,
            spec.hop_distance(src, dst) + 1
        );
    }

    #[test]
    fn serialization_contention_queues() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, sinks) = setup((2, 1, 1), cfg);
        for seq in 0..2 {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[1]);
        assert_eq!(sink.received.len(), 2);
        let dt = sink.received[1].0 - sink.received[0].0;
        assert!(dt >= cfg.ser_time(520), "spacing {dt} too small");
    }

    #[test]
    fn utilization_accounting() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, _) = setup((2, 1, 1), cfg);
        for seq in 0..100 {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let nic: &Nic = sim.get(nics[0]);
        let tx: u64 = (0..TORUS_PORTS).map(|p| nic.port_tx_packets(p)).sum();
        assert_eq!(tx, 100);
        let bytes: u64 = (0..TORUS_PORTS).map(|p| nic.port_tx_bytes(p)).sum();
        assert_eq!(bytes, 52_000);
        // the egress port was busy for 100 serializations
        let busy: Time = nic.ports.iter().map(|p| p.busy_time).fold(Time::ZERO, |a, b| a + b);
        let local = cfg.ser_time(520) * 100; // local link on nic1, not nic0
        assert_eq!(busy, local);
    }

    #[test]
    fn credit_stalls_under_fanin() {
        // Many sources all target node 0 with tiny credits: stalls observed,
        // but every packet still arrives (no loss, no deadlock).
        let cfg = NicConfig {
            credits_per_vc: 1,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((4, 4, 1), cfg);
        let mut seq = 0;
        for s in spec.nodes() {
            if s.0 == 0 {
                continue;
            }
            for _ in 0..20 {
                seq += 1;
                let p = Packet::raw(s, NodeAddr(0), 496, Time::ZERO, seq);
                sim.schedule(Time::ZERO, nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[0]);
        assert_eq!(sink.received.len(), 15 * 20, "packets lost under backpressure");
        let total_stalls: u64 = nics
            .iter()
            .map(|&n| sim.get::<Nic>(n).stats.credit_stalls)
            .sum();
        assert!(total_stalls > 0, "expected credit stalls with 1-credit links");
    }

    #[test]
    fn wraparound_ring_saturation_no_deadlock() {
        // Every node sends to its antipode around an 8-ring with minimal
        // credits — the classic torus deadlock scenario; the dateline VC
        // rule must keep it live.
        let cfg = NicConfig {
            credits_per_vc: 1,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((8, 1, 1), cfg);
        let mut seq = 0;
        for s in spec.nodes() {
            let dst = NodeAddr((s.0 + 4) % 8);
            for _ in 0..50 {
                seq += 1;
                let p = Packet::raw(s, dst, 496, Time::ZERO, seq);
                sim.schedule(Time::ZERO, nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let total: usize = sinks
            .iter()
            .map(|&s| sim.get::<Sink>(s).received.len())
            .sum();
        assert_eq!(total, 8 * 50, "deadlock or loss in wrapped ring");
    }

    #[test]
    fn saturated_3d_torus_random_traffic_no_loss() {
        let cfg = NicConfig {
            credits_per_vc: 2,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((3, 3, 3), cfg);
        let mut rng = crate::util::rng::Rng::new(99);
        let n = spec.n_nodes();
        let mut sent = 0u64;
        for _ in 0..2000 {
            let s = rng.index(n);
            let d = rng.index(n);
            sent += 1;
            let p = Packet::raw(NodeAddr(s as u16), NodeAddr(d as u16), 256, Time::ZERO, sent);
            sim.schedule(Time::from_ns(rng.below(1000)), nics[s], Msg::Inject(p));
        }
        sim.run_to_completion();
        let total: usize = sinks
            .iter()
            .map(|&s| sim.get::<Sink>(s).received.len())
            .sum();
        assert_eq!(total as u64, sent);
    }

    #[test]
    fn link_rate_matches_tourmalet() {
        let cfg = NicConfig::default();
        // 12 lanes x 8.4 Gbit/s x 64/66 encoding ≈ 97.75 Gbit/s
        assert!((cfg.link_gbps() - 97.745).abs() < 0.01, "{}", cfg.link_gbps());
        let t = cfg.ser_time(520);
        assert!((t.ns_f64() - 42.56).abs() < 0.2, "{}", t.ns_f64());
    }
}
