//! Tourmalet NIC model (paper §1).
//!
//! Each Tourmalet offers **7 links**: six form the 3D torus, the seventh
//! attaches the local unit (the wafer's concentrator, or a host). Every
//! link comprises up to **12 serial lanes of 8.4 Gbit/s** each. Routing is
//! done entirely in the NIC from the 16-bit destination address
//! (dimension-order, wrap-aware — see [`super::routing`]).
//!
//! The model is packet-granular store-and-forward: a packet occupies its
//! egress serializer for `wire_bytes · 8 / link_rate`, then arrives at the
//! neighbor after cable propagation plus the router pipeline latency.
//! Link-level flow control is credit-based with two virtual channels and
//! the classic *dateline* rule — packets traversing the wrap-around edge
//! of a ring switch to VC1 and stay there for the rest of that ring, and
//! the VC resets to 0 when the packet turns into a new dimension. Combined
//! with dimension-order routing this keeps the channel-dependency graph
//! acyclic, i.e. deadlock-free with finite input buffers.
//! `credits_per_vc = 0` disables flow control (infinite buffers).
//!
//! Allocation discipline on the hot path: transit is allocation-free —
//! packets move through the port queues by value and their spike payload
//! `Vec` is never touched. The payload's birth (bucket flush) and death
//! (FPGA RX) sites are closed into a free-list loop by
//! [`super::packet::pool`] (packet-object pooling; A/B'd in
//! `benches/bench_events.rs`).

use std::cmp::Ordering;
use std::collections::VecDeque;
use std::sync::Arc;

use crate::fault::FaultModel;
use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};
use crate::util::rng::Rng;
use crate::util::stats::Histogram;

use super::link::{LinkLayer, LinkReliabilityConfig, Recovered, Reliability};
use super::packet::Packet;
use super::routing::{next_hop, next_hop_with, Hop};
use super::torus::{Dir, NodeAddr, TorusSpec, LOCAL_PORT};

/// Physical/protocol parameters of a Tourmalet NIC and its links.
#[derive(Clone, Copy, Debug)]
pub struct NicConfig {
    /// Serial lanes per link (≤ 12).
    pub lanes: u32,
    /// Per-lane line rate in Gbit/s (8.4 for Tourmalet).
    pub gbps_per_lane: f64,
    /// Router pipeline latency per hop.
    pub hop_latency: Time,
    /// Cable propagation delay per link.
    pub cable_latency: Time,
    /// Input-buffer credits per (port, VC) in packets; 0 = unbounded.
    pub credits_per_vc: u32,
    /// Encoding efficiency of the serial lanes (64b/66b ≈ 0.97).
    pub efficiency: f64,
    /// Link-level reliability protocol (`off` = CRC failures are silent
    /// loss, byte-identical to the pre-reliability fabric; `link` =
    /// ACK/NACK retransmission, [`super::link`]).
    pub reliability: Reliability,
    /// Retransmission-protocol knobs (only read under `reliability=link`).
    pub retx: LinkReliabilityConfig,
}

impl Default for NicConfig {
    fn default() -> Self {
        NicConfig {
            lanes: 12,
            gbps_per_lane: 8.4,
            hop_latency: Time::from_ns(70),
            cable_latency: Time::from_ns(5),
            credits_per_vc: 8,
            efficiency: 64.0 / 66.0,
            reliability: Reliability::Off,
            retx: LinkReliabilityConfig::default(),
        }
    }
}

impl NicConfig {
    /// Effective link rate in Gbit/s (lanes × lane rate × encoding).
    pub fn link_gbps(&self) -> f64 {
        self.lanes as f64 * self.gbps_per_lane * self.efficiency
    }

    /// Serialization time for `bytes` on one link.
    pub fn ser_time(&self, bytes: u32) -> Time {
        crate::sim::ps_for_bits(bytes as u64 * 8, self.link_gbps())
    }

    /// Latency of a link-level credit return to the upstream router: the
    /// credit flit rides the reverse-direction link, so it pays the cable
    /// propagation plus the receiving router's pipeline.
    pub fn credit_return_latency(&self) -> Time {
        self.cable_latency + self.hop_latency
    }

    /// The smallest latency **any** message can incur crossing a torus
    /// link — the link's contribution to the conservative-PDES lookahead
    /// (`docs/ARCHITECTURE.md`). Packets pay `ser + cable + hop` with
    /// `ser > 0`, credits pay exactly `cable + hop`, so the minimum is
    /// the credit-return latency.
    pub fn min_link_latency(&self) -> Time {
        self.credit_return_latency()
    }
}

/// Per-port egress state. One queue **per virtual channel**: a VC0 packet
/// stalled on credits must not block a VC1 packet behind it (head-of-line
/// separation is what makes the dateline scheme actually deadlock-free).
#[derive(Debug)]
struct Port {
    queues: [VecDeque<Packet>; 2],
    busy: bool,
    /// Remaining downstream credits per VC.
    credits: [u32; 2],
    /// Last VC served (round-robin arbitration between the VC queues).
    last_vc: u8,
    /// Cumulative busy time (for utilization reporting).
    busy_time: Time,
    tx_packets: u64,
    tx_bytes: u64,
    /// Peak total queue depth observed.
    peak_queue: usize,
}

impl Port {
    fn new(credits: u32) -> Self {
        Port {
            queues: [VecDeque::new(), VecDeque::new()],
            busy: false,
            credits: [credits, credits],
            last_vc: 1,
            busy_time: Time::ZERO,
            tx_packets: 0,
            tx_bytes: 0,
            peak_queue: 0,
        }
    }

    fn queued(&self) -> usize {
        self.queues[0].len() + self.queues[1].len()
    }

    /// Pick the next VC to serve: round-robin among non-empty queues whose
    /// credits allow transmission. Returns `None` if nothing can go.
    /// `fresh_blocked` is the reliability window stall: a head-of-line
    /// packet that is *not* a retransmission copy (`link_seq == 0`) is
    /// ineligible while the link's retransmission buffer is full —
    /// retransmissions always pass, which is what keeps the window stall
    /// from composing with credit stalls into a deadlock.
    fn arbitrate(&self, limited: bool, fresh_blocked: bool) -> Option<u8> {
        for i in 0..2u8 {
            let vc = (self.last_vc + 1 + i) % 2;
            let Some(head) = self.queues[vc as usize].front() else {
                continue;
            };
            if limited && self.credits[vc as usize] == 0 {
                continue;
            }
            if fresh_blocked && head.link_seq == 0 {
                continue;
            }
            return Some(vc);
        }
        None
    }
}

/// Aggregated NIC statistics (read after the run via `Sim::get`).
#[derive(Clone, Debug, Default)]
pub struct NicStats {
    pub forwarded: u64,
    pub delivered: u64,
    pub injected: u64,
    pub delivered_events: u64,
    /// Spike events injected at this NIC (sum of `n_events` over injects).
    pub injected_events: u64,
    /// Fabric transit latency (inject → deliver), picoseconds.
    pub transit_ps: Histogram,
    /// Hops of delivered packets (torus hops, local link excluded).
    pub hops: Histogram,
    /// Fault-free shortest-path hop distance src→dst of delivered packets —
    /// the baseline against which detour hop inflation is measured.
    pub min_hops: Histogram,
    /// Credit-stall occurrences (head-of-line packet without credit).
    pub credit_stalls: u64,
    /// Packets dropped by stochastic link loss (receiver side).
    pub lost_packets: u64,
    /// Spike events inside lost packets.
    pub lost_events: u64,
    /// Packets dropped because no live route to the destination existed.
    pub undeliverable_packets: u64,
    /// Spike events inside undeliverable packets.
    pub undeliverable_events: u64,
    /// Hops taken off the dimension-order path to route around faults.
    pub detour_hops: u64,
    /// Retransmission copies transmitted (`reliability=link`).
    pub retransmissions: u64,
    /// NACKs sent by this NIC's receive side (CRC failure or sequence gap).
    pub nacks: u64,
    /// Retransmission-timer expirations that triggered a replay.
    pub timeouts: u64,
    /// Packets acknowledged after at least one retransmission — losses the
    /// link layer recovered.
    pub recovered_packets: u64,
    /// Spike events inside recovered packets.
    pub recovered_events: u64,
    /// Received packets dropped as already-accepted duplicates.
    pub duplicate_packets: u64,
    /// Packets abandoned after the retry budget (also counted in
    /// `undeliverable_packets` — surfaced, never silently dropped).
    pub residual_loss_packets: u64,
    /// Spike events inside abandoned packets.
    pub residual_loss_events: u64,
    /// Link-layer recovery latency (first transmission → cumulative ACK)
    /// of recovered packets, picoseconds.
    pub recovery_ps: Histogram,
}

/// Per-NIC fault-injection state: a shared handle on the fabric-wide
/// [`FaultModel`] plus this NIC's private packet-level RNG (loss draws,
/// latency jitter). The RNG is seeded from the model and the NIC address
/// only, so its draw sequence is a pure function of this actor's event
/// order — which the engine keeps partition-independent (determinism
/// contract, `docs/ARCHITECTURE.md`).
struct FaultHandle {
    model: Arc<FaultModel>,
    rng: Rng,
}

/// Outcome of the egress decision for one packet at one NIC.
enum Egress {
    /// Forward out `port`; `wraps` = crosses the ring's wrap edge,
    /// `detour` = adaptive step off the dimension-order path.
    Port { port: u8, wraps: bool, detour: bool },
    /// No live path to the destination exists right now.
    Undeliverable,
}

/// The NIC actor. Port indices `0..TORUS_PORTS` are the torus directions
/// in [`super::torus::DIRS`] order ([`super::torus::TORUS_PORTS`]); port
/// [`LOCAL_PORT`] is the local link.
pub struct Nic {
    pub addr: NodeAddr,
    torus: TorusSpec,
    pub cfg: NicConfig,
    /// Actor ids: six torus neighbors + the local unit (if attached).
    neighbors: [Option<ActorId>; 7],
    ports: [Port; 7],
    pub stats: NicStats,
    fault: Option<FaultHandle>,
    /// Link reliability state — `Some` iff `cfg.reliability == Link`.
    link: Option<LinkLayer>,
}

impl Nic {
    pub fn new(addr: NodeAddr, torus: TorusSpec, cfg: NicConfig) -> Self {
        let credits = cfg.credits_per_vc;
        let link = match cfg.reliability {
            Reliability::Off => None,
            Reliability::Link => Some(LinkLayer::new(cfg.retx)),
        };
        Nic {
            addr,
            torus,
            cfg,
            neighbors: [None; 7],
            ports: std::array::from_fn(|_| Port::new(credits)),
            stats: NicStats::default(),
            fault: None,
            link,
        }
    }

    /// Install a fault model (done by the network builder before the run
    /// starts). Without one the NIC routes pure dimension-order with no
    /// loss, jitter, or degradation — bit-identical to the pre-fault code.
    pub fn set_fault_model(&mut self, model: Arc<FaultModel>) {
        let rng = model.nic_rng(self.addr);
        self.fault = Some(FaultHandle { model, rng });
    }

    /// Wire a torus neighbor (done by the network builder).
    pub fn set_neighbor(&mut self, dir: Dir, id: ActorId) {
        self.neighbors[dir.port() as usize] = Some(id);
    }

    /// Attach the local unit on the 7th link.
    pub fn attach_local(&mut self, id: ActorId) {
        self.neighbors[LOCAL_PORT as usize] = Some(id);
    }

    /// Utilization of a port over `window` (busy fraction 0..1).
    pub fn port_utilization(&self, port: u8, window: Time) -> f64 {
        if window == Time::ZERO {
            return 0.0;
        }
        self.ports[port as usize].busy_time.ps() as f64 / window.ps() as f64
    }

    pub fn port_tx_packets(&self, port: u8) -> u64 {
        self.ports[port as usize].tx_packets
    }

    pub fn port_tx_bytes(&self, port: u8) -> u64 {
        self.ports[port as usize].tx_bytes
    }

    pub fn port_peak_queue(&self, port: u8) -> usize {
        self.ports[port as usize].peak_queue
    }

    pub fn queued_packets(&self) -> usize {
        self.ports.iter().map(|p| p.queued()).sum()
    }

    /// Egress decision for `p` at simulation time `now`.
    fn egress_for(&self, p: &Packet, now: Time) -> Egress {
        let hop = match &self.fault {
            None => match next_hop(&self.torus, self.addr, p.dst) {
                None => Hop::Deliver,
                Some(dir) => Hop::Via(dir),
            },
            Some(f) => next_hop_with(&self.torus, &f.model.view(now), self.addr, p.dst),
        };
        match hop {
            Hop::Deliver => Egress::Port { port: LOCAL_PORT, wraps: false, detour: false },
            Hop::Unreachable => Egress::Undeliverable,
            Hop::Via(dir) => {
                let (x, y, z) = self.torus.coords_of(self.addr);
                let coord = [x, y, z][dir.axis()];
                let n = self.torus.dims(dir.axis());
                let wraps = if dir.sign() > 0 { coord + 1 == n } else { coord == 0 };
                let detour = self.fault.is_some()
                    && next_hop(&self.torus, self.addr, p.dst) != Some(dir);
                Egress::Port { port: dir.port(), wraps, detour }
            }
        }
    }

    /// Return the upstream flow-control credit for a packet that is being
    /// removed from our input buffer without being forwarded (lost or
    /// undeliverable). Dropping a packet must never leak its credit, or
    /// the upstream (port, vc) slot would throttle forever.
    fn release_ingress(&self, p: &mut Packet, ctx: &mut Ctx<'_, Msg>) {
        if let Some((up_actor, up_port, up_vc)) = p.ingress.take() {
            ctx.send(
                up_actor,
                self.cfg.credit_return_latency(),
                Msg::Credit {
                    port: up_port,
                    vc: up_vc,
                },
            );
        }
    }

    /// Route `p` onto an egress queue and kick the serializer.
    ///
    /// VC discipline (dateline): entering a new dimension resets to VC0;
    /// traversing the wrap edge of a ring promotes to VC1 for the rest of
    /// that ring. Detour hops (adaptive steps off the dimension-order
    /// path, taken only under faults) also ride VC1: VC1 queues drain in
    /// dimension-order like everything else, and promoting the detoured
    /// packet to the escape channel means it can never close a VC0 cycle
    /// that dimension-order routing itself would not create.
    fn enqueue(&mut self, mut p: Packet, ctx: &mut Ctx<'_, Msg>) {
        let (port, wraps, detour) = match self.egress_for(&p, ctx.now()) {
            Egress::Port { port, wraps, detour } => (port, wraps, detour),
            Egress::Undeliverable => {
                self.stats.undeliverable_packets += 1;
                self.stats.undeliverable_events += p.n_events() as u64;
                self.release_ingress(&mut p, ctx);
                return;
            }
        };
        if port != LOCAL_PORT {
            let axis = Dir::from_port(port).axis() as u8;
            if axis != p.axis {
                p.vc = 0;
                p.axis = axis;
            }
            if wraps || detour {
                p.vc = 1;
            }
            if detour {
                self.stats.detour_hops += 1;
            }
        }
        let port_state = &mut self.ports[port as usize];
        port_state.queues[p.vc as usize].push_back(p);
        port_state.peak_queue = port_state.peak_queue.max(port_state.queued());
        self.try_tx(port, ctx);
    }

    /// Start transmission on `port` if idle and some VC has both a packet
    /// and a credit (round-robin among the VCs).
    fn try_tx(&mut self, port: u8, ctx: &mut Ctx<'_, Msg>) {
        let pi = port as usize;
        let Some(dst_actor) = self.neighbors[pi] else {
            panic!("nic {} port {port}: no neighbor wired", self.addr);
        };
        let limited = self.cfg.credits_per_vc > 0 && port != LOCAL_PORT;
        let reliable = port != LOCAL_PORT && self.link.is_some();
        let window_full = match &self.link {
            Some(l) if reliable => l.tx[pi].window_full(l.cfg.window),
            _ => false,
        };
        let vc = {
            let port_state = &self.ports[pi];
            if port_state.busy {
                return;
            }
            match port_state.arbitrate(limited, window_full) {
                Some(vc) => vc,
                None => {
                    if port_state.queued() > 0 {
                        self.stats.credit_stalls += 1;
                    }
                    return; // retried on Credit arrival / ACK progress
                }
            }
        };
        let port_state = &mut self.ports[pi];
        let mut p = port_state.queues[vc as usize].pop_front().unwrap();
        port_state.last_vc = vc;
        debug_assert_eq!(p.vc, vc);
        if limited {
            port_state.credits[vc as usize] -= 1;
        }
        let mut ser = self.cfg.ser_time(p.wire_bytes());
        if port != LOCAL_PORT {
            if let Some(f) = &self.fault {
                // A degraded cable serializes slower (fewer live lanes).
                let scale = f.model.ser_scale(self.addr, Dir::from_port(port));
                if scale != 1.0 {
                    ser = Time::from_ps((ser.ps() as f64 * scale).round() as u64);
                }
            }
        }
        port_state.busy = true;
        port_state.busy_time += ser;
        port_state.tx_packets += 1;
        port_state.tx_bytes += p.wire_bytes() as u64;

        // This packet no longer occupies our input buffer → return the
        // credit upstream for the (port, vc) slot it arrived on. The
        // credit crosses the reverse link (cable + pipeline); a positive
        // latency here is also what gives cross-domain PDES its lookahead.
        if let Some((up_actor, up_port, up_vc)) = p.ingress.take() {
            ctx.send(
                up_actor,
                self.cfg.credit_return_latency(),
                Msg::Credit {
                    port: up_port,
                    vc: up_vc,
                },
            );
        }

        // A retransmission copy (stamped before it was queued) crosses the
        // same cable again: it is a new transmission for the wire stats
        // above, but not a new topological hop.
        let is_retx = reliable && p.link_seq != 0;
        if is_retx {
            self.stats.retransmissions += 1;
        } else {
            p.hops += 1;
        }
        let mut arrival = ser + self.cfg.cable_latency + self.cfg.hop_latency;
        if port != LOCAL_PORT {
            if let Some(f) = &mut self.fault {
                if f.model.jitter_ns() > 0.0 {
                    // Exponential latency jitter with mean `jitter_ns`
                    // (Rng::exponential takes a *rate*). Additive only, so
                    // the healthy `min_link_latency` stays a sound PDES
                    // lookahead bound.
                    let jitter_ns = f.rng.exponential(1.0 / f.model.jitter_ns());
                    arrival += Time::from_ps((jitter_ns * 1e3).round() as u64);
                }
            }
        }
        if port == LOCAL_PORT {
            // Delivery over the 7th link to the attached unit.
            self.stats.delivered += 1;
            self.stats.delivered_events += p.n_events() as u64;
            self.stats.hops.record(p.hops as u64 - 1);
            self.stats
                .min_hops
                .record(self.torus.hop_distance(p.src, p.dst) as u64);
            let transit = (ctx.now() + arrival).saturating_sub(p.injected);
            self.stats.transit_ps.record(transit.ps());
            ctx.send(dst_actor, arrival, Msg::Deliver(p));
        } else {
            if reliable {
                let now = ctx.now();
                let link = self.link.as_mut().unwrap();
                let tx = &mut link.tx[pi];
                if is_retx {
                    tx.mark_sent(p.link_seq);
                } else {
                    // Stamp and buffer a retransmission copy. The copy's
                    // `ingress` is cleared: the upstream credit for the
                    // original was already returned above, and a replayed
                    // copy must never return it again.
                    p.link_seq = tx.stamp();
                    let mut copy = p.clone();
                    copy.ingress = None;
                    tx.record(p.link_seq, copy, now);
                }
                tx.last_progress = now;
            }
            self.stats.forwarded += 1;
            p.ingress = Some((ctx.self_id(), port, p.vc));
            ctx.send(dst_actor, arrival, Msg::Packet(p));
            if reliable {
                self.arm_timer(port, ctx);
            }
        }
        ctx.send_self(ser, Msg::TxDone { port });
    }

    /// Receive-side of the link reliability protocol: CRC check, per-link
    /// sequence check, cumulative ACK / go-back-N NACK. Control frames are
    /// modeled like credit flits — they occupy no input buffer, consume no
    /// credits, and cross the reverse link in exactly
    /// [`NicConfig::credit_return_latency`] (= the PDES lookahead bound).
    fn receive_reliable(&mut self, mut p: Packet, crc_failed: bool, ctx: &mut Ctx<'_, Msg>) {
        let (up_actor, up_port, _) = *p
            .ingress
            .as_ref()
            .expect("reliable packet without ingress bookkeeping");
        let lat = self.cfg.credit_return_latency();
        if crc_failed {
            // The CRC covers the whole packet, so the sequence field of a
            // corrupted packet cannot be trusted either — NACK the next
            // expected sequence and go-back-N from there.
            let expect = {
                let link = self.link.as_mut().unwrap();
                *link.rx_expect(up_actor, up_port)
            };
            self.stats.lost_packets += 1;
            self.stats.lost_events += p.n_events() as u64;
            self.stats.nacks += 1;
            self.release_ingress(&mut p, ctx);
            ctx.send(up_actor, lat, Msg::Nack { port: up_port, expect });
            return;
        }
        let seq = p.link_seq;
        debug_assert_ne!(seq, 0, "unstamped packet on a reliable link");
        let expect = {
            let link = self.link.as_mut().unwrap();
            *link.rx_expect(up_actor, up_port)
        };
        match seq.cmp(&expect) {
            Ordering::Equal => {
                // In-order: accept, cumulatively acknowledge, and clear
                // the link stamp — the next hop's transmitter re-stamps
                // with its own link sequence.
                *self.link.as_mut().unwrap().rx_expect(up_actor, up_port) = seq + 1;
                ctx.send(up_actor, lat, Msg::Ack { port: up_port, ack: seq + 1 });
                p.link_seq = 0;
                self.enqueue(p, ctx);
            }
            Ordering::Less => {
                // Already accepted (a replayed copy of an acknowledged
                // packet, or its ACK was lost to the sender's give-up
                // race): drop it, but re-ACK so the sender retires it.
                self.stats.duplicate_packets += 1;
                self.release_ingress(&mut p, ctx);
                ctx.send(up_actor, lat, Msg::Ack { port: up_port, ack: expect });
            }
            Ordering::Greater => {
                // Gap: an earlier packet was lost on this link (links are
                // FIFO without jitter, so a gap implies genuine loss; with
                // jitter a retransmission may be overtaken — the NACK is
                // then suppressed sender-side and the timeout recovers).
                self.stats.nacks += 1;
                self.release_ingress(&mut p, ctx);
                ctx.send(up_actor, lat, Msg::Nack { port: up_port, expect });
            }
        }
    }

    /// Cumulative-ACK bookkeeping shared by ACK and NACK arrivals.
    fn account_recovered(&mut self, recovered: Vec<Recovered>, now: Time) {
        for r in recovered {
            self.stats.recovered_packets += 1;
            self.stats.recovered_events += r.events;
            self.stats.recovery_ps.record(now.saturating_sub(r.first_tx).ps());
        }
    }

    /// Drop queued retransmission copies that a cumulative ACK (or a
    /// give-up) has made obsolete. Copies carry no `ingress`, so removal
    /// has no credit side effects.
    fn purge_retx_queue(&mut self, pi: usize, below: u64) {
        let port_state = &mut self.ports[pi];
        for q in port_state.queues.iter_mut() {
            q.retain(|qp| qp.link_seq == 0 || qp.link_seq >= below);
        }
    }

    fn handle_ack(&mut self, port: u8, ack: u64, ctx: &mut Ctx<'_, Msg>) {
        let pi = port as usize;
        let mut recovered = Vec::new();
        let progressed = {
            let link = self
                .link
                .as_mut()
                .expect("nic: Ack without reliability layer");
            link.tx[pi].ack_advance(ack, &mut recovered)
        };
        self.account_recovered(recovered, ctx.now());
        if progressed {
            {
                let link = self.link.as_mut().unwrap();
                let tx = &mut link.tx[pi];
                tx.backoff = 0;
                tx.replayed_for = None;
                tx.last_progress = ctx.now();
            }
            self.purge_retx_queue(pi, ack);
            // the window may have freed a fresh head-of-line packet
            self.try_tx(port, ctx);
        }
    }

    fn handle_nack(&mut self, port: u8, expect: u64, ctx: &mut Ctx<'_, Msg>) {
        let pi = port as usize;
        let mut recovered = Vec::new();
        let (progressed, do_replay) = {
            let link = self
                .link
                .as_mut()
                .expect("nic: Nack without reliability layer");
            let tx = &mut link.tx[pi];
            // A NACK is also a cumulative ACK for everything below it.
            let progressed = tx.ack_advance(expect, &mut recovered);
            if progressed {
                tx.backoff = 0;
                tx.last_progress = ctx.now();
            }
            // Each packet arriving behind the gap repeats the same NACK —
            // replay only once per base; the timeout is the backstop if
            // the replay itself is lost.
            let do_replay = tx.replayed_for != Some(expect) && !tx.is_empty();
            tx.replayed_for = Some(expect);
            (progressed, do_replay)
        };
        self.account_recovered(recovered, ctx.now());
        if progressed {
            self.purge_retx_queue(pi, expect);
        }
        if do_replay {
            self.replay(port, ctx);
        } else if progressed {
            self.try_tx(port, ctx);
        }
    }

    /// One go-back-N replay round on `port`: age every in-flight entry,
    /// abandon the over-budget prefix (surfaced as undeliverable +
    /// residual loss, receiver advanced via [`Msg::SeqSkip`]), re-queue
    /// retransmission copies ahead of fresh traffic on their original VCs.
    fn replay(&mut self, port: u8, ctx: &mut Ctx<'_, Msg>) {
        let pi = port as usize;
        let out = {
            let link = self
                .link
                .as_mut()
                .expect("nic: replay without reliability layer");
            let max_retries = link.cfg.max_retries;
            link.tx[pi].replay(max_retries)
        };
        if out.residual_packets > 0 {
            self.stats.undeliverable_packets += out.residual_packets;
            self.stats.undeliverable_events += out.residual_events;
            self.stats.residual_loss_packets += out.residual_packets;
            self.stats.residual_loss_events += out.residual_events;
            let Some(dst_actor) = self.neighbors[pi] else {
                panic!("nic {} port {port}: no neighbor wired", self.addr);
            };
            // The receiver must stop expecting the abandoned prefix, or
            // go-back-N would NACK it forever.
            ctx.send(
                dst_actor,
                self.cfg.credit_return_latency(),
                Msg::SeqSkip {
                    sender: ctx.self_id(),
                    port,
                    expect: out.skip_to,
                },
            );
            self.purge_retx_queue(pi, out.skip_to);
        }
        let port_state = &mut self.ports[pi];
        // ascending sequence → reversed push_front keeps replay order and
        // puts the copies ahead of fresh packets on each VC
        for p in out.clones.into_iter().rev() {
            port_state.queues[p.vc as usize].push_front(p);
        }
        port_state.peak_queue = port_state.peak_queue.max(port_state.queued());
        self.arm_timer(port, ctx);
        self.try_tx(port, ctx);
    }

    /// Arm the port's retransmission timer if it has in-flight packets and
    /// no timer outstanding. One timer event per port at a time — the
    /// handler checks real progress, so a stale firing re-arms for the
    /// remainder instead of replaying.
    fn arm_timer(&mut self, port: u8, ctx: &mut Ctx<'_, Msg>) {
        let Some(link) = self.link.as_mut() else {
            return;
        };
        let tx = &mut link.tx[port as usize];
        if tx.timer_outstanding || tx.is_empty() {
            return;
        }
        tx.timer_outstanding = true;
        let dt = link.cfg.timeout_after(tx.backoff);
        ctx.send_self(dt, Msg::RetxTimer { port });
    }

    fn handle_retx_timer(&mut self, port: u8, ctx: &mut Ctx<'_, Msg>) {
        let pi = port as usize;
        let now = ctx.now();
        let deadline = {
            let link = self
                .link
                .as_mut()
                .expect("nic: RetxTimer without reliability layer");
            let tx = &mut link.tx[pi];
            tx.timer_outstanding = false;
            if tx.is_empty() {
                return; // fully acknowledged; next transmission re-arms
            }
            tx.last_progress + link.cfg.timeout_after(tx.backoff)
        };
        if now < deadline {
            // progress happened since this timer was armed — stale firing
            let link = self.link.as_mut().unwrap();
            link.tx[pi].timer_outstanding = true;
            ctx.send_self(deadline - now, Msg::RetxTimer { port });
            return;
        }
        // Genuine timeout: the link showed no life for a full (backed-off)
        // timeout. Reached only when both a loss and its NACK-triggered
        // replay were lost (NACK suppression), or when the peer is silent.
        self.stats.timeouts += 1;
        {
            let link = self.link.as_mut().unwrap();
            let tx = &mut link.tx[pi];
            tx.backoff = (tx.backoff + 1).min(link.cfg.backoff_cap);
            tx.replayed_for = None;
            tx.last_progress = now;
        }
        self.replay(port, ctx);
    }
}

impl Actor<Msg> for Nic {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Packet(mut p) => {
                // Stochastic link loss is modeled at the receiver: the
                // packet already paid serialization + wire time, and the
                // upstream credit must still come back (a real lost flit
                // frees its buffer slot too — credits never leak). Under
                // `reliability=link` the same draw is a CRC failure that
                // the protocol detects and recovers instead of dropping.
                let crc_failed = match &mut self.fault {
                    Some(f) if f.model.loss() > 0.0 => f.rng.chance(f.model.loss()),
                    _ => false,
                };
                if self.link.is_some() {
                    self.receive_reliable(p, crc_failed, ctx);
                } else if crc_failed {
                    self.stats.lost_packets += 1;
                    self.stats.lost_events += p.n_events() as u64;
                    self.release_ingress(&mut p, ctx);
                } else {
                    self.enqueue(p, ctx);
                }
            }
            Msg::Inject(mut p) => {
                self.stats.injected += 1;
                self.stats.injected_events += p.n_events() as u64;
                p.injected = ctx.now();
                p.ingress = None;
                p.vc = 0;
                p.axis = 3;
                self.enqueue(p, ctx);
            }
            Msg::TxDone { port } => {
                self.ports[port as usize].busy = false;
                self.try_tx(port, ctx);
            }
            Msg::Credit { port, vc } => {
                if self.cfg.credits_per_vc > 0 {
                    let ps = &mut self.ports[port as usize];
                    ps.credits[vc as usize] += 1;
                    debug_assert!(
                        ps.credits[vc as usize] <= self.cfg.credits_per_vc,
                        "credit overflow on {} port {port} vc {vc}",
                        self.addr
                    );
                }
                self.try_tx(port, ctx);
            }
            Msg::Ack { port, ack } => self.handle_ack(port, ack, ctx),
            Msg::Nack { port, expect } => self.handle_nack(port, expect, ctx),
            Msg::SeqSkip { sender, port, expect } => {
                let link = self
                    .link
                    .as_mut()
                    .expect("nic: SeqSkip without reliability layer");
                link.rx_skip(sender, port, expect);
            }
            Msg::RetxTimer { port } => self.handle_retx_timer(port, ctx),
            other => panic!("nic {}: unexpected message {:?}", self.addr, other),
        }
    }

    fn name(&self) -> String {
        format!("nic-{}", self.addr)
    }

    fn placement(&self) -> crate::sim::Placement {
        crate::sim::Placement::Site(self.addr.0 as u32)
    }

    /// Reconstruct from config, keeping the neighbor/local wiring and
    /// re-installing the fault model. `Nic::new` is a pure function of
    /// `(addr, torus, cfg)`, and `set_fault_model` re-derives the packet
    /// RNG from the model and address alone, so the reset NIC is
    /// byte-identical to a freshly built one.
    fn reset(&mut self) -> bool {
        let neighbors = self.neighbors;
        let fault = self.fault.take();
        *self = Nic::new(self.addr, self.torus, self.cfg);
        self.neighbors = neighbors;
        if let Some(f) = fault {
            self.set_fault_model(f.model);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::network::build_torus;
    use crate::extoll::packet::Packet;
    use crate::extoll::torus::TORUS_PORTS;
    use crate::fault::FaultConfig;
    use crate::sim::Sim;

    /// Local unit that records deliveries.
    pub struct Sink {
        pub received: Vec<(Time, Packet)>,
    }

    impl Actor<Msg> for Sink {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            match msg {
                Msg::Deliver(p) => self.received.push((ctx.now(), p)),
                Msg::Credit { .. } => {}
                m => panic!("sink: unexpected {m:?}"),
            }
        }
    }

    fn setup(
        dims: (u16, u16, u16),
        cfg: NicConfig,
    ) -> (Sim<Msg>, TorusSpec, Vec<ActorId>, Vec<ActorId>) {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(dims.0, dims.1, dims.2);
        let nics = build_torus(&mut sim, &spec, cfg);
        let mut sinks = Vec::new();
        for &nic in nics.iter() {
            let sink = sim.add(Sink { received: vec![] });
            sim.get_mut::<Nic>(nic).attach_local(sink);
            sinks.push(sink);
        }
        (sim, spec, nics, sinks)
    }

    #[test]
    fn single_hop_delivery_latency() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, sinks) = setup((2, 1, 1), cfg);
        let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[1]);
        assert_eq!(sink.received.len(), 1);
        let (at, p) = &sink.received[0];
        // two link traversals (torus hop + local link), ser+cable+hop each
        let ser = cfg.ser_time(520);
        let expect = (ser + cfg.cable_latency + cfg.hop_latency) * 2;
        assert_eq!(*at, expect);
        assert_eq!(p.hops, 2);
    }

    #[test]
    fn delivery_to_self_goes_over_local_link_once() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, sinks) = setup((2, 2, 1), cfg);
        let p = Packet::raw(NodeAddr(0), NodeAddr(0), 64, Time::ZERO, 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        assert_eq!(sim.get::<Sink>(sinks[0]).received.len(), 1);
        assert_eq!(sim.get::<Sink>(sinks[0]).received[0].1.hops, 1);
    }

    #[test]
    fn all_pairs_arrive_exactly_once() {
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((3, 3, 2), cfg);
        let mut seq = 0u64;
        for s in spec.nodes() {
            for d in spec.nodes() {
                seq += 1;
                let p = Packet::raw(s, d, 128, Time::ZERO, seq);
                sim.schedule(Time::from_ns(seq), nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let total: usize = sinks
            .iter()
            .map(|&s| sim.get::<Sink>(s).received.len())
            .sum();
        assert_eq!(total, spec.n_nodes() * spec.n_nodes());
        for &s in &sinks {
            assert_eq!(sim.get::<Sink>(s).received.len(), spec.n_nodes());
        }
    }

    #[test]
    fn hop_count_matches_routing_distance() {
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((4, 4, 1), cfg);
        let src = NodeAddr(0);
        let dst = spec.addr_of(2, 3, 0);
        let p = Packet::raw(src, dst, 64, Time::ZERO, 9);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[dst.0 as usize]);
        assert_eq!(
            sink.received[0].1.hops as u32,
            spec.hop_distance(src, dst) + 1
        );
    }

    #[test]
    fn serialization_contention_queues() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, sinks) = setup((2, 1, 1), cfg);
        for seq in 0..2 {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[1]);
        assert_eq!(sink.received.len(), 2);
        let dt = sink.received[1].0 - sink.received[0].0;
        assert!(dt >= cfg.ser_time(520), "spacing {dt} too small");
    }

    #[test]
    fn utilization_accounting() {
        let cfg = NicConfig::default();
        let (mut sim, _, nics, _) = setup((2, 1, 1), cfg);
        for seq in 0..100 {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let nic: &Nic = sim.get(nics[0]);
        let tx: u64 = (0..TORUS_PORTS).map(|p| nic.port_tx_packets(p)).sum();
        assert_eq!(tx, 100);
        let bytes: u64 = (0..TORUS_PORTS).map(|p| nic.port_tx_bytes(p)).sum();
        assert_eq!(bytes, 52_000);
        // the egress port was busy for 100 serializations
        let busy: Time = nic.ports.iter().map(|p| p.busy_time).fold(Time::ZERO, |a, b| a + b);
        let local = cfg.ser_time(520) * 100; // local link on nic1, not nic0
        assert_eq!(busy, local);
    }

    #[test]
    fn credit_stalls_under_fanin() {
        // Many sources all target node 0 with tiny credits: stalls observed,
        // but every packet still arrives (no loss, no deadlock).
        let cfg = NicConfig {
            credits_per_vc: 1,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((4, 4, 1), cfg);
        let mut seq = 0;
        for s in spec.nodes() {
            if s.0 == 0 {
                continue;
            }
            for _ in 0..20 {
                seq += 1;
                let p = Packet::raw(s, NodeAddr(0), 496, Time::ZERO, seq);
                sim.schedule(Time::ZERO, nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[0]);
        assert_eq!(sink.received.len(), 15 * 20, "packets lost under backpressure");
        let total_stalls: u64 = nics
            .iter()
            .map(|&n| sim.get::<Nic>(n).stats.credit_stalls)
            .sum();
        assert!(total_stalls > 0, "expected credit stalls with 1-credit links");
    }

    #[test]
    fn wraparound_ring_saturation_no_deadlock() {
        // Every node sends to its antipode around an 8-ring with minimal
        // credits — the classic torus deadlock scenario; the dateline VC
        // rule must keep it live.
        let cfg = NicConfig {
            credits_per_vc: 1,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((8, 1, 1), cfg);
        let mut seq = 0;
        for s in spec.nodes() {
            let dst = NodeAddr((s.0 + 4) % 8);
            for _ in 0..50 {
                seq += 1;
                let p = Packet::raw(s, dst, 496, Time::ZERO, seq);
                sim.schedule(Time::ZERO, nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let total: usize = sinks
            .iter()
            .map(|&s| sim.get::<Sink>(s).received.len())
            .sum();
        assert_eq!(total, 8 * 50, "deadlock or loss in wrapped ring");
    }

    #[test]
    fn saturated_3d_torus_random_traffic_no_loss() {
        let cfg = NicConfig {
            credits_per_vc: 2,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((3, 3, 3), cfg);
        let mut rng = crate::util::rng::Rng::new(99);
        let n = spec.n_nodes();
        let mut sent = 0u64;
        for _ in 0..2000 {
            let s = rng.index(n);
            let d = rng.index(n);
            sent += 1;
            let p = Packet::raw(NodeAddr(s as u16), NodeAddr(d as u16), 256, Time::ZERO, sent);
            sim.schedule(Time::from_ns(rng.below(1000)), nics[s], Msg::Inject(p));
        }
        sim.run_to_completion();
        let total: usize = sinks
            .iter()
            .map(|&s| sim.get::<Sink>(s).received.len())
            .sum();
        assert_eq!(total as u64, sent);
    }

    fn install_fault(sim: &mut Sim<Msg>, nics: &[ActorId], model: &Arc<FaultModel>) {
        for &id in nics {
            sim.get_mut::<Nic>(id).set_fault_model(Arc::clone(model));
        }
    }

    #[test]
    fn zero_fault_model_is_transparent() {
        // An installed model with nothing configured must not change
        // delivery or hop counts versus no model at all.
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((3, 3, 2), cfg);
        let model = Arc::new(FaultModel::build(&FaultConfig::default(), spec, 7));
        install_fault(&mut sim, &nics, &model);
        let mut seq = 0u64;
        for s in spec.nodes() {
            for d in spec.nodes() {
                seq += 1;
                let p = Packet::raw(s, d, 128, Time::ZERO, seq);
                sim.schedule(Time::from_ns(seq), nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        for d in spec.nodes() {
            let sink: &Sink = sim.get(sinks[d.0 as usize]);
            assert_eq!(sink.received.len(), spec.n_nodes());
            for (_, p) in &sink.received {
                assert_eq!(p.hops as u32, spec.hop_distance(p.src, p.dst) + 1);
            }
        }
        let detours: u64 = nics.iter().map(|&n| sim.get::<Nic>(n).stats.detour_hops).sum();
        assert_eq!(detours, 0);
    }

    #[test]
    fn detour_around_failed_cable_still_delivers_all_pairs() {
        // One dead cable in a 4x4 torus (degree 4) cannot disconnect it:
        // every packet must still arrive, some via detour hops.
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((4, 4, 1), cfg);
        let fcfg = FaultConfig {
            fail: 1.0 / 32.0, // 32 cables in 4x4x1 → exactly one fails
            ..FaultConfig::default()
        };
        let model = Arc::new(FaultModel::build(&fcfg, spec, 42));
        assert_eq!(model.failed_cables(), 1);
        install_fault(&mut sim, &nics, &model);
        let mut seq = 0u64;
        for s in spec.nodes() {
            for d in spec.nodes() {
                seq += 1;
                let p = Packet::raw(s, d, 128, Time::ZERO, seq);
                sim.schedule(Time::from_ns(seq), nics[s.0 as usize], Msg::Inject(p));
            }
        }
        sim.run_to_completion();
        let total: usize = sinks.iter().map(|&s| sim.get::<Sink>(s).received.len()).sum();
        assert_eq!(total, spec.n_nodes() * spec.n_nodes(), "lost packets under detour");
        let (mut hops, mut min_hops) = (0u128, 0u128);
        let (mut detours, mut undeliverable) = (0u64, 0u64);
        for &n in &nics {
            let st = &sim.get::<Nic>(n).stats;
            hops += st.hops.sum();
            min_hops += st.min_hops.sum();
            detours += st.detour_hops;
            undeliverable += st.undeliverable_packets;
        }
        assert_eq!(undeliverable, 0);
        assert!(detours > 0, "some dimension-order route must cross the dead cable");
        assert!(hops > min_hops, "detours must inflate hop counts");
    }

    #[test]
    fn loss_drops_packets_but_credits_flow() {
        // Heavy receiver-side loss with 1-credit links: lost + received
        // must equal sent, and the run must terminate (credits returned
        // for dropped packets — no leak, no deadlock).
        let cfg = NicConfig {
            credits_per_vc: 1,
            ..NicConfig::default()
        };
        let (mut sim, spec, nics, sinks) = setup((2, 1, 1), cfg);
        let fcfg = FaultConfig {
            loss: 0.5,
            ..FaultConfig::default()
        };
        let model = Arc::new(FaultModel::build(&fcfg, spec, 3));
        install_fault(&mut sim, &nics, &model);
        let sent = 200u64;
        for seq in 0..sent {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let received = sim.get::<Sink>(sinks[1]).received.len() as u64;
        let lost: u64 = nics.iter().map(|&n| sim.get::<Nic>(n).stats.lost_packets).sum();
        assert_eq!(received + lost, sent);
        assert!(lost > 0, "0.5 loss over 200 packets losing nothing is astronomically unlikely");
        assert!(received > 0, "0.5 loss over 200 packets losing everything is astronomically unlikely");
    }

    #[test]
    fn undeliverable_when_destination_is_cut_off() {
        // 2x1x1 has exactly two cables (the two directed rings between the
        // pair); fail=1.0 kills both, isolating each node. Cross-node
        // packets must be counted undeliverable — not panic, not hang —
        // while self-delivery over the local link still works.
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((2, 1, 1), cfg);
        let fcfg = FaultConfig {
            fail: 1.0,
            ..FaultConfig::default()
        };
        let model = Arc::new(FaultModel::build(&fcfg, spec, 5));
        install_fault(&mut sim, &nics, &model);
        sim.schedule(
            Time::ZERO,
            nics[0],
            Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(1), 64, Time::ZERO, 1)),
        );
        sim.schedule(
            Time::ZERO,
            nics[0],
            Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(0), 64, Time::ZERO, 2)),
        );
        sim.run_to_completion();
        assert_eq!(sim.get::<Sink>(sinks[1]).received.len(), 0);
        assert_eq!(sim.get::<Sink>(sinks[0]).received.len(), 1);
        let st = &sim.get::<Nic>(nics[0]).stats;
        assert_eq!(st.undeliverable_packets, 1);
        assert_eq!(st.undeliverable_events, 1);
    }

    #[test]
    fn jitter_and_degradation_only_add_latency() {
        // With jitter + a degraded cable the packet can only be later than
        // the healthy schedule — never earlier (PDES lookahead soundness).
        let cfg = NicConfig::default();
        let (mut sim, spec, nics, sinks) = setup((2, 1, 1), cfg);
        let fcfg = FaultConfig {
            degrade: 1.0,
            degrade_factor: 2.0,
            jitter_ns: 20.0,
            ..FaultConfig::default()
        };
        let model = Arc::new(FaultModel::build(&fcfg, spec, 11));
        install_fault(&mut sim, &nics, &model);
        let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[1]);
        assert_eq!(sink.received.len(), 1);
        let healthy = (cfg.ser_time(520) + cfg.cable_latency + cfg.hop_latency) * 2;
        assert!(sink.received[0].0 > healthy, "faults must only slow packets down");
    }

    fn link_cfg(retx: LinkReliabilityConfig) -> NicConfig {
        NicConfig {
            reliability: Reliability::Link,
            retx,
            ..NicConfig::default()
        }
    }

    #[test]
    fn reliability_zero_loss_is_latency_transparent() {
        // With no CRC failures the protocol must not perturb the data
        // path: same arrival instant and hop count as reliability=off,
        // and no recovery machinery fires.
        let cfg = link_cfg(LinkReliabilityConfig::default());
        let (mut sim, _, nics, sinks) = setup((2, 1, 1), cfg);
        let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
        sim.run_to_completion();
        let sink: &Sink = sim.get(sinks[1]);
        assert_eq!(sink.received.len(), 1);
        let (at, p) = &sink.received[0];
        let ser = cfg.ser_time(520);
        let expect = (ser + cfg.cable_latency + cfg.hop_latency) * 2;
        assert_eq!(*at, expect, "reliability=link must not delay clean packets");
        assert_eq!(p.hops, 2);
        assert_eq!(p.link_seq, 0, "stamp must be cleared before local delivery");
        for &n in &nics {
            let st = &sim.get::<Nic>(n).stats;
            assert_eq!(st.retransmissions, 0);
            assert_eq!(st.nacks, 0);
            assert_eq!(st.timeouts, 0);
            assert_eq!(st.recovered_packets, 0);
            assert_eq!(st.residual_loss_packets, 0);
        }
    }

    #[test]
    fn reliability_recovers_every_packet_under_loss() {
        // CRC failures (the loss draw) trigger NACK + go-back-N replay:
        // every packet is delivered exactly once, in order, and the
        // recovery shows up in the stats. Jitter stays off so the links
        // are FIFO and accounting is exact.
        let cfg = link_cfg(LinkReliabilityConfig::default());
        let (mut sim, spec, nics, sinks) = setup((2, 1, 1), cfg);
        let fcfg = FaultConfig {
            loss: 0.15,
            ..FaultConfig::default()
        };
        let model = Arc::new(FaultModel::build(&fcfg, spec, 3));
        install_fault(&mut sim, &nics, &model);
        let sent = 400u64;
        for seq in 0..sent {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::from_ns(seq * 50), nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let received = &sim.get::<Sink>(sinks[1]).received;
        assert_eq!(received.len() as u64, sent, "link layer must recover every loss");
        for w in received.windows(2) {
            assert!(
                w[0].1.seq < w[1].1.seq,
                "go-back-N on a single link must deliver in order"
            );
        }
        let mut crc = 0u64;
        let mut retx = 0u64;
        let mut recovered = 0u64;
        for &n in &nics {
            let st = &sim.get::<Nic>(n).stats;
            crc += st.lost_packets;
            retx += st.retransmissions;
            recovered += st.recovered_packets;
            assert_eq!(st.residual_loss_packets, 0, "retry budget must not exhaust");
            assert_eq!(st.undeliverable_packets, 0);
        }
        assert!(crc > 0, "0.15 loss over 400 packets must fail some CRCs");
        assert!(retx >= crc, "every CRC failure needs at least one retransmission");
        assert!(recovered > 0);
    }

    #[test]
    fn reliability_gives_up_on_silent_peer_and_terminates() {
        // A peer that never ACKs (nor returns credits): the timeout
        // backstop must fire with backoff, the retry budget must bound the
        // timer chain, and the abandoned packets must surface as
        // undeliverable residual loss — the run terminates.
        let mut sim = Sim::new();
        let spec = TorusSpec::new(2, 1, 1);
        let cfg = link_cfg(LinkReliabilityConfig {
            timeout: Time::from_ns(500),
            max_retries: 3,
            ..LinkReliabilityConfig::default()
        });
        let nic = sim.add(Nic::new(NodeAddr(0), spec, cfg));
        struct Blackhole;
        impl Actor<Msg> for Blackhole {
            fn handle(&mut self, _msg: Msg, _ctx: &mut Ctx<'_, Msg>) {}
        }
        let hole = sim.add(Blackhole);
        for d in crate::extoll::torus::DIRS {
            sim.get_mut::<Nic>(nic).set_neighbor(d, hole);
        }
        sim.get_mut::<Nic>(nic).attach_local(hole);
        let sent = 5u64;
        for seq in 0..sent {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 64, Time::ZERO, seq);
            sim.schedule(Time::ZERO, nic, Msg::Inject(p));
        }
        sim.run_to_completion();
        let st = &sim.get::<Nic>(nic).stats;
        assert_eq!(st.undeliverable_packets, sent);
        assert_eq!(st.residual_loss_packets, sent);
        assert_eq!(st.residual_loss_events, 0, "raw packets carry no events");
        assert!(st.timeouts >= 1, "only the timer can detect a silent peer");
        assert!(st.retransmissions > 0);
        assert_eq!(st.recovered_packets, 0);
    }

    #[test]
    fn reliability_zero_retries_gives_up_but_accounts_exactly() {
        // max_retries=0 abandons the whole in-flight window on the first
        // replay round; SeqSkip must advance the receiver past every
        // abandoned prefix so later packets still get through, and
        // delivered + residual must equal sent exactly (jitter-free).
        let cfg = link_cfg(LinkReliabilityConfig {
            max_retries: 0,
            ..LinkReliabilityConfig::default()
        });
        let (mut sim, spec, nics, sinks) = setup((2, 1, 1), cfg);
        let fcfg = FaultConfig {
            loss: 0.25,
            ..FaultConfig::default()
        };
        let model = Arc::new(FaultModel::build(&fcfg, spec, 9));
        install_fault(&mut sim, &nics, &model);
        let sent = 200u64;
        for seq in 0..sent {
            let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq);
            sim.schedule(Time::from_ns(seq * 60), nics[0], Msg::Inject(p));
        }
        sim.run_to_completion();
        let received = &sim.get::<Sink>(sinks[1]).received;
        let mut seqs: Vec<u64> = received.iter().map(|(_, p)| p.seq).collect();
        seqs.dedup();
        assert_eq!(seqs.len(), received.len(), "no duplicate deliveries");
        let residual: u64 = nics
            .iter()
            .map(|&n| sim.get::<Nic>(n).stats.residual_loss_packets)
            .sum();
        assert_eq!(received.len() as u64 + residual, sent);
        assert!(residual > 0, "0.25 loss with a zero retry budget must abandon some");
        assert!(
            (received.len() as u64) > 0,
            "SeqSkip must keep the link making progress after give-ups"
        );
    }

    #[test]
    fn link_rate_matches_tourmalet() {
        let cfg = NicConfig::default();
        // 12 lanes x 8.4 Gbit/s x 64/66 encoding ≈ 97.75 Gbit/s
        assert!((cfg.link_gbps() - 97.745).abs() < 0.01, "{}", cfg.link_gbps());
        let t = cfg.ser_time(520);
        assert!((t.ns_f64() - 42.56).abs() < 0.2, "{}", t.ns_f64());
    }
}
