//! Flow-level (analytic) bandwidth model of the torus fabric.
//!
//! Complements the packet-level simulator: given a static traffic matrix,
//! accumulate the offered load on every directed link under dimension-order
//! routing and report utilizations and the saturation bottleneck. This is
//! the model behind the paper's Fig. 1 claim that the 8-concentrators-per-
//! wafer topology is "optimal … regarding bandwidth utilisation": it
//! exposes exactly which link saturates first as the wafer fan-in or the
//! torus shape changes, without running a packet simulation.

use std::collections::BTreeMap;

use super::routing::links_on_route;
use super::torus::{Dir, NodeAddr, TorusSpec};

/// One static flow: `gbps` offered from `src` to `dst`.
#[derive(Clone, Copy, Debug)]
pub struct Flow {
    pub src: NodeAddr,
    pub dst: NodeAddr,
    pub gbps: f64,
}

/// Load accumulated on one directed link.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkLoad {
    pub gbps: f64,
    pub n_flows: u32,
}

/// Result of a flow-level analysis.
#[derive(Clone, Debug)]
pub struct FlowAnalysis {
    /// Load per directed torus link (node, egress direction).
    pub links: BTreeMap<(u16, u8), LinkLoad>,
    /// Load injected/delivered through each node's local link.
    pub local_links: BTreeMap<u16, LinkLoad>,
    /// Link capacity used for utilization (Gbit/s).
    pub link_capacity_gbps: f64,
    pub total_offered_gbps: f64,
}

impl FlowAnalysis {
    /// Run the analysis for `flows` on `torus` with `link_capacity_gbps`.
    pub fn run(torus: &TorusSpec, flows: &[Flow], link_capacity_gbps: f64) -> FlowAnalysis {
        let mut links: BTreeMap<(u16, u8), LinkLoad> = BTreeMap::new();
        let mut local_links: BTreeMap<u16, LinkLoad> = BTreeMap::new();
        let mut total = 0.0;
        for f in flows {
            total += f.gbps;
            for (node, dir) in links_on_route(torus, f.src, f.dst) {
                let e = links.entry((node.0, dir.port())).or_default();
                e.gbps += f.gbps;
                e.n_flows += 1;
            }
            // delivery over the destination's local link
            let e = local_links.entry(f.dst.0).or_default();
            e.gbps += f.gbps;
            e.n_flows += 1;
        }
        FlowAnalysis {
            links,
            local_links,
            link_capacity_gbps,
            total_offered_gbps: total,
        }
    }

    /// Peak torus-link utilization (1.0 = saturated).
    pub fn max_utilization(&self) -> f64 {
        self.links
            .values()
            .map(|l| l.gbps / self.link_capacity_gbps)
            .fold(0.0, f64::max)
    }

    /// Peak local-link utilization given the local link capacity.
    pub fn max_local_utilization(&self, local_capacity_gbps: f64) -> f64 {
        self.local_links
            .values()
            .map(|l| l.gbps / local_capacity_gbps)
            .fold(0.0, f64::max)
    }

    /// Mean utilization over links that carry traffic.
    pub fn mean_active_utilization(&self) -> f64 {
        let active: Vec<f64> = self
            .links
            .values()
            .filter(|l| l.gbps > 0.0)
            .map(|l| l.gbps / self.link_capacity_gbps)
            .collect();
        if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<f64>() / active.len() as f64
        }
    }

    /// The most loaded torus link.
    pub fn bottleneck(&self) -> Option<((NodeAddr, Dir), LinkLoad)> {
        self.links
            .iter()
            .max_by(|a, b| a.1.gbps.partial_cmp(&b.1.gbps).unwrap())
            .map(|(&(n, p), &l)| ((NodeAddr(n), Dir::from_port(p)), l))
    }

    /// Sustainable fraction of the offered traffic: if the hottest link is
    /// oversubscribed by `u > 1`, throughput scales down by `1/u`
    /// (uniform-rate fluid approximation).
    pub fn sustainable_fraction(&self) -> f64 {
        let u = self.max_utilization();
        if u <= 1.0 {
            1.0
        } else {
            1.0 / u
        }
    }

    /// Number of torus links carrying any traffic.
    pub fn active_links(&self) -> usize {
        self.links.values().filter(|l| l.gbps > 0.0).count()
    }
}

/// Uniform all-to-all traffic matrix helper: every ordered pair of distinct
/// nodes exchanges `gbps_per_flow`.
pub fn uniform_all_to_all(torus: &TorusSpec, gbps_per_flow: f64) -> Vec<Flow> {
    let mut flows = Vec::new();
    for s in torus.nodes() {
        for d in torus.nodes() {
            if s != d {
                flows.push(Flow {
                    src: s,
                    dst: d,
                    gbps: gbps_per_flow,
                });
            }
        }
    }
    flows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_loads_route_links() {
        let t = TorusSpec::new(4, 1, 1);
        let flows = [Flow {
            src: NodeAddr(0),
            dst: NodeAddr(2),
            gbps: 10.0,
        }];
        let a = FlowAnalysis::run(&t, &flows, 100.0);
        assert_eq!(a.active_links(), 2); // 0->1, 1->2
        assert!((a.max_utilization() - 0.1).abs() < 1e-12);
        assert_eq!(a.local_links[&2].n_flows, 1);
    }

    #[test]
    fn bottleneck_detection() {
        let t = TorusSpec::new(4, 1, 1);
        // two flows share link 0->1
        let flows = [
            Flow {
                src: NodeAddr(0),
                dst: NodeAddr(1),
                gbps: 60.0,
            },
            Flow {
                src: NodeAddr(3),
                dst: NodeAddr(1),
                gbps: 50.0,
            },
        ];
        let a = FlowAnalysis::run(&t, &flows, 100.0);
        let ((node, dir), load) = a.bottleneck().unwrap();
        assert_eq!(node, NodeAddr(0));
        assert_eq!(dir, Dir::XPlus);
        assert!((load.gbps - 110.0).abs() < 1e-9);
        assert!((a.sustainable_fraction() - 100.0 / 110.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_traffic_is_balanced_on_symmetric_torus() {
        let t = TorusSpec::new(4, 4, 1);
        let flows = uniform_all_to_all(&t, 1.0);
        let a = FlowAnalysis::run(&t, &flows, 1000.0);
        // all active links should carry similar load on a symmetric torus
        let loads: Vec<f64> = a.links.values().map(|l| l.gbps).collect();
        let max = loads.iter().cloned().fold(0.0, f64::max);
        let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        // dimension-order routing on even tori has some imbalance from the
        // tie-breaking wrap preference, but within a small factor
        assert!(max / min <= 3.0, "max={max} min={min}");
        assert_eq!(a.total_offered_gbps, (16.0 * 15.0));
    }

    #[test]
    fn sustainable_fraction_at_low_load_is_one() {
        let t = TorusSpec::new(3, 3, 3);
        let flows = uniform_all_to_all(&t, 0.001);
        let a = FlowAnalysis::run(&t, &flows, 100.0);
        assert_eq!(a.sustainable_fraction(), 1.0);
    }
}
