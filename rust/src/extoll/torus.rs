//! 3D-torus topology (paper §1).
//!
//! Extoll networks connect Tourmalet nodes in a 3D torus; message routing
//! uses a **16-bit destination address** in the packet header. This module
//! maps node addresses ⇄ (x, y, z) coordinates, enumerates the six torus
//! ports of each node, and answers neighbor queries with wrap-around.

use std::fmt;

/// A 16-bit Extoll node address (paper §1).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeAddr(pub u16);

impl fmt::Display for NodeAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// One of the six torus directions; also the port index on a Tourmalet.
///
/// Tourmalet exposes 7 links: six form the torus, the seventh attaches the
/// local unit (here: the wafer's concentrator, see [`crate::wafer`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dir {
    XPlus = 0,
    XMinus = 1,
    YPlus = 2,
    YMinus = 3,
    ZPlus = 4,
    ZMinus = 5,
}

/// All six torus directions.
pub const DIRS: [Dir; 6] = [
    Dir::XPlus,
    Dir::XMinus,
    Dir::YPlus,
    Dir::YMinus,
    Dir::ZPlus,
    Dir::ZMinus,
];

/// Number of torus ports on a Tourmalet — the valid torus port indices
/// are `0..TORUS_PORTS`, in [`DIRS`] order. Derived from `DIRS` so
/// port-range loops (e.g. link-utilization stats) can never silently
/// include the local port.
pub const TORUS_PORTS: u8 = DIRS.len() as u8;

/// Port index of the local (non-torus) link on a Tourmalet (the 7th link).
pub const LOCAL_PORT: u8 = TORUS_PORTS;

/// Number of links on a Tourmalet NIC (paper §1: "offers 7 links").
pub const TOURMALET_LINKS: usize = 7;

impl Dir {
    pub fn port(self) -> u8 {
        self as u8
    }

    pub fn from_port(p: u8) -> Dir {
        DIRS[p as usize]
    }

    pub fn opposite(self) -> Dir {
        match self {
            Dir::XPlus => Dir::XMinus,
            Dir::XMinus => Dir::XPlus,
            Dir::YPlus => Dir::YMinus,
            Dir::YMinus => Dir::YPlus,
            Dir::ZPlus => Dir::ZMinus,
            Dir::ZMinus => Dir::ZPlus,
        }
    }

    /// Dimension index (0=x, 1=y, 2=z).
    pub fn axis(self) -> usize {
        (self as usize) / 2
    }

    /// +1 or -1 along the axis.
    pub fn sign(self) -> i64 {
        if (self as usize) % 2 == 0 {
            1
        } else {
            -1
        }
    }
}

/// Torus dimensions. A `1×1×1` torus is a single node; a dimension of size
/// 1 or 2 has degenerate wrap-around (handled explicitly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TorusSpec {
    pub nx: u16,
    pub ny: u16,
    pub nz: u16,
}

impl TorusSpec {
    pub fn new(nx: u16, ny: u16, nz: u16) -> Self {
        assert!(nx >= 1 && ny >= 1 && nz >= 1, "degenerate torus");
        let n = nx as u32 * ny as u32 * nz as u32;
        assert!(n <= 1 << 16, "torus exceeds 16-bit address space");
        TorusSpec { nx, ny, nz }
    }

    pub fn n_nodes(&self) -> usize {
        self.nx as usize * self.ny as usize * self.nz as usize
    }

    pub fn dims(&self, axis: usize) -> u16 {
        match axis {
            0 => self.nx,
            1 => self.ny,
            2 => self.nz,
            _ => panic!("axis {axis}"),
        }
    }

    /// Address of coordinates (row-major: x fastest).
    pub fn addr_of(&self, x: u16, y: u16, z: u16) -> NodeAddr {
        debug_assert!(x < self.nx && y < self.ny && z < self.nz);
        NodeAddr(x + self.nx * (y + self.ny * z))
    }

    /// Coordinates of an address.
    pub fn coords_of(&self, a: NodeAddr) -> (u16, u16, u16) {
        let v = a.0;
        let x = v % self.nx;
        let y = (v / self.nx) % self.ny;
        let z = v / (self.nx * self.ny);
        debug_assert!(z < self.nz, "address {v} outside torus");
        (x, y, z)
    }

    /// Neighbor of `a` in direction `d`, with wrap-around.
    pub fn neighbor(&self, a: NodeAddr, d: Dir) -> NodeAddr {
        let (mut x, mut y, mut z) = self.coords_of(a);
        let step = |v: u16, n: u16, sign: i64| -> u16 {
            if sign > 0 {
                if v + 1 == n {
                    0
                } else {
                    v + 1
                }
            } else if v == 0 {
                n - 1
            } else {
                v - 1
            }
        };
        match d.axis() {
            0 => x = step(x, self.nx, d.sign()),
            1 => y = step(y, self.ny, d.sign()),
            2 => z = step(z, self.nz, d.sign()),
            _ => unreachable!(),
        }
        self.addr_of(x, y, z)
    }

    /// Signed shortest displacement from `from` to `to` along `axis`
    /// (torus wrap-aware). Positive means travel in the + direction.
    pub fn shortest_delta(&self, from: u16, to: u16, axis: usize) -> i64 {
        let n = self.dims(axis) as i64;
        let mut d = to as i64 - from as i64;
        if d > n / 2 {
            d -= n;
        } else if d < -(n - 1) / 2 - ((n + 1) % 2) {
            // symmetric wrap for even sizes: prefer + direction on ties
            d += n;
        }
        // normalize ties (|d| == n/2 for even n): prefer positive
        if n % 2 == 0 && d == -(n / 2) {
            d = n / 2;
        }
        d
    }

    /// Minimal hop count between two nodes (sum of per-axis distances).
    pub fn hop_distance(&self, a: NodeAddr, b: NodeAddr) -> u32 {
        let ca = self.coords_of(a);
        let cb = self.coords_of(b);
        let pairs = [(ca.0, cb.0, 0usize), (ca.1, cb.1, 1), (ca.2, cb.2, 2)];
        pairs
            .iter()
            .map(|&(f, t, ax)| self.shortest_delta(f, t, ax).unsigned_abs() as u32)
            .sum()
    }

    /// Iterate all node addresses.
    pub fn nodes(&self) -> impl Iterator<Item = NodeAddr> {
        (0..self.n_nodes() as u16).map(NodeAddr)
    }

    /// Enumerate every physical cable exactly once, in deterministic
    /// order, as its canonical directed form `(node, positive_dir)`. The
    /// cable `(a, d)` carries the directed links `(a, d)` and
    /// `(neighbor(a, d), d.opposite())`. Size-1 dimensions (self-loops,
    /// never routed over) are skipped. The fault model samples failures
    /// over this set so both directions of a cable always fail together.
    pub fn cables(&self) -> Vec<(NodeAddr, Dir)> {
        let mut cables = Vec::new();
        for a in self.nodes() {
            for d in [Dir::XPlus, Dir::YPlus, Dir::ZPlus] {
                if self.neighbor(a, d) != a {
                    cables.push((a, d));
                }
            }
        }
        cables
    }
}

/// Partition of the torus nodes into PDES domains (see `sim/pdes.rs` and
/// `docs/ARCHITECTURE.md`).
///
/// Nodes are split into contiguous **address blocks** of near-equal size
/// (`⌊n/D⌋` or `⌈n/D⌉` nodes each). Addresses are row-major (x fastest),
/// so contiguous blocks are slabs along the high-order axes — and because
/// the system builder places wafers on consecutive node addresses, a
/// domain boundary tends to coincide with a wafer boundary, keeping the
/// chatty concentrator↔FPGA traffic inside one domain.
#[derive(Clone, Copy, Debug)]
pub struct DomainMap {
    spec: TorusSpec,
    n_domains: usize,
}

impl DomainMap {
    /// Partition `spec` into (at most) `requested` domains; the count is
    /// clamped to `[1, n_nodes]` so every domain owns at least one node.
    pub fn new(spec: TorusSpec, requested: usize) -> DomainMap {
        DomainMap {
            spec,
            n_domains: requested.clamp(1, spec.n_nodes()),
        }
    }

    pub fn spec(&self) -> &TorusSpec {
        &self.spec
    }

    /// Effective number of domains (after clamping).
    pub fn n_domains(&self) -> usize {
        self.n_domains
    }

    /// The domain owning node `a`. Total and exclusive: every node maps
    /// to exactly one domain in `0..n_domains`.
    pub fn domain_of(&self, a: NodeAddr) -> u32 {
        debug_assert!((a.0 as usize) < self.spec.n_nodes());
        (a.0 as usize * self.n_domains / self.spec.n_nodes()) as u32
    }

    /// Number of nodes owned by domain `d`.
    pub fn nodes_in(&self, d: u32) -> usize {
        self.spec.nodes().filter(|&a| self.domain_of(a) == d).count()
    }

    /// Enumerate every **directed** torus link whose endpoints live in
    /// different domains, as `(node, dir, neighbor)`. The set is
    /// symmetric: `(a, d, b)` is listed iff `(b, d.opposite(), a)` is —
    /// these are exactly the channels whose minimum message latency
    /// determines the conservative lookahead.
    pub fn inter_domain_edges(&self) -> Vec<(NodeAddr, Dir, NodeAddr)> {
        let mut edges = Vec::new();
        for a in self.spec.nodes() {
            for d in DIRS {
                let b = self.spec.neighbor(a, d);
                if self.domain_of(a) != self.domain_of(b) {
                    edges.push((a, d, b));
                }
            }
        }
        edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_coord_roundtrip() {
        let t = TorusSpec::new(4, 3, 2);
        for z in 0..2 {
            for y in 0..3 {
                for x in 0..4 {
                    let a = t.addr_of(x, y, z);
                    assert_eq!(t.coords_of(a), (x, y, z));
                }
            }
        }
        assert_eq!(t.n_nodes(), 24);
    }

    #[test]
    fn neighbors_wrap() {
        let t = TorusSpec::new(4, 4, 4);
        let a = t.addr_of(3, 0, 2);
        assert_eq!(t.coords_of(t.neighbor(a, Dir::XPlus)), (0, 0, 2));
        assert_eq!(t.coords_of(t.neighbor(a, Dir::XMinus)), (2, 0, 2));
        assert_eq!(t.coords_of(t.neighbor(a, Dir::YMinus)), (3, 3, 2));
        assert_eq!(t.coords_of(t.neighbor(a, Dir::ZPlus)), (3, 0, 3));
    }

    #[test]
    fn neighbor_opposite_is_inverse() {
        let t = TorusSpec::new(3, 5, 2);
        for a in t.nodes() {
            for d in DIRS {
                assert_eq!(t.neighbor(t.neighbor(a, d), d.opposite()), a);
            }
        }
    }

    #[test]
    fn shortest_delta_wraps() {
        let t = TorusSpec::new(8, 8, 8);
        assert_eq!(t.shortest_delta(0, 3, 0), 3);
        assert_eq!(t.shortest_delta(0, 7, 0), -1);
        assert_eq!(t.shortest_delta(6, 1, 0), 3);
        // even size tie: |d|=4 both ways; convention: positive
        assert_eq!(t.shortest_delta(0, 4, 0), 4);
        assert_eq!(t.shortest_delta(4, 0, 0), 4);
    }

    #[test]
    fn hop_distance_symmetric_and_triangle_sane() {
        let t = TorusSpec::new(4, 4, 2);
        for a in t.nodes() {
            for b in t.nodes() {
                assert_eq!(t.hop_distance(a, b), t.hop_distance(b, a));
                if a == b {
                    assert_eq!(t.hop_distance(a, b), 0);
                } else {
                    assert!(t.hop_distance(a, b) >= 1);
                }
            }
        }
        // max distance in 4x4x2: 2+2+1 = 5
        let m = t
            .nodes()
            .map(|b| t.hop_distance(NodeAddr(0), b))
            .max()
            .unwrap();
        assert_eq!(m, 5);
    }

    #[test]
    fn size_one_dims() {
        let t = TorusSpec::new(1, 1, 1);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.neighbor(NodeAddr(0), Dir::XPlus), NodeAddr(0));
        assert_eq!(t.hop_distance(NodeAddr(0), NodeAddr(0)), 0);
    }

    #[test]
    #[should_panic(expected = "16-bit")]
    fn too_big_rejected() {
        let _ = TorusSpec::new(256, 256, 2);
    }

    #[test]
    fn domain_map_partitions_evenly() {
        let t = TorusSpec::new(4, 2, 2);
        for d in [1usize, 2, 3, 4, 16] {
            let dm = DomainMap::new(t, d);
            assert_eq!(dm.n_domains(), d.min(16));
            let total: usize = (0..dm.n_domains() as u32).map(|i| dm.nodes_in(i)).sum();
            assert_eq!(total, 16, "every node in exactly one domain");
            let max = (0..dm.n_domains() as u32).map(|i| dm.nodes_in(i)).max().unwrap();
            let min = (0..dm.n_domains() as u32).map(|i| dm.nodes_in(i)).min().unwrap();
            assert!(max - min <= 1, "unbalanced split at D={d}: {min}..{max}");
        }
        // requested > nodes clamps
        assert_eq!(DomainMap::new(TorusSpec::new(2, 1, 1), 8).n_domains(), 2);
        assert_eq!(DomainMap::new(t, 0).n_domains(), 1);
    }

    #[test]
    fn domain_edges_symmetric_and_boundary_only() {
        let t = TorusSpec::new(4, 2, 2);
        let dm = DomainMap::new(t, 4);
        let edges = dm.inter_domain_edges();
        assert!(!edges.is_empty());
        for &(a, d, b) in &edges {
            assert_ne!(dm.domain_of(a), dm.domain_of(b));
            assert_eq!(t.neighbor(a, d), b);
            assert!(edges.contains(&(b, d.opposite(), a)), "missing reverse edge");
        }
        // one domain ⇒ no inter-domain edges
        assert!(DomainMap::new(t, 1).inter_domain_edges().is_empty());
    }

    #[test]
    fn cables_cover_every_directed_link_once() {
        for spec in [
            TorusSpec::new(4, 2, 2),
            TorusSpec::new(2, 2, 1),
            TorusSpec::new(3, 1, 1),
            TorusSpec::new(1, 1, 1),
        ] {
            let cables = spec.cables();
            let mut directed = std::collections::BTreeSet::new();
            for &(a, d) in &cables {
                assert_eq!(d.sign(), 1, "canonical form uses positive dirs");
                let b = spec.neighbor(a, d);
                assert_ne!(a, b, "self-loop cable listed");
                assert!(directed.insert((a, d.port())), "duplicate link");
                assert!(directed.insert((b, d.opposite().port())), "duplicate link");
            }
            // every non-self-loop directed link is covered
            for a in spec.nodes() {
                for d in DIRS {
                    if spec.neighbor(a, d) != a {
                        assert!(directed.contains(&(a, d.port())), "missing ({a}, {d:?})");
                    }
                }
            }
        }
    }

    #[test]
    fn torus_port_constants_consistent() {
        assert_eq!(TORUS_PORTS as usize, DIRS.len());
        assert_eq!(LOCAL_PORT, TORUS_PORTS, "local port follows the torus ports");
        assert_eq!(TOURMALET_LINKS, TORUS_PORTS as usize + 1);
        for d in DIRS {
            assert!(d.port() < TORUS_PORTS);
        }
    }

    #[test]
    fn dir_axis_sign_port() {
        assert_eq!(Dir::XPlus.axis(), 0);
        assert_eq!(Dir::ZMinus.axis(), 2);
        assert_eq!(Dir::YPlus.sign(), 1);
        assert_eq!(Dir::YMinus.sign(), -1);
        for (i, d) in DIRS.iter().enumerate() {
            assert_eq!(d.port() as usize, i);
            assert_eq!(Dir::from_port(d.port()), *d);
        }
    }
}
