//! Gigabit-Ethernet baseline (paper abstract: "currently connected to a
//! compute cluster via Gigabit-Ethernet network technology").
//!
//! The comparison fabric for every Extoll experiment: a store-and-forward
//! GbE path with standard framing overhead and a (configurable) switch +
//! kernel-stack latency. The same `Inject`/`Deliver` actor interface as
//! [`super::nic::Nic`] lets workloads run unchanged over either fabric.
//! An optional per-message handshake mode models the request/acknowledge
//! software protocol the ring-buffer design (paper §2.1) eliminates.

use std::collections::VecDeque;

use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};
use crate::util::stats::Histogram;

use super::packet::Packet;

/// Ethernet framing overhead per frame: preamble+SFD (8) + MAC (14) +
/// FCS (4) + min IFG (12) + IPv4 (20) + UDP (8) = 66 bytes.
pub const GBE_FRAME_OVERHEAD_BYTES: u32 = 66;
/// Maximum UDP payload per standard (non-jumbo) frame.
pub const GBE_MAX_PAYLOAD_BYTES: u32 = 1472;

/// Configuration of the GbE baseline path.
#[derive(Clone, Copy, Debug)]
pub struct GbeConfig {
    /// Line rate in Gbit/s (1.0 for the BrainScaleS cluster links).
    pub gbps: f64,
    /// One-way switch + NIC + kernel latency.
    pub path_latency: Time,
    /// If set, every message requires a software acknowledgment before the
    /// next may be sent (the handshake baseline of Fig. 2a).
    pub handshake: bool,
    /// Software turnaround time to generate an acknowledgment.
    pub ack_turnaround: Time,
}

impl Default for GbeConfig {
    fn default() -> Self {
        GbeConfig {
            gbps: 1.0,
            path_latency: Time::from_us(10),
            handshake: false,
            ack_turnaround: Time::from_us(5),
        }
    }
}

impl GbeConfig {
    /// Serialization time of `payload` bytes including framing overhead.
    pub fn ser_time(&self, payload: u32) -> Time {
        let frames = payload.div_ceil(GBE_MAX_PAYLOAD_BYTES).max(1);
        let wire = payload + frames * GBE_FRAME_OVERHEAD_BYTES;
        crate::sim::ps_for_bits(wire as u64 * 8, self.gbps)
    }
}

/// Statistics of the GbE path.
#[derive(Clone, Debug, Default)]
pub struct GbeStats {
    pub delivered: u64,
    pub delivered_bytes: u64,
    pub delivered_events: u64,
    /// inject→deliver latency (ps).
    pub transit_ps: Histogram,
    /// time messages spent waiting for handshake acks (ps).
    pub handshake_wait_ps: Histogram,
}

/// A point-to-point GbE path actor: `Inject` on one side, `Deliver` to the
/// attached sink. (The BrainScaleS GbE setup is one switch hop between an
/// FPGA and its host; multi-hop effects fold into `path_latency`.)
pub struct GbeLink {
    cfg: GbeConfig,
    /// Delivery target.
    sink: Option<ActorId>,
    queue: VecDeque<Packet>,
    busy: bool,
    /// Waiting for an ack (handshake mode).
    awaiting_ack: bool,
    pub stats: GbeStats,
}

impl GbeLink {
    pub fn new(cfg: GbeConfig) -> Self {
        GbeLink {
            cfg,
            sink: None,
            queue: VecDeque::new(),
            busy: false,
            awaiting_ack: false,
            stats: GbeStats::default(),
        }
    }

    pub fn attach_sink(&mut self, id: ActorId) {
        self.sink = Some(id);
    }

    fn try_tx(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy || self.awaiting_ack || self.queue.is_empty() {
            return;
        }
        let p = self.queue.pop_front().unwrap();
        let ser = self.cfg.ser_time(p.payload_bytes);
        self.busy = true;
        let arrival = ser + self.cfg.path_latency;
        let sink = self.sink.expect("gbe link has no sink attached");
        self.stats.delivered += 1;
        self.stats.delivered_bytes += p.payload_bytes as u64;
        self.stats.delivered_events += p.n_events() as u64;
        self.stats
            .transit_ps
            .record((ctx.now() + arrival).saturating_sub(p.injected).ps());
        ctx.send(sink, arrival, Msg::Deliver(p));
        ctx.send_self(ser, Msg::Timer(TIMER_TX_DONE));
        if self.cfg.handshake {
            // ack returns after delivery + turnaround + path back
            self.awaiting_ack = true;
            let ack_at = arrival + self.cfg.ack_turnaround + self.cfg.path_latency;
            ctx.send_self(ack_at, Msg::Timer(TIMER_ACK));
        }
    }
}

/// Timer tag: serializer free.
pub const TIMER_TX_DONE: u32 = 1;
/// Timer tag: handshake acknowledgment received.
pub const TIMER_ACK: u32 = 2;

impl Actor<Msg> for GbeLink {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Inject(mut p) => {
                p.injected = ctx.now();
                self.queue.push_back(p);
                self.try_tx(ctx);
            }
            Msg::Timer(TIMER_TX_DONE) => {
                self.busy = false;
                self.try_tx(ctx);
            }
            Msg::Timer(TIMER_ACK) => {
                self.awaiting_ack = false;
                self.try_tx(ctx);
            }
            other => panic!("gbe link: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        "gbe-link".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::NodeAddr;
    use crate::sim::Sim;

    struct Sink {
        received: Vec<(Time, Packet)>,
    }

    impl Actor<Msg> for Sink {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::Deliver(p) = msg {
                self.received.push((ctx.now(), p));
            }
        }
    }

    fn setup(cfg: GbeConfig) -> (Sim<Msg>, ActorId, ActorId) {
        let mut sim = Sim::new();
        let link = sim.add(GbeLink::new(cfg));
        let sink = sim.add(Sink { received: vec![] });
        sim.get_mut::<GbeLink>(link).attach_sink(sink);
        (sim, link, sink)
    }

    #[test]
    fn delivery_latency_includes_framing_and_path() {
        let cfg = GbeConfig::default();
        let (mut sim, link, sink) = setup(cfg);
        let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, 1);
        sim.schedule(Time::ZERO, link, Msg::Inject(p));
        sim.run_to_completion();
        let s: &Sink = sim.get(sink);
        assert_eq!(s.received.len(), 1);
        // (496+66)*8 bits at 1 Gbit/s = 4.496us; + 10us path
        let expect = Time::from_ns(4496) + Time::from_us(10);
        assert_eq!(s.received[0].0, expect);
    }

    #[test]
    fn throughput_serializes_back_to_back() {
        let cfg = GbeConfig::default();
        let (mut sim, link, sink) = setup(cfg);
        for seq in 0..10 {
            sim.schedule(
                Time::ZERO,
                link,
                Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq)),
            );
        }
        sim.run_to_completion();
        let s: &Sink = sim.get(sink);
        assert_eq!(s.received.len(), 10);
        let dt = s.received[9].0 - s.received[8].0;
        assert_eq!(dt, cfg.ser_time(496), "pipelined spacing = ser time");
    }

    #[test]
    fn handshake_gates_next_message() {
        let cfg = GbeConfig {
            handshake: true,
            ..GbeConfig::default()
        };
        let (mut sim, link, sink) = setup(cfg);
        for seq in 0..3 {
            sim.schedule(
                Time::ZERO,
                link,
                Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(1), 64, Time::ZERO, seq)),
            );
        }
        sim.run_to_completion();
        let s: &Sink = sim.get(sink);
        assert_eq!(s.received.len(), 3);
        let dt = s.received[1].0 - s.received[0].0;
        // spacing must cover ser + path (deliver) + turnaround + path (ack)
        let min = cfg.ser_time(64) + cfg.path_latency + cfg.ack_turnaround + cfg.path_latency;
        assert!(dt >= min, "dt={dt} < {min}");
    }

    #[test]
    fn handshake_vs_streaming_throughput_gap() {
        // The Fig. 2a motivation: per-message handshakes collapse
        // throughput. 100 messages of 496B each.
        let mk = |handshake| {
            let cfg = GbeConfig {
                handshake,
                ..GbeConfig::default()
            };
            let (mut sim, link, sink) = setup(cfg);
            for seq in 0..100 {
                sim.schedule(
                    Time::ZERO,
                    link,
                    Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, seq)),
                );
            }
            sim.run_to_completion();
            let s: &Sink = sim.get(sink);
            s.received.last().unwrap().0
        };
        let t_stream = mk(false);
        let t_handshake = mk(true);
        assert!(
            t_handshake.ps() > t_stream.ps() * 4,
            "handshake {t_handshake} should be ≫ streaming {t_stream}"
        );
    }

    #[test]
    fn jumbo_payload_counts_frames() {
        let cfg = GbeConfig::default();
        // 1473 bytes -> 2 frames -> 2x overhead
        let t1 = cfg.ser_time(1472);
        let t2 = cfg.ser_time(1473);
        let extra = t2 - t1;
        assert!(extra >= crate::sim::ps_for_bits((GBE_FRAME_OVERHEAD_BYTES as u64) * 8, 1.0));
    }
}
