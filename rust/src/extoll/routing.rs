//! Dimension-order routing on the 3D torus (paper §1), with fault-aware
//! adaptive fallback.
//!
//! "Routing of messages through the network is entirely done by the
//! Tourmalet network chips and is based on a given 16 bit destination
//! address in the message header." We implement deterministic
//! dimension-order (X → Y → Z) routing with wrap-aware shortest direction
//! per axis — the standard deadlock-free scheme for torus networks and the
//! default in Extoll deployments.
//!
//! ## Fault awareness
//!
//! Every routing query can be evaluated against a [`LinkStatus`] view of
//! the fabric (see [`crate::fault`]). On a fault-free view the decision is
//! exactly classic dimension-order routing. When links are down,
//! [`next_hop_with`] falls back to an **adaptive shortest-path detour**:
//! it computes hop distances to the destination over the live links only
//! and steps to any live neighbor strictly closer to the destination —
//! preferring the dimension-order direction whenever it still lies on a
//! shortest live path, so the detour perturbs as little as possible.
//! Because every hop strictly decreases a finite distance, adaptive routes
//! are loop-free and reach the destination whenever the live graph keeps
//! it connected; when it does not, the query reports
//! [`Hop::Unreachable`] instead of panicking, and the caller accounts the
//! packet as undeliverable. (Deadlock safety of detours is argued in
//! `docs/ARCHITECTURE.md`: detour hops ride the VC1 escape channel.)

use super::torus::{Dir, NodeAddr, TorusSpec, DIRS};

/// A view of which torus links are usable, threaded through the routing
/// queries. Implemented by [`FaultFree`] (the perfect fabric) and by
/// [`crate::fault::FaultView`] (a [`crate::fault::FaultModel`] at a
/// specific simulation time).
pub trait LinkStatus {
    /// Is the directed link leaving `from` towards `dir` usable?
    fn alive(&self, from: NodeAddr, dir: Dir) -> bool;

    /// Fast-path hint: `true` promises `alive` returns `true` for every
    /// link, letting the router skip the live-graph search entirely and
    /// make the classic dimension-order decision.
    fn fault_free(&self) -> bool {
        false
    }
}

/// The perfect fabric: every link is up. Routing under this view is
/// byte-identical to the pre-fault-model dimension-order router.
pub struct FaultFree;

impl LinkStatus for FaultFree {
    #[inline]
    fn alive(&self, _from: NodeAddr, _dir: Dir) -> bool {
        true
    }

    #[inline]
    fn fault_free(&self) -> bool {
        true
    }
}

/// One routing decision under a [`LinkStatus`] view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hop {
    /// `here == dst`: deliver to the local port.
    Deliver,
    /// Forward out of this direction's port.
    Via(Dir),
    /// The live graph does not connect `here` to `dst`.
    Unreachable,
}

/// Compute the egress direction at `here` for a packet addressed to `dst`.
/// Returns `None` when `here == dst` (deliver locally).
///
/// This is the pure dimension-order decision on the perfect fabric; the
/// fault-aware variant is [`next_hop_with`].
pub fn next_hop(torus: &TorusSpec, here: NodeAddr, dst: NodeAddr) -> Option<Dir> {
    if here == dst {
        return None;
    }
    let (hx, hy, hz) = torus.coords_of(here);
    let (dx, dy, dz) = torus.coords_of(dst);
    for (axis, (h, d)) in [(hx, dx), (hy, dy), (hz, dz)].into_iter().enumerate() {
        if h != d {
            let delta = torus.shortest_delta(h, d, axis);
            let dir = match (axis, delta > 0) {
                (0, true) => Dir::XPlus,
                (0, false) => Dir::XMinus,
                (1, true) => Dir::YPlus,
                (1, false) => Dir::YMinus,
                (2, true) => Dir::ZPlus,
                (2, false) => Dir::ZMinus,
                _ => unreachable!(),
            };
            return Some(dir);
        }
    }
    None
}

/// Hop distances to `dst` over the live links only: `dist[a]` is the
/// minimum number of usable links from node `a` to `dst`, or `u32::MAX`
/// when the live graph does not connect them. Reverse BFS from `dst`
/// (edge `(x, dir)` is traversable iff `status.alive(x, dir)`).
pub fn live_distances<S: LinkStatus + ?Sized>(
    torus: &TorusSpec,
    status: &S,
    dst: NodeAddr,
) -> Vec<u32> {
    let n = torus.n_nodes();
    let mut dist = vec![u32::MAX; n];
    dist[dst.0 as usize] = 0;
    let mut frontier = std::collections::VecDeque::with_capacity(n);
    frontier.push_back(dst);
    while let Some(y) = frontier.pop_front() {
        let dy = dist[y.0 as usize];
        for d in DIRS {
            // the forward edge (x, d) lands on y
            let x = torus.neighbor(y, d.opposite());
            if x == y {
                continue; // size-1 dimension self-loop; never routed over
            }
            if dist[x.0 as usize] == u32::MAX && status.alive(x, d) {
                dist[x.0 as usize] = dy + 1;
                frontier.push_back(x);
            }
        }
    }
    dist
}

/// The routing decision at `here` for `dst` under `status`.
///
/// On a fault-free view this is exactly [`next_hop`]. Otherwise: step to
/// a live neighbor strictly closer to `dst` in the live graph, preferring
/// the dimension-order direction when it qualifies (so zero-fault and
/// far-from-fault decisions are unchanged), breaking remaining ties by
/// the fixed [`DIRS`] port order — deterministic, no RNG involved.
pub fn next_hop_with<S: LinkStatus + ?Sized>(
    torus: &TorusSpec,
    status: &S,
    here: NodeAddr,
    dst: NodeAddr,
) -> Hop {
    if here == dst {
        return Hop::Deliver;
    }
    let preferred = next_hop(torus, here, dst)
        .expect("distinct nodes always have a dimension-order direction");
    if status.fault_free() {
        return Hop::Via(preferred);
    }
    let dist = live_distances(torus, status, dst);
    let dh = dist[here.0 as usize];
    if dh == u32::MAX {
        return Hop::Unreachable;
    }
    let closes_in = |dir: Dir| {
        let n = torus.neighbor(here, dir);
        n != here
            && status.alive(here, dir)
            && dist[n.0 as usize] != u32::MAX
            && dist[n.0 as usize] + 1 == dh
    };
    if closes_in(preferred) {
        return Hop::Via(preferred);
    }
    for dir in DIRS {
        if closes_in(dir) {
            return Hop::Via(dir);
        }
    }
    unreachable!("finite live distance {dh} at {here} without a closer live neighbor");
}

/// Walk the full path from `src` to `dst` under `status`, calling
/// `visit(node, dir)` for every link crossed, in order. Returns the hop
/// count, or `None` when the live graph does not connect the endpoints.
///
/// This is the single shared walker behind [`route`] /
/// [`links_on_route`] and their fault-aware variants, so the
/// `path.len() <= n_nodes` loop guard covers adaptive detours too. (The
/// guard is defense in depth: strictly-decreasing live distance already
/// forbids loops.)
pub fn walk_route_with<S: LinkStatus + ?Sized>(
    torus: &TorusSpec,
    status: &S,
    src: NodeAddr,
    dst: NodeAddr,
    mut visit: impl FnMut(NodeAddr, Dir),
) -> Option<usize> {
    let mut here = src;
    let mut hops = 0usize;
    loop {
        match next_hop_with(torus, status, here, dst) {
            Hop::Deliver => return Some(hops),
            Hop::Unreachable => return None,
            Hop::Via(d) => {
                visit(here, d);
                here = torus.neighbor(here, d);
                hops += 1;
                assert!(hops <= torus.n_nodes(), "routing loop from {src} to {dst}");
            }
        }
    }
}

/// Full path (sequence of directions) from `src` to `dst` on the perfect
/// fabric.
pub fn route(torus: &TorusSpec, src: NodeAddr, dst: NodeAddr) -> Vec<Dir> {
    route_with(torus, &FaultFree, src, dst).expect("fault-free torus is connected")
}

/// Full path from `src` to `dst` under `status`; `None` when unreachable.
pub fn route_with<S: LinkStatus + ?Sized>(
    torus: &TorusSpec,
    status: &S,
    src: NodeAddr,
    dst: NodeAddr,
) -> Option<Vec<Dir>> {
    let mut path = Vec::new();
    walk_route_with(torus, status, src, dst, |_, d| path.push(d)).map(|_| path)
}

/// Every (node, direction) link crossed on the path from `src` to `dst`
/// on the perfect fabric. Used by the flow-level analysis to accumulate
/// per-link loads.
pub fn links_on_route(torus: &TorusSpec, src: NodeAddr, dst: NodeAddr) -> Vec<(NodeAddr, Dir)> {
    links_on_route_with(torus, &FaultFree, src, dst).expect("fault-free torus is connected")
}

/// Every (node, direction) link crossed under `status`; `None` when
/// unreachable.
pub fn links_on_route_with<S: LinkStatus + ?Sized>(
    torus: &TorusSpec,
    status: &S,
    src: NodeAddr,
    dst: NodeAddr,
) -> Option<Vec<(NodeAddr, Dir)>> {
    let mut links = Vec::new();
    walk_route_with(torus, status, src, dst, |node, d| links.push((node, d))).map(|_| links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn routes_reach_destination_minimally() {
        let t = TorusSpec::new(4, 4, 4);
        for src in t.nodes() {
            for dst in t.nodes() {
                let p = route(&t, src, dst);
                assert_eq!(p.len() as u32, t.hop_distance(src, dst), "{src}->{dst}");
                // walk it
                let mut here = src;
                for d in &p {
                    here = t.neighbor(here, *d);
                }
                assert_eq!(here, dst);
            }
        }
    }

    #[test]
    fn dimension_order_is_respected() {
        let t = TorusSpec::new(4, 4, 4);
        for src in t.nodes() {
            for dst in t.nodes() {
                let p = route(&t, src, dst);
                // axis indices along the path must be non-decreasing
                let axes: Vec<usize> = p.iter().map(|d| d.axis()).collect();
                let mut sorted = axes.clone();
                sorted.sort_unstable();
                assert_eq!(axes, sorted, "{src}->{dst} path not dimension-ordered");
            }
        }
    }

    #[test]
    fn wrap_direction_is_shortest() {
        let t = TorusSpec::new(8, 1, 1);
        // 0 -> 7 should go X- (1 hop), not X+ (7 hops)
        let p = route(&t, NodeAddr(0), NodeAddr(7));
        assert_eq!(p, vec![Dir::XMinus]);
        let p = route(&t, NodeAddr(0), NodeAddr(3));
        assert_eq!(p.len(), 3);
        assert!(p.iter().all(|d| *d == Dir::XPlus));
    }

    #[test]
    fn self_route_is_empty() {
        let t = TorusSpec::new(3, 3, 3);
        assert!(route(&t, NodeAddr(5), NodeAddr(5)).is_empty());
        assert!(next_hop(&t, NodeAddr(5), NodeAddr(5)).is_none());
    }

    #[test]
    fn links_on_route_matches_route() {
        let t = TorusSpec::new(4, 2, 2);
        let src = NodeAddr(0);
        let dst = t.addr_of(2, 1, 1);
        let p = route(&t, src, dst);
        let l = links_on_route(&t, src, dst);
        assert_eq!(p.len(), l.len());
        assert_eq!(l[0].0, src);
        for (i, (node, dir)) in l.iter().enumerate() {
            assert_eq!(*dir, p[i]);
            if i + 1 < l.len() {
                assert_eq!(t.neighbor(*node, *dir), l[i + 1].0);
            }
        }
    }

    #[test]
    fn deadlock_freedom_no_cycles_in_channel_dependency() {
        // Dimension-order routing: a packet never goes from a higher axis
        // back to a lower one; verify on a larger torus by sampling.
        let t = TorusSpec::new(6, 6, 6);
        let mut checked = 0;
        for s in (0..216).step_by(7) {
            for d in (0..216).step_by(5) {
                let p = route(&t, NodeAddr(s), NodeAddr(d));
                let mut max_axis = 0;
                for dir in p {
                    assert!(dir.axis() >= max_axis);
                    max_axis = dir.axis();
                }
                checked += 1;
            }
        }
        assert!(checked > 1000);
    }

    /// A LinkStatus over an explicit set of dead directed links.
    struct DeadSet(BTreeSet<(u16, u8)>);

    impl LinkStatus for DeadSet {
        fn alive(&self, from: NodeAddr, dir: Dir) -> bool {
            !self.0.contains(&(from.0, dir.port()))
        }
    }

    /// Kill both directions of the cable leaving `a` towards `d`.
    fn kill_cable(dead: &mut BTreeSet<(u16, u8)>, t: &TorusSpec, a: NodeAddr, d: Dir) {
        let b = t.neighbor(a, d);
        dead.insert((a.0, d.port()));
        dead.insert((b.0, d.opposite().port()));
    }

    #[test]
    fn fault_free_view_matches_next_hop_exactly() {
        let t = TorusSpec::new(4, 3, 2);
        for src in t.nodes() {
            for dst in t.nodes() {
                let expected = match next_hop(&t, src, dst) {
                    None => Hop::Deliver,
                    Some(d) => Hop::Via(d),
                };
                assert_eq!(next_hop_with(&t, &FaultFree, src, dst), expected);
                // and an all-alive explicit view takes the same decisions
                let empty = DeadSet(BTreeSet::new());
                assert_eq!(next_hop_with(&t, &empty, src, dst), expected);
            }
        }
    }

    #[test]
    fn detour_routes_around_a_dead_cable() {
        let t = TorusSpec::new(4, 4, 1);
        let src = t.addr_of(0, 0, 0);
        let dst = t.addr_of(2, 0, 0);
        // kill the first X+ link on the dimension-order path
        let mut dead = BTreeSet::new();
        kill_cable(&mut dead, &t, src, Dir::XPlus);
        let status = DeadSet(dead);
        let p = route_with(&t, &status, src, dst).expect("still connected");
        // the path must avoid the dead link and still arrive
        let mut here = src;
        for d in &p {
            assert!(status.alive(here, *d), "route used dead link at {here}");
            here = t.neighbor(here, *d);
        }
        assert_eq!(here, dst);
        // the live shortest path is still length >= the fault-free one
        assert!(p.len() as u32 >= t.hop_distance(src, dst));
    }

    #[test]
    fn disconnected_destination_is_unreachable_not_a_panic() {
        let t = TorusSpec::new(3, 1, 1);
        let dst = NodeAddr(1);
        // sever node 1 from the ring entirely (both cables, both ways)
        let mut dead = BTreeSet::new();
        kill_cable(&mut dead, &t, NodeAddr(0), Dir::XPlus); // 0 <-> 1
        kill_cable(&mut dead, &t, NodeAddr(1), Dir::XPlus); // 1 <-> 2
        let status = DeadSet(dead);
        assert_eq!(next_hop_with(&t, &status, NodeAddr(0), dst), Hop::Unreachable);
        assert_eq!(route_with(&t, &status, NodeAddr(0), dst), None);
        assert_eq!(links_on_route_with(&t, &status, NodeAddr(0), dst), None);
        // the severed node can still deliver to itself
        assert_eq!(next_hop_with(&t, &status, dst, dst), Hop::Deliver);
    }

    #[test]
    fn adaptive_prefers_dimension_order_when_possible() {
        let t = TorusSpec::new(4, 4, 4);
        // a dead cable far away from the src->dst corridor must not
        // change the decision
        let src = t.addr_of(0, 0, 0);
        let dst = t.addr_of(2, 2, 0);
        let mut dead = BTreeSet::new();
        kill_cable(&mut dead, &t, t.addr_of(0, 0, 3), Dir::ZPlus);
        let status = DeadSet(dead);
        assert_eq!(
            route_with(&t, &status, src, dst).unwrap(),
            route(&t, src, dst),
            "distant fault perturbed a dimension-order route"
        );
    }

    #[test]
    fn live_distances_match_hop_distance_when_fault_free() {
        let t = TorusSpec::new(3, 4, 2);
        for dst in t.nodes() {
            let dist = live_distances(&t, &FaultFree, dst);
            for a in t.nodes() {
                assert_eq!(dist[a.0 as usize], t.hop_distance(a, dst), "{a}->{dst}");
            }
        }
    }
}
