//! Dimension-order routing on the 3D torus (paper §1).
//!
//! "Routing of messages through the network is entirely done by the
//! Tourmalet network chips and is based on a given 16 bit destination
//! address in the message header." We implement deterministic
//! dimension-order (X → Y → Z) routing with wrap-aware shortest direction
//! per axis — the standard deadlock-free scheme for torus networks and the
//! default in Extoll deployments.

use super::torus::{Dir, NodeAddr, TorusSpec};

/// Compute the egress direction at `here` for a packet addressed to `dst`.
/// Returns `None` when `here == dst` (deliver locally).
pub fn next_hop(torus: &TorusSpec, here: NodeAddr, dst: NodeAddr) -> Option<Dir> {
    if here == dst {
        return None;
    }
    let (hx, hy, hz) = torus.coords_of(here);
    let (dx, dy, dz) = torus.coords_of(dst);
    for (axis, (h, d)) in [(hx, dx), (hy, dy), (hz, dz)].into_iter().enumerate() {
        if h != d {
            let delta = torus.shortest_delta(h, d, axis);
            let dir = match (axis, delta > 0) {
                (0, true) => Dir::XPlus,
                (0, false) => Dir::XMinus,
                (1, true) => Dir::YPlus,
                (1, false) => Dir::YMinus,
                (2, true) => Dir::ZPlus,
                (2, false) => Dir::ZMinus,
                _ => unreachable!(),
            };
            return Some(dir);
        }
    }
    None
}

/// Full path (sequence of directions) from `src` to `dst`.
pub fn route(torus: &TorusSpec, src: NodeAddr, dst: NodeAddr) -> Vec<Dir> {
    let mut path = Vec::new();
    let mut here = src;
    while let Some(d) = next_hop(torus, here, dst) {
        path.push(d);
        here = torus.neighbor(here, d);
        assert!(
            path.len() <= torus.n_nodes(),
            "routing loop from {src} to {dst}"
        );
    }
    path
}

/// Every (node, direction) link crossed on the path from `src` to `dst`.
/// Used by the flow-level analysis to accumulate per-link loads.
pub fn links_on_route(torus: &TorusSpec, src: NodeAddr, dst: NodeAddr) -> Vec<(NodeAddr, Dir)> {
    let mut links = Vec::new();
    let mut here = src;
    while let Some(d) = next_hop(torus, here, dst) {
        links.push((here, d));
        here = torus.neighbor(here, d);
    }
    links
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_reach_destination_minimally() {
        let t = TorusSpec::new(4, 4, 4);
        for src in t.nodes() {
            for dst in t.nodes() {
                let p = route(&t, src, dst);
                assert_eq!(p.len() as u32, t.hop_distance(src, dst), "{src}->{dst}");
                // walk it
                let mut here = src;
                for d in &p {
                    here = t.neighbor(here, *d);
                }
                assert_eq!(here, dst);
            }
        }
    }

    #[test]
    fn dimension_order_is_respected() {
        let t = TorusSpec::new(4, 4, 4);
        for src in t.nodes() {
            for dst in t.nodes() {
                let p = route(&t, src, dst);
                // axis indices along the path must be non-decreasing
                let axes: Vec<usize> = p.iter().map(|d| d.axis()).collect();
                let mut sorted = axes.clone();
                sorted.sort_unstable();
                assert_eq!(axes, sorted, "{src}->{dst} path not dimension-ordered");
            }
        }
    }

    #[test]
    fn wrap_direction_is_shortest() {
        let t = TorusSpec::new(8, 1, 1);
        // 0 -> 7 should go X- (1 hop), not X+ (7 hops)
        let p = route(&t, NodeAddr(0), NodeAddr(7));
        assert_eq!(p, vec![Dir::XMinus]);
        let p = route(&t, NodeAddr(0), NodeAddr(3));
        assert_eq!(p.len(), 3);
        assert!(p.iter().all(|d| *d == Dir::XPlus));
    }

    #[test]
    fn self_route_is_empty() {
        let t = TorusSpec::new(3, 3, 3);
        assert!(route(&t, NodeAddr(5), NodeAddr(5)).is_empty());
        assert!(next_hop(&t, NodeAddr(5), NodeAddr(5)).is_none());
    }

    #[test]
    fn links_on_route_matches_route() {
        let t = TorusSpec::new(4, 2, 2);
        let src = NodeAddr(0);
        let dst = t.addr_of(2, 1, 1);
        let p = route(&t, src, dst);
        let l = links_on_route(&t, src, dst);
        assert_eq!(p.len(), l.len());
        assert_eq!(l[0].0, src);
        for (i, (node, dir)) in l.iter().enumerate() {
            assert_eq!(*dir, p[i]);
            if i + 1 < l.len() {
                assert_eq!(t.neighbor(*node, *dir), l[i + 1].0);
            }
        }
    }

    #[test]
    fn deadlock_freedom_no_cycles_in_channel_dependency() {
        // Dimension-order routing: a packet never goes from a higher axis
        // back to a lower one; verify on a larger torus by sampling.
        let t = TorusSpec::new(6, 6, 6);
        let mut checked = 0;
        for s in (0..216).step_by(7) {
            for d in (0..216).step_by(5) {
                let p = route(&t, NodeAddr(s), NodeAddr(d));
                let mut max_axis = 0;
                for dir in p {
                    assert!(dir.axis() >= max_axis);
                    max_axis = dir.axis();
                }
                checked += 1;
            }
        }
        assert!(checked > 1000);
    }
}
