//! Extoll packet model (paper §1, §3.1).
//!
//! An Extoll packet carries up to **496 B of payload** — 31 sixteen-byte
//! event cells, i.e. **124 events** (paper §3.1). Header/trailer overhead
//! is modeled as 24 B (routing + command word, RMA descriptor, CRC),
//! consistent with the published Extoll RMA packet layout and with the
//! paper's observation that single-event messages are limited to one event
//! per two 210 MHz clocks on the FPGA's 64-bit egress datapath.

use crate::fpga::event::{payload_bytes_for_events, RoutedEvent, CELL_BYTES};
use crate::fpga::lookup::EndpointAddr;
use crate::sim::{ActorId, Time};

use super::torus::NodeAddr;

/// Free-list pooling of spike-batch payload buffers — the packet-object
/// pooling of the DES hot path (ROADMAP perf target; A/B'd in
/// `benches/bench_events.rs`).
///
/// A `SpikeBatch` packet's only heap allocation is its
/// `Vec<RoutedEvent>` payload. That vector is born when an aggregation
/// bucket cuts a flush batch (`fpga/bucket.rs`), rides the packet
/// through concentrators and NICs by move (transit never reallocates —
/// see `extoll/nic.rs`), and dies when the destination FPGA's RX path
/// consumes it. Under load that is one allocation + one free per packet,
/// the next-largest allocator load after the slab-pooled event queue.
///
/// This pool closes the loop: the RX path [`pool::recycle`]s the spent
/// buffer and the bucket layer [`pool::take`]s it for the next flush.
/// Free lists are **thread-local**, so partitioned PDES workers never
/// contend, and pooling is invisible to the simulation: buffers are
/// cleared on reuse and carry no identity, so reports are byte-identical
/// with the pool on or off (gated in `rust/tests/determinism_queue.rs`).
/// [`pool::set_enabled`] exists for exactly that A/B.
pub mod pool {
    use std::cell::RefCell;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    use crate::fpga::event::RoutedEvent;

    /// Cap on pooled buffers per thread (a full list is ~124 events ×
    /// 4096 buffers ≈ 8 MB of f32-sized cells — generous for any
    /// machine size we simulate; beyond it, recycled buffers just drop).
    const MAX_FREE_PER_THREAD: usize = 4096;

    static ENABLED: AtomicBool = AtomicBool::new(true);
    static RECYCLED: AtomicU64 = AtomicU64::new(0);
    static FRESH: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        static FREE: RefCell<Vec<Vec<RoutedEvent>>> = RefCell::new(Vec::new());
    }

    /// Turn pooling off/on (process-wide). Only intended for the
    /// bench A/B; the default is on.
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// An empty event buffer with at least `capacity` reserved —
    /// recycled when the thread-local free list has one, fresh otherwise.
    ///
    /// Disabled, it returns an **unreserved** `Vec` — exactly the
    /// pre-pooling behaviour (`std::mem::take` of a bucket accumulator),
    /// so the bench A/B measures pooling against the true old baseline
    /// rather than a pre-reserved one.
    pub fn take(capacity: usize) -> Vec<RoutedEvent> {
        if !enabled() {
            return Vec::new();
        }
        let recycled = FREE.with(|f| f.borrow_mut().pop());
        if let Some(mut buf) = recycled {
            RECYCLED.fetch_add(1, Ordering::Relaxed);
            debug_assert!(buf.is_empty());
            if buf.capacity() < capacity {
                // buf is empty, so this guarantees capacity() ≥ capacity
                buf.reserve(capacity);
            }
            return buf;
        }
        FRESH.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(capacity)
    }

    /// Return a spent payload buffer to the current thread's free list.
    pub fn recycle(mut buf: Vec<RoutedEvent>) {
        if !enabled() || buf.capacity() == 0 {
            return;
        }
        buf.clear();
        FREE.with(|f| {
            let mut free = f.borrow_mut();
            if free.len() < MAX_FREE_PER_THREAD {
                free.push(buf);
            }
        });
    }

    /// `(recycled, fresh)` buffer counts since the last
    /// [`reset_stats`] (process-wide, for the bench artifact).
    pub fn stats() -> (u64, u64) {
        (
            RECYCLED.load(Ordering::Relaxed),
            FRESH.load(Ordering::Relaxed),
        )
    }

    pub fn reset_stats() {
        RECYCLED.store(0, Ordering::Relaxed);
        FRESH.store(0, Ordering::Relaxed);
    }
}

/// Maximum payload per Extoll packet (paper: 496 B = 124 events).
pub const MAX_PAYLOAD_BYTES: u32 = 496;
/// Maximum events per packet (paper: 124).
pub const MAX_EVENTS_PER_PACKET: usize = 124;
/// Modeled header+trailer overhead per packet on the wire.
pub const HEADER_BYTES: u32 = 24;
/// FPGA egress datapath width (64-bit words at the 210 MHz clock).
pub const DATAPATH_BITS_PER_CYCLE: u32 = 64;

/// What a packet carries.
#[derive(Clone, Debug, PartialEq)]
pub enum PacketKind {
    /// Aggregated spike events for one destination FPGA (paper §3.1).
    SpikeBatch {
        /// Which of the 6 FPGAs behind the destination concentrator.
        dst_fpga: u8,
        /// Events, at most [`MAX_EVENTS_PER_PACKET`].
        events: Vec<RoutedEvent>,
    },
    /// RMA PUT to host memory (paper §2): ring-buffer data stream.
    RmaPut {
        /// Network logical address the payload is written to.
        nla: u64,
        /// Raise a notification at the target on completion.
        notify: bool,
        /// Logical payload size (bytes) written to host memory.
        bytes: u32,
    },
    /// RMA notification message (completion/credit exchange, paper §2.1).
    Notification { code: u64 },
    /// Opaque bulk payload (baseline comparisons, fabric stress tests).
    Raw,
}

/// A packet traversing the Extoll fabric.
#[derive(Clone, Debug)]
pub struct Packet {
    pub src: NodeAddr,
    pub dst: NodeAddr,
    pub kind: PacketKind,
    /// Payload bytes on the wire (already cell-padded for spike batches).
    pub payload_bytes: u32,
    /// Global sequence number (tracking, dedup checks in tests).
    pub seq: u64,
    /// When the payload's oldest content was created (latency accounting).
    pub created: Time,
    /// When the packet was injected into the fabric.
    pub injected: Time,
    /// Hop count so far.
    pub hops: u8,
    /// Ingress bookkeeping for the current hop (actor, port, vc), used by
    /// the NIC to return link-level credits upstream.
    pub ingress: Option<(ActorId, u8, u8)>,
    /// Fabric-internal: current virtual channel (dateline scheme).
    pub vc: u8,
    /// Fabric-internal: axis of the ring currently being traversed
    /// (3 = none yet / local).
    pub axis: u8,
    /// Link-layer sequence number of the **current hop** under
    /// `reliability=link` (`extoll/link.rs`); `0` = unstamped. Stamped by
    /// the transmitting port, cleared on acceptance so the next hop
    /// re-stamps; a nonzero value on a queued packet marks it as a
    /// retransmission copy.
    pub link_seq: u64,
}

impl Packet {
    /// Build a spike-batch packet; pads payload to whole 16-byte cells.
    pub fn spike_batch(
        src: NodeAddr,
        dst: EndpointAddr,
        events: Vec<RoutedEvent>,
        created: Time,
        seq: u64,
    ) -> Packet {
        assert!(
            events.len() <= MAX_EVENTS_PER_PACKET,
            "spike batch of {} events exceeds the 124-event Extoll maximum",
            events.len()
        );
        assert!(!events.is_empty(), "empty spike batch");
        let payload_bytes = payload_bytes_for_events(events.len());
        Packet {
            src,
            dst: dst.node,
            kind: PacketKind::SpikeBatch {
                dst_fpga: dst.fpga,
                events,
            },
            payload_bytes,
            seq,
            created,
            injected: Time::ZERO,
            hops: 0,
            ingress: None,
            vc: 0,
            axis: 3,
            link_seq: 0,
        }
    }

    /// Build an RMA PUT packet (host communication path).
    pub fn rma_put(
        src: NodeAddr,
        dst: NodeAddr,
        nla: u64,
        bytes: u32,
        notify: bool,
        created: Time,
        seq: u64,
    ) -> Packet {
        assert!(bytes <= MAX_PAYLOAD_BYTES, "RMA PUT of {bytes} B exceeds max payload");
        Packet {
            src,
            dst,
            kind: PacketKind::RmaPut { nla, notify, bytes },
            payload_bytes: bytes,
            seq,
            created,
            injected: Time::ZERO,
            hops: 0,
            ingress: None,
            vc: 0,
            axis: 3,
            link_seq: 0,
        }
    }

    /// Build a small notification packet (credit/completion, paper §2.1).
    pub fn notification(src: NodeAddr, dst: NodeAddr, code: u64, created: Time, seq: u64) -> Packet {
        Packet {
            src,
            dst,
            kind: PacketKind::Notification { code },
            payload_bytes: 8,
            seq,
            created,
            injected: Time::ZERO,
            hops: 0,
            ingress: None,
            vc: 0,
            axis: 3,
            link_seq: 0,
        }
    }

    /// Build an opaque packet of `payload_bytes` (baselines, stress).
    pub fn raw(src: NodeAddr, dst: NodeAddr, payload_bytes: u32, created: Time, seq: u64) -> Packet {
        assert!(
            payload_bytes <= MAX_PAYLOAD_BYTES,
            "Extoll payload limit is {MAX_PAYLOAD_BYTES} B; use raw_gbe for Ethernet-framed baselines"
        );
        Packet {
            src,
            dst,
            kind: PacketKind::Raw,
            payload_bytes,
            seq,
            created,
            injected: Time::ZERO,
            hops: 0,
            ingress: None,
            vc: 0,
            axis: 3,
            link_seq: 0,
        }
    }

    /// Opaque packet without the Extoll payload limit (GbE baseline frames
    /// may carry up to 1472 B of UDP payload).
    pub fn raw_gbe(src: NodeAddr, dst: NodeAddr, payload_bytes: u32, created: Time, seq: u64) -> Packet {
        Packet {
            src,
            dst,
            kind: PacketKind::Raw,
            payload_bytes,
            seq,
            created,
            injected: Time::ZERO,
            hops: 0,
            ingress: None,
            vc: 0,
            axis: 3,
            link_seq: 0,
        }
    }

    /// Number of events carried (0 for non-spike packets).
    pub fn n_events(&self) -> usize {
        match &self.kind {
            PacketKind::SpikeBatch { events, .. } => events.len(),
            _ => 0,
        }
    }

    /// Total bytes on the wire including header/trailer overhead.
    pub fn wire_bytes(&self) -> u32 {
        HEADER_BYTES + self.payload_bytes
    }

    /// 210 MHz cycles to shift this packet through the FPGA's 64-bit
    /// egress datapath (header word(s) + payload words, rounded up).
    pub fn egress_cycles(&self) -> u64 {
        let bits = (self.wire_bytes() as u64) * 8;
        bits.div_ceil(DATAPATH_BITS_PER_CYCLE as u64)
    }

    /// Header overhead as a fraction of the wire size.
    pub fn overhead_fraction(&self) -> f64 {
        HEADER_BYTES as f64 / self.wire_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::event::RoutedEvent;

    fn ev(n: usize) -> Vec<RoutedEvent> {
        (0..n)
            .map(|i| RoutedEvent::new((i % 32768) as u16, (i % 32768) as u16, Time::ZERO))
            .collect()
    }

    #[test]
    fn max_batch_is_496_bytes() {
        let p = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(124), Time::ZERO, 0);
        assert_eq!(p.payload_bytes, MAX_PAYLOAD_BYTES);
        assert_eq!(p.wire_bytes(), 520);
        assert_eq!(p.n_events(), 124);
    }

    #[test]
    #[should_panic(expected = "exceeds the 124-event")]
    fn oversize_batch_rejected() {
        let _ = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(125), Time::ZERO, 0);
    }

    #[test]
    fn single_event_overhead_matches_paper_rate() {
        // One event per message: header(24B) + one cell(16B) = 40B = 5
        // 64-bit words -> 5 cycles on the datapath. The paper's "one event
        // every two clocks" is the *sustained header-limited* rate with the
        // minimal-header internal format; our wire model is strictly more
        // pessimistic per message, and the aggregation win we measure is
        // therefore a lower bound. Check the numbers are in that regime.
        let p = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(1), Time::ZERO, 0);
        assert_eq!(p.wire_bytes(), 40);
        assert!(p.egress_cycles() >= 2, "at least two clocks per single event");
        // Aggregated: 124 events in 520B -> ~0.52 cycles/event.
        let big = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(124), Time::ZERO, 0);
        let per_event = big.egress_cycles() as f64 / 124.0;
        assert!(per_event < 1.0, "aggregation must beat 1 cycle/event, got {per_event}");
    }

    #[test]
    fn overhead_fraction_decreases_with_aggregation() {
        let small = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(1), Time::ZERO, 0);
        let big = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(124), Time::ZERO, 0);
        assert!(small.overhead_fraction() > 0.5);
        assert!(big.overhead_fraction() < 0.05);
    }

    #[test]
    fn rma_put_fields() {
        let p = Packet::rma_put(NodeAddr(2), NodeAddr(3), 0xDEAD_BEEF, 256, true, Time::ZERO, 7);
        assert_eq!(p.payload_bytes, 256);
        assert_eq!(p.wire_bytes(), 280);
        match p.kind {
            PacketKind::RmaPut { nla, notify, bytes } => {
                assert_eq!(nla, 0xDEAD_BEEF);
                assert!(notify);
                assert_eq!(bytes, 256);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn notification_is_small() {
        let p = Packet::notification(NodeAddr(0), NodeAddr(1), 42, Time::ZERO, 0);
        assert!(p.wire_bytes() <= 32);
    }

    /// One test covers take/recycle/disable: the enable flag is
    /// process-wide, so splitting these into parallel-running tests
    /// would race on it. (Free lists themselves are thread-local.)
    #[test]
    fn pool_roundtrip_and_disable() {
        let spent = {
            let mut v = pool::take(124);
            assert!(v.capacity() >= 124);
            v.push(RoutedEvent::new(1, 2, Time::ZERO));
            v
        };
        pool::recycle(spent);
        let reused = pool::take(124);
        assert!(reused.is_empty(), "recycled buffer must come back cleared");
        assert!(reused.capacity() >= 124);
        // a zero-capacity buffer is not worth pooling
        pool::recycle(Vec::new());
        let (recycled, fresh) = pool::stats();
        assert!(recycled >= 1);
        assert!(fresh >= 1);
        // disabled: take reverts to the pre-pooling baseline — an
        // unreserved buffer that regrows on demand
        pool::set_enabled(false);
        assert!(!pool::enabled());
        let v = pool::take(16);
        assert_eq!(v.capacity(), 0);
        pool::set_enabled(true);
    }

    #[test]
    fn cell_padding() {
        let p = Packet::spike_batch(NodeAddr(0), EndpointAddr::new(NodeAddr(1), 2), ev(5), Time::ZERO, 0);
        assert_eq!(p.payload_bytes, 2 * CELL_BYTES);
    }
}
