//! Remote Memory Access (RMA) protocol helpers (paper §2).
//!
//! The FPGA↔host path uses the Extoll RMA unit: one-sided PUTs into a
//! remote memory window plus a hardware **notification** queue that tells
//! the software how much data arrived (paper §2/§2.1). This module provides
//! the pieces shared by the FPGA-side requester and the host-side
//! completer: PUT fragmentation over the 496-byte packet payload limit and
//! the 64-bit notification word codec.

use crate::sim::Time;

use super::packet::{Packet, MAX_PAYLOAD_BYTES};
use super::torus::NodeAddr;

/// Notification word layout: `kind(4) | channel(12) | value(48)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Notification {
    /// FPGA → host: `value` bytes were written to ring buffer `channel`.
    DataWritten { channel: u16, bytes: u64 },
    /// Host → FPGA: software freed `value` bytes of ring buffer `channel`
    /// (credit return, paper §2.1 "credit based flow control").
    SpaceFreed { channel: u16, bytes: u64 },
    /// Generic completion (RMA PUT with notification flag).
    Completion { channel: u16, value: u64 },
}

const KIND_DATA: u64 = 1;
const KIND_SPACE: u64 = 2;
const KIND_COMPLETION: u64 = 3;
const VALUE_MASK: u64 = (1 << 48) - 1;

impl Notification {
    /// Encode into the 64-bit notification word.
    pub fn encode(self) -> u64 {
        let (kind, ch, val) = match self {
            Notification::DataWritten { channel, bytes } => (KIND_DATA, channel, bytes),
            Notification::SpaceFreed { channel, bytes } => (KIND_SPACE, channel, bytes),
            Notification::Completion { channel, value } => (KIND_COMPLETION, channel, value),
        };
        debug_assert!(ch < (1 << 12));
        debug_assert!(val <= VALUE_MASK);
        (kind << 60) | ((ch as u64) << 48) | (val & VALUE_MASK)
    }

    /// Decode a notification word; `None` for unknown kinds.
    pub fn decode(w: u64) -> Option<Notification> {
        let kind = w >> 60;
        let channel = ((w >> 48) & 0xFFF) as u16;
        let value = w & VALUE_MASK;
        match kind {
            KIND_DATA => Some(Notification::DataWritten {
                channel,
                bytes: value,
            }),
            KIND_SPACE => Some(Notification::SpaceFreed {
                channel,
                bytes: value,
            }),
            KIND_COMPLETION => Some(Notification::Completion { channel, value }),
            _ => None,
        }
    }

    /// Wrap into a small fabric packet.
    pub fn packet(self, src: NodeAddr, dst: NodeAddr, now: Time, seq: u64) -> Packet {
        Packet::notification(src, dst, self.encode(), now, seq)
    }
}

/// Fragment a logical write of `bytes` at `nla` into RMA PUT packets that
/// respect the Extoll payload limit. Only the **last** fragment carries the
/// notification flag, so the receiver raises one notification per logical
/// write — exactly the behaviour the ring-buffer protocol relies on.
pub fn fragment_put(
    src: NodeAddr,
    dst: NodeAddr,
    nla: u64,
    bytes: u64,
    notify: bool,
    now: Time,
    seq_base: u64,
) -> Vec<Packet> {
    assert!(bytes > 0, "empty RMA PUT");
    let mut out = Vec::new();
    let mut offset = 0u64;
    while offset < bytes {
        let chunk = (bytes - offset).min(MAX_PAYLOAD_BYTES as u64) as u32;
        let last = offset + chunk as u64 >= bytes;
        out.push(Packet::rma_put(
            src,
            dst,
            nla + offset,
            chunk,
            notify && last,
            now,
            seq_base + out.len() as u64,
        ));
        offset += chunk as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::packet::PacketKind;

    #[test]
    fn notification_roundtrip() {
        for n in [
            Notification::DataWritten {
                channel: 5,
                bytes: 4096,
            },
            Notification::SpaceFreed {
                channel: 4095,
                bytes: (1 << 48) - 1,
            },
            Notification::Completion {
                channel: 0,
                value: 42,
            },
        ] {
            assert_eq!(Notification::decode(n.encode()), Some(n));
        }
    }

    #[test]
    fn unknown_kind_decodes_none() {
        assert_eq!(Notification::decode(0), None);
        assert_eq!(Notification::decode(0xF << 60), None);
    }

    #[test]
    fn fragmentation_respects_payload_limit() {
        let ps = fragment_put(NodeAddr(0), NodeAddr(1), 0x1000, 1500, true, Time::ZERO, 0);
        assert_eq!(ps.len(), 4); // 496+496+496+12
        let mut total = 0u64;
        let mut notis = 0;
        let mut expect_nla = 0x1000u64;
        for p in &ps {
            match p.kind {
                PacketKind::RmaPut { nla, notify, bytes } => {
                    assert!(bytes <= MAX_PAYLOAD_BYTES);
                    assert_eq!(nla, expect_nla);
                    expect_nla += bytes as u64;
                    total += bytes as u64;
                    if notify {
                        notis += 1;
                    }
                }
                _ => panic!("not a put"),
            }
        }
        assert_eq!(total, 1500);
        assert_eq!(notis, 1);
        // only the last one notifies
        assert!(matches!(
            ps.last().unwrap().kind,
            PacketKind::RmaPut { notify: true, .. }
        ));
    }

    #[test]
    fn small_put_single_fragment() {
        let ps = fragment_put(NodeAddr(0), NodeAddr(1), 0, 64, false, Time::ZERO, 10);
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].seq, 10);
        assert!(matches!(
            ps[0].kind,
            PacketKind::RmaPut { notify: false, .. }
        ));
    }

    #[test]
    fn exact_multiple_of_payload() {
        let ps = fragment_put(NodeAddr(0), NodeAddr(1), 0, 992, true, Time::ZERO, 0);
        assert_eq!(ps.len(), 2);
        assert!(matches!(ps[1].kind, PacketKind::RmaPut { notify: true, .. }));
        assert!(matches!(ps[0].kind, PacketKind::RmaPut { notify: false, .. }));
    }
}
