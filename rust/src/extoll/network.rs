//! Fabric construction helpers: build a torus of NIC actors and wire the
//! neighbor links.
//!
//! The builder exploits the fact that [`crate::sim::Sim::add`] assigns
//! consecutive actor ids: NICs are added in node-address order, so the id
//! of node `a` is `base + a.0`, and neighbor wiring needs no second pass.

use crate::msg::Msg;
use crate::sim::{ActorId, ChannelGraph, Sim, Time};

use super::nic::{Nic, NicConfig};
use super::torus::{Dir, DomainMap, NodeAddr, TorusSpec, DIRS, TORUS_PORTS};

/// Build a full torus of NICs; returns the actor ids in node-address order.
///
/// Local units are attached afterwards via [`Nic::attach_local`].
pub fn build_torus(sim: &mut Sim<Msg>, spec: &TorusSpec, cfg: NicConfig) -> Vec<ActorId> {
    let base = sim.n_actors();
    let ids: Vec<ActorId> = spec
        .nodes()
        .map(|addr| sim.add(Nic::new(addr, *spec, cfg)))
        .collect();
    debug_assert_eq!(ids.first().copied(), Some(base));
    for addr in spec.nodes() {
        for dir in DIRS {
            let n = spec.neighbor(addr, dir);
            let id = ids[addr.0 as usize];
            sim.get_mut::<Nic>(id).set_neighbor(dir, base + n.0 as usize);
        }
    }
    ids
}

/// The smallest latency any message can incur on the directed torus link
/// `a --dir--> b` — that link's contribution to the conservative-PDES
/// lookahead. Today every link shares one [`NicConfig`], so this is the
/// config's per-link minimum ([`NicConfig::min_link_latency`]:
/// credit returns pay cable + pipeline; packets pay serialization on
/// top); a heterogeneous fabric (per-cable lengths, mixed lane counts)
/// only needs to specialize this one function — every lookahead below is
/// folded over it, edge by edge.
pub fn edge_min_latency(cfg: &NicConfig, _from: NodeAddr, _dir: Dir, _to: NodeAddr) -> Time {
    cfg.min_link_latency()
}

/// Conservative-PDES lookahead for a partitioned fabric: the minimum of
/// [`edge_min_latency`] over every **inter-domain** torus link
/// ([`DomainMap::inter_domain_edges`]). A domain may therefore execute up
/// to `min(domain clocks) + lookahead`, exclusive, without risking a
/// causality violation (`docs/ARCHITECTURE.md` has the full invariant).
/// Returns `None` when no inter-domain links exist (single domain) —
/// nothing to synchronize on.
pub fn pdes_lookahead(dm: &DomainMap, cfg: &NicConfig) -> Option<Time> {
    dm.inter_domain_edges()
        .into_iter()
        .map(|(a, d, b)| edge_min_latency(cfg, a, d, b))
        .min()
}

/// Per-neighbor channel-clock topology for a partitioned fabric
/// ([`crate::sim::SyncMode::Channel`]): one direct edge per ordered pair
/// of adjacent domains, with lookahead = the minimum
/// [`edge_min_latency`] over that pair's physical links;
/// [`ChannelGraph::from_edges`] then closes the edge set under path
/// composition (min-plus distances, minimum cycles on the diagonal).
/// This is the full Chandy–Misra–Bryant bound [`pdes_lookahead`] is the
/// global-minimum collapse of: with channel clocks, a domain constrains
/// another only through the accumulated lookahead of a real route
/// between them.
pub fn pdes_channel_graph(dm: &DomainMap, cfg: &NicConfig) -> ChannelGraph {
    let edges = dm
        .inter_domain_edges()
        .into_iter()
        .map(|(a, d, b)| (dm.domain_of(a), dm.domain_of(b), edge_min_latency(cfg, a, d, b)));
    ChannelGraph::from_edges(dm.n_domains(), edges)
}

/// A handle to a built fabric (spec + NIC actor ids), with convenience
/// accessors for post-run statistics.
pub struct Fabric {
    pub spec: TorusSpec,
    pub cfg: NicConfig,
    pub nics: Vec<ActorId>,
}

impl Fabric {
    pub fn build(sim: &mut Sim<Msg>, spec: TorusSpec, cfg: NicConfig) -> Fabric {
        let nics = build_torus(sim, &spec, cfg);
        Fabric { spec, cfg, nics }
    }

    /// Total packets delivered to local units across all nodes.
    pub fn total_delivered(&self, sim: &Sim<Msg>) -> u64 {
        self.nics
            .iter()
            .map(|&id| sim.get::<Nic>(id).stats.delivered)
            .sum()
    }

    /// Total spike events delivered across all nodes.
    pub fn total_delivered_events(&self, sim: &Sim<Msg>) -> u64 {
        self.nics
            .iter()
            .map(|&id| sim.get::<Nic>(id).stats.delivered_events)
            .sum()
    }

    /// Merged transit-latency histogram (ps).
    pub fn transit_histogram(&self, sim: &Sim<Msg>) -> crate::util::stats::Histogram {
        let mut h = crate::util::stats::Histogram::new();
        for &id in &self.nics {
            h.merge(&sim.get::<Nic>(id).stats.transit_ps);
        }
        h
    }

    /// Peak utilization over all torus ports, given the observation
    /// window (the local port is deliberately excluded — it is not a
    /// torus link; `TORUS_PORTS` keeps it out by construction).
    pub fn max_link_utilization(&self, sim: &Sim<Msg>, window: crate::sim::Time) -> f64 {
        let mut max = 0.0f64;
        for &id in &self.nics {
            let nic = sim.get::<Nic>(id);
            for port in 0..TORUS_PORTS {
                max = max.max(nic.port_utilization(port, window));
            }
        }
        max
    }

    /// Mean utilization over all torus ports that carried any traffic.
    pub fn mean_active_link_utilization(&self, sim: &Sim<Msg>, window: crate::sim::Time) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u32;
        for &id in &self.nics {
            let nic = sim.get::<Nic>(id);
            for port in 0..TORUS_PORTS {
                if nic.port_tx_packets(port) > 0 {
                    sum += nic.port_utilization(port, window);
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_wires_all_neighbors() {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(3, 2, 2);
        let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
        assert_eq!(fabric.nics.len(), 12);
        // ids must map to addresses in order
        for (i, &id) in fabric.nics.iter().enumerate() {
            let nic = sim.get::<Nic>(id);
            assert_eq!(nic.addr.0 as usize, i);
        }
    }

    #[test]
    fn stats_start_zero() {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(2, 2, 1);
        let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
        assert_eq!(fabric.total_delivered(&sim), 0);
        assert_eq!(fabric.total_delivered_events(&sim), 0);
        assert_eq!(fabric.max_link_utilization(&sim, crate::sim::Time::from_us(1)), 0.0);
    }

    #[test]
    fn lookahead_folds_over_inter_domain_edges() {
        let spec = TorusSpec::new(4, 2, 2);
        let cfg = NicConfig::default();
        // uniform link config: the fold over the edge set equals the
        // per-link minimum
        let dm = DomainMap::new(spec, 4);
        assert!(!dm.inter_domain_edges().is_empty());
        assert_eq!(pdes_lookahead(&dm, &cfg), Some(cfg.min_link_latency()));
        // single domain: no inter-domain edges, nothing to synchronize on
        assert_eq!(pdes_lookahead(&DomainMap::new(spec, 1), &cfg), None);
    }

    #[test]
    fn channel_graph_closure_covers_all_domain_pairs() {
        let spec = TorusSpec::new(4, 2, 2);
        let cfg = NicConfig::default();
        let dm = DomainMap::new(spec, 4);
        let g = pdes_channel_graph(&dm, &cfg);
        assert_eq!(g.n_domains(), 4);
        // the cheapest channel is a single inter-domain hop
        assert_eq!(g.min_lookahead(), Some(cfg.min_link_latency()));
        // a torus is strongly connected, so its domain quotient is too:
        // the closure has a channel for every ordered pair, diagonal
        // (cycle) channels included
        assert_eq!(g.n_channels(), 4 * 4);
    }
}
