//! Fabric construction helpers: build a torus of NIC actors and wire the
//! neighbor links.
//!
//! The builder exploits the fact that [`crate::sim::Sim::add`] assigns
//! consecutive actor ids: NICs are added in node-address order, so the id
//! of node `a` is `base + a.0`, and neighbor wiring needs no second pass.

use std::sync::Arc;

use crate::fault::FaultModel;
use crate::msg::Msg;
use crate::sim::{ActorId, ChannelGraph, Sim, Time};

use super::nic::{Nic, NicConfig};
use super::torus::{Dir, DomainMap, NodeAddr, TorusSpec, DIRS, TORUS_PORTS};

/// Build a full torus of NICs; returns the actor ids in node-address order.
///
/// Local units are attached afterwards via [`Nic::attach_local`].
pub fn build_torus(sim: &mut Sim<Msg>, spec: &TorusSpec, cfg: NicConfig) -> Vec<ActorId> {
    build_torus_with(sim, spec, cfg, None)
}

/// [`build_torus`] with an optional fault model installed on every NIC.
pub fn build_torus_with(
    sim: &mut Sim<Msg>,
    spec: &TorusSpec,
    cfg: NicConfig,
    fault: Option<&Arc<FaultModel>>,
) -> Vec<ActorId> {
    let base = sim.n_actors();
    let ids: Vec<ActorId> = spec
        .nodes()
        .map(|addr| sim.add(Nic::new(addr, *spec, cfg)))
        .collect();
    debug_assert_eq!(ids.first().copied(), Some(base));
    for addr in spec.nodes() {
        for dir in DIRS {
            let n = spec.neighbor(addr, dir);
            let id = ids[addr.0 as usize];
            sim.get_mut::<Nic>(id).set_neighbor(dir, base + n.0 as usize);
        }
    }
    if let Some(model) = fault {
        for &id in &ids {
            sim.get_mut::<Nic>(id).set_fault_model(Arc::clone(model));
        }
    }
    ids
}

/// The smallest latency any message can incur on the directed torus link
/// `a --dir--> b` — that link's contribution to the conservative-PDES
/// lookahead. Today every link shares one [`NicConfig`], so this is the
/// config's per-link minimum ([`NicConfig::min_link_latency`]:
/// credit returns pay cable + pipeline; packets pay serialization on
/// top); a heterogeneous fabric (per-cable lengths, mixed lane counts)
/// only needs to specialize this one function — every lookahead below is
/// folded over it, edge by edge.
pub fn edge_min_latency(cfg: &NicConfig, _from: NodeAddr, _dir: Dir, _to: NodeAddr) -> Time {
    cfg.min_link_latency()
}

/// Conservative-PDES lookahead for a partitioned fabric: the minimum of
/// [`edge_min_latency`] over every **inter-domain** torus link
/// ([`DomainMap::inter_domain_edges`]). A domain may therefore execute up
/// to `min(domain clocks) + lookahead`, exclusive, without risking a
/// causality violation (`docs/ARCHITECTURE.md` has the full invariant).
/// Returns `None` when no inter-domain links exist (single domain) —
/// nothing to synchronize on.
pub fn pdes_lookahead(dm: &DomainMap, cfg: &NicConfig) -> Option<Time> {
    pdes_lookahead_with(dm, cfg, None)
}

/// [`pdes_lookahead`] aware of a fault model: links dead from t = 0
/// (`link_ever_alive == false`) never carry a message — adaptive routing
/// never selects them, and credits only travel on links packets arrived
/// over — so they are excluded from the fold. Links that fail mid-run
/// still count: packets enqueued just before the cutover may cross after
/// it. With today's uniform link config the exclusion only matters when a
/// domain pair loses *all* its physical links (the channel graph then
/// bounds that pair through real multi-hop routes instead); if every
/// inter-domain link is dead we fall back to the unfiltered edge set —
/// the bound stays conservative and partitioned setup keeps working.
pub fn pdes_lookahead_with(
    dm: &DomainMap,
    cfg: &NicConfig,
    fault: Option<&FaultModel>,
) -> Option<Time> {
    live_inter_domain_edges(dm, fault)
        .into_iter()
        .map(|(a, d, b)| edge_min_latency(cfg, a, d, b))
        .min()
}

/// The inter-domain edge set restricted to links the fault model ever
/// brings up, falling back to the full set when the filter would empty it
/// (see [`pdes_lookahead_with`] for why both halves are sound).
fn live_inter_domain_edges(
    dm: &DomainMap,
    fault: Option<&FaultModel>,
) -> Vec<(NodeAddr, Dir, NodeAddr)> {
    let all = dm.inter_domain_edges();
    let Some(model) = fault else {
        return all;
    };
    let live: Vec<_> = all
        .iter()
        .copied()
        .filter(|&(a, d, _)| model.link_ever_alive(a, d))
        .collect();
    if live.is_empty() {
        all
    } else {
        live
    }
}

/// Per-neighbor channel-clock topology for a partitioned fabric
/// ([`crate::sim::SyncMode::Channel`]): one direct edge per ordered pair
/// of adjacent domains, with lookahead = the minimum
/// [`edge_min_latency`] over that pair's physical links;
/// [`ChannelGraph::from_edges`] then closes the edge set under path
/// composition (min-plus distances, minimum cycles on the diagonal).
/// This is the full Chandy–Misra–Bryant bound [`pdes_lookahead`] is the
/// global-minimum collapse of: with channel clocks, a domain constrains
/// another only through the accumulated lookahead of a real route
/// between them.
pub fn pdes_channel_graph(dm: &DomainMap, cfg: &NicConfig) -> ChannelGraph {
    pdes_channel_graph_with(dm, cfg, None)
}

/// [`pdes_channel_graph`] aware of a fault model: never-alive links are
/// dropped before the closure, so a domain pair whose only direct cables
/// are dead is bounded through its surviving multi-hop routes (or not at
/// all, if routing cannot reach it — `ChannelGraph::from_edges` tolerates
/// disconnected pairs). Same filter and fallback as
/// [`pdes_lookahead_with`].
pub fn pdes_channel_graph_with(
    dm: &DomainMap,
    cfg: &NicConfig,
    fault: Option<&FaultModel>,
) -> ChannelGraph {
    let edges = live_inter_domain_edges(dm, fault)
        .into_iter()
        .map(|(a, d, b)| (dm.domain_of(a), dm.domain_of(b), edge_min_latency(cfg, a, d, b)));
    ChannelGraph::from_edges(dm.n_domains(), edges)
}

/// A handle to a built fabric (spec + NIC actor ids), with convenience
/// accessors for post-run statistics.
pub struct Fabric {
    pub spec: TorusSpec,
    pub cfg: NicConfig,
    pub nics: Vec<ActorId>,
}

impl Fabric {
    pub fn build(sim: &mut Sim<Msg>, spec: TorusSpec, cfg: NicConfig) -> Fabric {
        Fabric::build_with(sim, spec, cfg, None)
    }

    /// [`Fabric::build`] with an optional fault model installed on every
    /// NIC before the run starts.
    pub fn build_with(
        sim: &mut Sim<Msg>,
        spec: TorusSpec,
        cfg: NicConfig,
        fault: Option<&Arc<FaultModel>>,
    ) -> Fabric {
        let nics = build_torus_with(sim, &spec, cfg, fault);
        Fabric { spec, cfg, nics }
    }

    /// Total packets delivered to local units across all nodes.
    pub fn total_delivered(&self, sim: &Sim<Msg>) -> u64 {
        self.nics
            .iter()
            .map(|&id| sim.get::<Nic>(id).stats.delivered)
            .sum()
    }

    /// Total spike events delivered across all nodes.
    pub fn total_delivered_events(&self, sim: &Sim<Msg>) -> u64 {
        self.nics
            .iter()
            .map(|&id| sim.get::<Nic>(id).stats.delivered_events)
            .sum()
    }

    /// Merged transit-latency histogram (ps).
    pub fn transit_histogram(&self, sim: &Sim<Msg>) -> crate::util::stats::Histogram {
        let mut h = crate::util::stats::Histogram::new();
        for &id in &self.nics {
            h.merge(&sim.get::<Nic>(id).stats.transit_ps);
        }
        h
    }

    /// Peak utilization over all torus ports, given the observation
    /// window (the local port is deliberately excluded — it is not a
    /// torus link; `TORUS_PORTS` keeps it out by construction).
    pub fn max_link_utilization(&self, sim: &Sim<Msg>, window: crate::sim::Time) -> f64 {
        let mut max = 0.0f64;
        for &id in &self.nics {
            let nic = sim.get::<Nic>(id);
            for port in 0..TORUS_PORTS {
                max = max.max(nic.port_utilization(port, window));
            }
        }
        max
    }

    /// Mean utilization over all torus ports that carried any traffic.
    pub fn mean_active_link_utilization(&self, sim: &Sim<Msg>, window: crate::sim::Time) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u32;
        for &id in &self.nics {
            let nic = sim.get::<Nic>(id);
            for port in 0..TORUS_PORTS {
                if nic.port_tx_packets(port) > 0 {
                    sum += nic.port_utilization(port, window);
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_wires_all_neighbors() {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(3, 2, 2);
        let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
        assert_eq!(fabric.nics.len(), 12);
        // ids must map to addresses in order
        for (i, &id) in fabric.nics.iter().enumerate() {
            let nic = sim.get::<Nic>(id);
            assert_eq!(nic.addr.0 as usize, i);
        }
    }

    #[test]
    fn stats_start_zero() {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(2, 2, 1);
        let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
        assert_eq!(fabric.total_delivered(&sim), 0);
        assert_eq!(fabric.total_delivered_events(&sim), 0);
        assert_eq!(fabric.max_link_utilization(&sim, crate::sim::Time::from_us(1)), 0.0);
    }

    #[test]
    fn lookahead_folds_over_inter_domain_edges() {
        let spec = TorusSpec::new(4, 2, 2);
        let cfg = NicConfig::default();
        // uniform link config: the fold over the edge set equals the
        // per-link minimum
        let dm = DomainMap::new(spec, 4);
        assert!(!dm.inter_domain_edges().is_empty());
        assert_eq!(pdes_lookahead(&dm, &cfg), Some(cfg.min_link_latency()));
        // single domain: no inter-domain edges, nothing to synchronize on
        assert_eq!(pdes_lookahead(&DomainMap::new(spec, 1), &cfg), None);
    }

    #[test]
    fn dead_links_are_excluded_from_lookahead_until_none_remain() {
        use crate::fault::{FaultConfig, FaultModel};
        let spec = TorusSpec::new(4, 2, 2);
        let cfg = NicConfig::default();
        let dm = DomainMap::new(spec, 4);
        // no model / zero-fault model: identical to the unfiltered fold
        assert_eq!(pdes_lookahead_with(&dm, &cfg, None), Some(cfg.min_link_latency()));
        let healthy = FaultModel::build(&FaultConfig::default(), spec, 1);
        assert_eq!(
            pdes_lookahead_with(&dm, &cfg, Some(&healthy)),
            Some(cfg.min_link_latency())
        );
        // every cable dead from t=0: the filter would empty the edge set,
        // so the fold falls back to the unfiltered (still conservative)
        // bound rather than losing the partitioned setup invariants
        let all_dead = FaultModel::build(
            &FaultConfig { fail: 1.0, ..FaultConfig::default() },
            spec,
            1,
        );
        assert_eq!(
            pdes_lookahead_with(&dm, &cfg, Some(&all_dead)),
            Some(cfg.min_link_latency())
        );
        let g = pdes_channel_graph_with(&dm, &cfg, Some(&all_dead));
        assert_eq!(g.min_lookahead(), Some(cfg.min_link_latency()));
    }

    #[test]
    fn channel_graph_closure_covers_all_domain_pairs() {
        let spec = TorusSpec::new(4, 2, 2);
        let cfg = NicConfig::default();
        let dm = DomainMap::new(spec, 4);
        let g = pdes_channel_graph(&dm, &cfg);
        assert_eq!(g.n_domains(), 4);
        // the cheapest channel is a single inter-domain hop
        assert_eq!(g.min_lookahead(), Some(cfg.min_link_latency()));
        // a torus is strongly connected, so its domain quotient is too:
        // the closure has a channel for every ordered pair, diagonal
        // (cycle) channels included
        assert_eq!(g.n_channels(), 4 * 4);
    }
}
