//! Fabric construction helpers: build a torus of NIC actors and wire the
//! neighbor links.
//!
//! The builder exploits the fact that [`crate::sim::Sim::add`] assigns
//! consecutive actor ids: NICs are added in node-address order, so the id
//! of node `a` is `base + a.0`, and neighbor wiring needs no second pass.

use crate::msg::Msg;
use crate::sim::{ActorId, Sim, Time};

use super::nic::{Nic, NicConfig};
use super::torus::{DomainMap, TorusSpec, DIRS};

/// Build a full torus of NICs; returns the actor ids in node-address order.
///
/// Local units are attached afterwards via [`Nic::attach_local`].
pub fn build_torus(sim: &mut Sim<Msg>, spec: &TorusSpec, cfg: NicConfig) -> Vec<ActorId> {
    let base = sim.n_actors();
    let ids: Vec<ActorId> = spec
        .nodes()
        .map(|addr| sim.add(Nic::new(addr, *spec, cfg)))
        .collect();
    debug_assert_eq!(ids.first().copied(), Some(base));
    for addr in spec.nodes() {
        for dir in DIRS {
            let n = spec.neighbor(addr, dir);
            let id = ids[addr.0 as usize];
            sim.get_mut::<Nic>(id).set_neighbor(dir, base + n.0 as usize);
        }
    }
    ids
}

/// Conservative-PDES lookahead for a partitioned fabric: the minimum
/// latency any message can incur on any **inter-domain** torus link
/// (packets pay serialization + cable + router pipeline; credit returns
/// pay cable + pipeline — see [`NicConfig::min_link_latency`]). A domain
/// may therefore execute up to `min(domain clocks) + lookahead`,
/// exclusive, without risking a causality violation
/// (`docs/ARCHITECTURE.md` has the full invariant).
///
/// All torus links share one [`NicConfig`], so the minimum over the
/// inter-domain edge set degenerates to that config's per-link minimum;
/// a multi-domain partition of a (connected) torus always has crossing
/// edges, so no enumeration is needed. Returns `None` for a single
/// domain — nothing to synchronize on.
pub fn pdes_lookahead(dm: &DomainMap, cfg: &NicConfig) -> Option<Time> {
    if dm.n_domains() <= 1 {
        return None;
    }
    Some(cfg.min_link_latency())
}

/// A handle to a built fabric (spec + NIC actor ids), with convenience
/// accessors for post-run statistics.
pub struct Fabric {
    pub spec: TorusSpec,
    pub cfg: NicConfig,
    pub nics: Vec<ActorId>,
}

impl Fabric {
    pub fn build(sim: &mut Sim<Msg>, spec: TorusSpec, cfg: NicConfig) -> Fabric {
        let nics = build_torus(sim, &spec, cfg);
        Fabric { spec, cfg, nics }
    }

    /// Total packets delivered to local units across all nodes.
    pub fn total_delivered(&self, sim: &Sim<Msg>) -> u64 {
        self.nics
            .iter()
            .map(|&id| sim.get::<Nic>(id).stats.delivered)
            .sum()
    }

    /// Total spike events delivered across all nodes.
    pub fn total_delivered_events(&self, sim: &Sim<Msg>) -> u64 {
        self.nics
            .iter()
            .map(|&id| sim.get::<Nic>(id).stats.delivered_events)
            .sum()
    }

    /// Merged transit-latency histogram (ps).
    pub fn transit_histogram(&self, sim: &Sim<Msg>) -> crate::util::stats::Histogram {
        let mut h = crate::util::stats::Histogram::new();
        for &id in &self.nics {
            h.merge(&sim.get::<Nic>(id).stats.transit_ps);
        }
        h
    }

    /// Peak utilization over all torus ports, given the observation window.
    pub fn max_link_utilization(&self, sim: &Sim<Msg>, window: crate::sim::Time) -> f64 {
        let mut max = 0.0f64;
        for &id in &self.nics {
            let nic = sim.get::<Nic>(id);
            for port in 0..6 {
                max = max.max(nic.port_utilization(port, window));
            }
        }
        max
    }

    /// Mean utilization over all torus ports that carried any traffic.
    pub fn mean_active_link_utilization(&self, sim: &Sim<Msg>, window: crate::sim::Time) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u32;
        for &id in &self.nics {
            let nic = sim.get::<Nic>(id);
            for port in 0..6 {
                if nic.port_tx_packets(port) > 0 {
                    sum += nic.port_utilization(port, window);
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_wires_all_neighbors() {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(3, 2, 2);
        let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
        assert_eq!(fabric.nics.len(), 12);
        // ids must map to addresses in order
        for (i, &id) in fabric.nics.iter().enumerate() {
            let nic = sim.get::<Nic>(id);
            assert_eq!(nic.addr.0 as usize, i);
        }
    }

    #[test]
    fn stats_start_zero() {
        let mut sim = Sim::new();
        let spec = TorusSpec::new(2, 2, 1);
        let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
        assert_eq!(fabric.total_delivered(&sim), 0);
        assert_eq!(fabric.total_delivered_events(&sim), 0);
        assert_eq!(fabric.max_link_utilization(&sim, crate::sim::Time::from_us(1)), 0.0);
    }
}
