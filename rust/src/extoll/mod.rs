//! The Extoll network substrate (paper §1): Tourmalet NICs, links of up to
//! 12 × 8.4 Gbit/s serial lanes, a 3D-torus topology with 16-bit node
//! addresses, dimension-order routing, the RMA protocol helpers, a
//! flow-level bandwidth analyzer, and the Gigabit-Ethernet baseline the
//! paper's system replaces.

pub mod analysis;
pub mod baseline;
pub mod link;
pub mod network;
pub mod nic;
pub mod packet;
pub mod rma;
pub mod routing;
pub mod torus;

pub use analysis::{Flow, FlowAnalysis};
pub use baseline::{GbeConfig, GbeLink};
pub use link::{LinkLayer, LinkReliabilityConfig, Reliability};
pub use network::{build_torus, build_torus_with, Fabric};
pub use nic::{Nic, NicConfig, NicStats};
pub use packet::{Packet, PacketKind, HEADER_BYTES, MAX_EVENTS_PER_PACKET, MAX_PAYLOAD_BYTES};
pub use rma::{fragment_put, Notification};
pub use routing::{
    links_on_route, links_on_route_with, next_hop, next_hop_with, route, route_with, FaultFree,
    Hop, LinkStatus,
};
pub use torus::{Dir, NodeAddr, TorusSpec, DIRS, LOCAL_PORT, TOURMALET_LINKS};
